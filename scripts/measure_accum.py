import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf iteration i9 measurement: microbatch gradient accumulation (accum=2)
vs accum=1 on internlm2-1.8b train_4k (pod).  Same global batch, same math;
hypothesis: per-device activation temp halves.

  PYTHONPATH=src python scripts/measure_accum.py
"""

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import get_config
from repro.launch.cells import _lm_param_shardings, _set_lm_hints, _ns
from repro.launch.mesh import make_production_mesh
from repro.optim import adamw
from repro.train import steps as train_steps


def measure(accum: int):
    mesh = make_production_mesh()
    spec = get_config("internlm2-1.8b")
    cfg = spec.config
    _set_lm_hints(mesh)
    pshape, pshard = _lm_param_shardings(cfg, mesh)
    opt_cfg = adamw.AdamWConfig()
    oshape = jax.eval_shape(lambda p: adamw.init(p, opt_cfg), pshape)
    oshard = {"mu": pshard, "nu": pshard, "step": _ns(mesh)}
    B, S = 256, 4096
    if accum == 1:
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        bshard = {k: _ns(mesh, ("data",), None) for k in batch}
    else:
        batch = {"tokens": jax.ShapeDtypeStruct((accum, B // accum, S),
                                                jnp.int32),
                 "labels": jax.ShapeDtypeStruct((accum, B // accum, S),
                                                jnp.int32)}
        bshard = {k: _ns(mesh, None, ("data",), None) for k in batch}
    fn = train_steps.make_lm_train_step(cfg, opt_cfg, accum=accum)
    rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
    with mesh:
        c = jax.jit(fn, in_shardings=(pshard, oshard, bshard, _ns(mesh))) \
            .lower(pshape, oshape, batch, rng).compile()
    m = c.memory_analysis()
    print(f"accum={accum}: temp={m.temp_size_in_bytes / 1e9:.2f} GB "
          f"args={m.argument_size_in_bytes / 1e9:.2f} GB")
    return m.temp_size_in_bytes


if __name__ == "__main__":
    t1 = measure(1)
    t2 = measure(2)
    print(f"temp ratio accum2/accum1 = {t2 / t1:.3f}")
