#!/usr/bin/env python
"""Docs link checker (CI docs job; ISSUE 4).

Verifies every intra-repo reference in the given markdown files:

  * relative markdown links ``[text](path)`` and ``[text](path#anchor)``
    resolve to files/directories in the repository (http(s)/mailto links
    are skipped),
  * backtick code spans that look like repo paths (contain a ``/`` and a
    known source suffix) resolve to files — this is how README/DESIGN
    point at modules,
  * ``DESIGN.md §x.y`` section references used across the repo's docs and
    docstrings resolve to an actual ``### x.y`` / ``## x`` heading.

Exit 1 with a per-file report when anything dangles.

Usage: python scripts/check_links.py README.md DESIGN.md ROADMAP.md
"""

from __future__ import annotations

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_PATH = re.compile(r"`([A-Za-z0-9_./-]+/[A-Za-z0-9_./-]+"
                       r"\.(?:py|md|json|yml|toml|txt))(?:::[^`]*)?`")
# only explicitly-prefixed refs are checked: bare §x.y cites the *paper*
# by repo convention; ranges (DESIGN.md §2.7–§2.9) check both ends
SECTION_REF = re.compile(r"DESIGN\.md\s+§(\d+(?:\.\d+)?)"
                         r"(?:[–-]§(\d+(?:\.\d+)?))?")
HEADING = re.compile(r"^#{1,4}\s+(\d+(?:\.\d+)?)[.\s]", re.M)
# bare code paths in DESIGN.md/docstrings are relative to the package root
PATH_PREFIXES = ("", "src/repro")


def design_sections() -> set[str]:
    path = os.path.join(ROOT, "DESIGN.md")
    if not os.path.exists(path):
        return set()
    with open(path) as fh:
        return set(HEADING.findall(fh.read()))


def check_file(path: str, sections: set[str]) -> list[str]:
    errors = []
    with open(path) as fh:
        text = fh.read()
    base = os.path.dirname(os.path.abspath(path))
    for target in MD_LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#")[0]
        if not rel:
            continue
        if not os.path.exists(os.path.join(base, rel)):
            errors.append(f"dangling link: ({target})")
    for rel in CODE_PATH.findall(text):
        if not any(os.path.exists(os.path.join(ROOT, pre, rel))
                   for pre in PATH_PREFIXES):
            errors.append(f"dangling code path: `{rel}`")
    for m in SECTION_REF.findall(text):
        for sec in filter(None, m):
            if sec not in sections:
                errors.append(f"dangling section ref: DESIGN.md §{sec}")
    return errors


def main(argv: list[str]) -> int:
    files = argv or ["README.md", "DESIGN.md", "ROADMAP.md"]
    sections = design_sections()
    failed = False
    for f in files:
        path = os.path.join(ROOT, f) if not os.path.isabs(f) else f
        if not os.path.exists(path):
            print(f"{f}: MISSING FILE")
            failed = True
            continue
        errors = check_file(path, sections)
        for e in errors:
            print(f"{f}: {e}")
        failed = failed or bool(errors)
        if not errors:
            print(f"{f}: ok")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
