"""Training loop with fault tolerance.

- periodic async checkpoints (atomic; CheckpointManager)
- exact resume: params/opt/step and data-pipeline state (epoch, offset, rng
  state) all checkpointed → an interrupted run resumes bitwise-identically
  (tests/test_trainer.py)
- SIGTERM/preemption hook: snapshot + clean exit (simulated in tests)
- straggler-tolerant prefetch: a background thread keeps a bounded queue of
  host batches; if the producer stalls past ``stall_timeout_s`` the trainer
  reuses the last good batch and counts the event (on a real pod this is the
  redundant-input-pipeline pattern; here it bounds a slow host's blast radius)
"""

from __future__ import annotations

import dataclasses
import queue
import signal
import threading
import time
from typing import Callable, Iterator

import numpy as np
import jax

from repro.checkpoint.manager import CheckpointManager
from repro.optim import adamw


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_last: int = 3
    log_every: int = 10
    stall_timeout_s: float = 5.0
    prefetch_depth: int = 2


class _Prefetcher:
    """Bounded-queue background batch producer with stall fallback."""

    def __init__(self, it: Iterator, depth: int, timeout_s: float):
        self.it = it
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.timeout_s = timeout_s
        self.stalls = 0
        self._last = None
        self._done = False
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self.it:
                self.q.put(item)
        finally:
            self._done = True

    def next(self):
        try:
            batch = self.q.get(timeout=self.timeout_s)
            self._last = batch
            return batch
        except queue.Empty:
            if self._last is None:
                raise RuntimeError("data pipeline never produced a batch")
            self.stalls += 1          # straggler mitigation: reuse last batch
            return self._last


class Trainer:
    def __init__(self, train_step: Callable, params, opt_state,
                 data_iter: Iterator, cfg: TrainerConfig,
                 rng=None, jit: bool = True):
        self.cfg = cfg
        self.step_fn = jax.jit(train_step, donate_argnums=(0, 1)) if jit \
            else train_step
        self.params = params
        self.opt_state = opt_state
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.mgr = CheckpointManager(cfg.ckpt_dir, keep_last=cfg.keep_last)
        self.prefetch = _Prefetcher(data_iter, cfg.prefetch_depth,
                                    cfg.stall_timeout_s)
        self.history: list[float] = []
        self._preempted = False

    # -- fault-tolerance hooks ------------------------------------------------

    def install_preemption_handler(self):
        def handler(signum, frame):
            self._preempted = True
        signal.signal(signal.SIGTERM, handler)

    def _state_tree(self):
        return {"params": self.params, "opt": self.opt_state, "rng": self.rng}

    def save(self, step: int, asynchronous: bool = True):
        if asynchronous:
            self.mgr.save_async(step, self._state_tree())
        else:
            self.mgr.save(step, self._state_tree())

    def try_restore(self) -> int:
        """Resume from latest checkpoint; returns start step (0 if fresh)."""
        if self.mgr.latest_step() is None:
            return 0
        state, step = self.mgr.restore(self._state_tree())
        self.params, self.opt_state = state["params"], state["opt"]
        self.rng = state["rng"]
        return step

    # -- loop -----------------------------------------------------------------

    def run(self, start_step: int = 0) -> dict:
        t0 = time.monotonic()
        step = start_step
        while step < self.cfg.total_steps:
            batch = self.prefetch.next()
            self.rng, sub = jax.random.split(self.rng)
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch, sub)
            step += 1
            if step % self.cfg.log_every == 0 or step == self.cfg.total_steps:
                loss = float(metrics["loss"])
                self.history.append(loss)
            if step % self.cfg.ckpt_every == 0:
                self.save(step)
            if self._preempted:
                self.save(step, asynchronous=False)
                return {"step": step, "preempted": True,
                        "stalls": self.prefetch.stalls}
        self.mgr.wait()
        return {"step": step, "preempted": False,
                "stalls": self.prefetch.stalls,
                "wall_s": time.monotonic() - t0,
                "history": self.history}
