"""Train-step factories — the functions the launcher jits/pjits and the
dry-run lowers.  Pure (params, opt_state, batch[, rng]) → (params, opt_state,
metrics); sharding is supplied externally via in_shardings/out_shardings.
Optional microbatch gradient accumulation via lax.scan (one optimizer update,
one gradient all-reduce per step — the standard comm-minimizing layout).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import gnn as gnn_lib
from repro.models import recsys as recsys_lib
from repro.models.transformer import LMConfig, lm_loss
from repro.optim import adamw


def _make_step(loss_fn: Callable, opt_cfg: adamw.AdamWConfig,
               total_steps: int = 10000, warmup: int = 100,
               accum: int = 1, has_rng: bool = False):
    def grads_of(params, batch, rng):
        if has_rng:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch, rng)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(params, opt_state, batch, rng):
        if accum == 1:
            loss, metrics, grads = grads_of(params, batch, rng)
        else:
            # batch leaves have a leading (accum,) microbatch dim
            def body(carry, mb):
                acc, loss_acc = carry
                loss, _, grads = grads_of(params, mb, rng)
                acc = jax.tree.map(jnp.add, acc, grads)
                return (acc, loss_acc + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = lax.scan(body, (zeros, jnp.float32(0)), batch)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss / accum
            metrics = {}
        lr_scale = adamw.cosine_schedule(opt_state["step"], 1.0, warmup,
                                         total_steps)
        params, opt_state, om = adamw.update(grads, opt_state, params,
                                             opt_cfg, lr_scale)
        return params, opt_state, {"loss": loss, **metrics, **om}

    return train_step


# ---------------------------------------------------------------------------
# per-family factories
# ---------------------------------------------------------------------------

def make_lm_train_step(cfg: LMConfig, opt_cfg=None, accum: int = 1, **kw):
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    return _make_step(lambda p, b: lm_loss(p, b, cfg), opt_cfg,
                      accum=accum, **kw)


def make_gnn_train_step(cfg: gnn_lib.GNNConfig, variant: str,
                        opt_cfg=None, fanout=(15, 10), **kw):
    opt_cfg = opt_cfg or adamw.AdamWConfig(weight_decay=0.0)
    if variant == "full":
        return _make_step(lambda p, b: gnn_lib.node_loss(p, b, cfg),
                          opt_cfg, **kw)
    if variant == "minibatch":
        return _make_step(
            lambda p, b, r: gnn_lib.minibatch_loss(p, b, r, cfg, fanout),
            opt_cfg, has_rng=True, **kw)
    if variant == "molecule":
        return _make_step(lambda p, b: gnn_lib.molecule_loss(p, b, cfg),
                          opt_cfg, **kw)
    raise ValueError(variant)


def make_recsys_train_step(cfg: recsys_lib.RecsysConfig, opt_cfg=None, **kw):
    opt_cfg = opt_cfg or adamw.AdamWConfig(weight_decay=0.0)
    loss_fn = recsys_lib.LOSS[cfg.arch]
    return _make_step(lambda p, b: loss_fn(p, b, cfg), opt_cfg, **kw)
