"""HYB+M2 inverted index builder (paper §6.7, after Culpepper & Moffat [6]).

Lists with average gap ≤ B (i.e. len ≥ n_docs/B) become bitmaps; the rest are
compressed with the configured codec.  The corpus is split into ``n_parts``
doc-id ranges — the paper's L3-cache partitioning, which at cluster scale maps
1:1 onto data-parallel shards (DESIGN.md §2.5).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any

import numpy as np

from repro.core import bitmap as bm
from repro.core import codecs as codec_lib


@dataclasses.dataclass
class TermPosting:
    kind: str                  # 'list' | 'bitmap' | 'empty'
    payload: Any               # PackedList/PatchedList/VarintList | words
    n: int                     # postings in this part
    raw: np.ndarray | None = None   # kept for oracle checks in tests


_part_uids = itertools.count()


@dataclasses.dataclass
class IndexPart:
    doc_lo: int
    doc_hi: int
    terms: dict[int, TermPosting]
    # process-unique id for cache keying: id(part) can be reused by the
    # allocator after a part is freed, which would let a long-lived
    # DecodeCache serve stale lists across index rebuilds
    uid: int = dataclasses.field(default_factory=lambda: next(_part_uids))


@dataclasses.dataclass
class HybridIndex:
    n_docs: int
    B: int                      # bitmap threshold (0 = no bitmaps)
    codec_name: str
    parts: list[IndexPart]

    def stats(self) -> dict:
        from repro.core import varint as varint_lib
        bits = 0
        n = 0
        codec = codec_lib.get_codec(self.codec_name)
        for part in self.parts:
            for tp in part.terms.values():
                n += tp.n
                if tp.kind == "bitmap":
                    bits += int(tp.payload.size) * 32
                elif tp.kind == "list":
                    if isinstance(tp.payload, varint_lib.VarintList):
                        bits += varint_lib.bits_per_int(tp.payload) * tp.n
                    else:
                        bits += codec.bits_per_int(tp.payload) * tp.n
        return {"bits_per_int": bits / max(n, 1), "postings": n}


def build(postings: list[np.ndarray], n_docs: int, codec_name: str = "bp-d1",
          B: int = 0, n_parts: int = 1, keep_raw: bool = False,
          varint_tail_below: int = 1024,
          precompute_layouts: bool = True) -> HybridIndex:
    """varint_tail_below: lists shorter than this are stored Varint — the
    paper's tail-codec rule (block packing pays block/n × padding overhead on
    tiny lists; EXPERIMENTS §Perf c4).

    precompute_layouts: project every skip-capable list onto its self-padded
    batch-uniform PackedLayout at build time (memoized per payload uid in
    the posting-source layer), so serving never pays the projection on the
    query path (DESIGN.md §2.8)."""
    codec = codec_lib.get_codec(codec_name)
    tail_codec = codec_lib.get_codec("varint")
    bounds = np.linspace(0, n_docs, n_parts + 1).astype(np.int64)
    parts = []
    for p in range(n_parts):
        lo, hi = int(bounds[p]), int(bounds[p + 1])
        span = max(hi - lo, 1)
        terms: dict[int, TermPosting] = {}
        for tid, docs in enumerate(postings):
            seg = docs[(docs >= lo) & (docs < hi)] - lo
            if seg.size == 0:
                terms[tid] = TermPosting("empty", None, 0)
                continue
            avg_gap = span / seg.size
            if B > 0 and avg_gap <= B:
                terms[tid] = TermPosting(
                    "bitmap", bm.build_np(seg, span), int(seg.size),
                    raw=seg if keep_raw else None)
            else:
                c = tail_codec if (codec_name != "varint"
                                   and seg.size < varint_tail_below) else codec
                terms[tid] = TermPosting(
                    "list", c.encode(seg), int(seg.size),
                    raw=seg if keep_raw else None)
        parts.append(IndexPart(lo, hi, terms))
    if precompute_layouts:
        from repro.index import source
        source.precompute_layouts(parts)
    return HybridIndex(n_docs=n_docs, B=B, codec_name=codec_name, parts=parts)


def warmup_serving(index: HybridIndex, queries: list[list[int]] | None = None,
                   *, plan=None, batch_size: int = 32, backend: str = "jax",
                   pool=None, **kwargs) -> dict:
    """Build-time AOT signature warmup (DESIGN.md §2.10): precompile the
    fused family ladder so the first served batch never stalls on jit
    compiles.  ``queries`` should be a representative workload sample when
    one exists (e.g. a replayed log slice); None synthesizes one from the
    index term stats.  Returns the ``batch.warmup`` report and the plan it
    warmed (pass both to the serving loop)."""
    from repro.index import batch as batch_lib
    if plan is None:
        plan = batch_lib.FusionPlan()
    report = batch_lib.warmup(index, queries, plan=plan,
                              batch_size=batch_size, backend=backend,
                              pool=pool, **kwargs)
    report["plan"] = plan
    return report


def build_sharded(postings: list[np.ndarray], n_docs: int, *, n_shards: int,
                  codec_name: str = "bp-d1", B: int = 0,
                  n_parts: int | None = None, keep_raw: bool = False,
                  varint_tail_below: int = 1024,
                  capacity_ints: int = 1 << 26, warm: bool = True):
    """Per-part build placed onto data-parallel shards (DESIGN.md §2.5).

    Builds ``n_parts`` doc-id-range parts (default ``n_shards`` — the 1:1
    part↔shard mapping the paper's partitioning suggests at cluster scale)
    and returns a ``repro.index.shard.ShardedIndex`` carrying the
    part→shard→device placement map, with each shard's working set staged
    on its own device when ``warm``."""
    if n_parts is None:
        n_parts = n_shards
    idx = build(postings, n_docs, codec_name=codec_name, B=B,
                n_parts=n_parts, keep_raw=keep_raw,
                varint_tail_below=varint_tail_below)
    from repro.index import shard as shard_lib
    return shard_lib.shard_index(idx, n_shards, capacity_ints=capacity_ints,
                                 warm=warm)
