"""HYB+M2 inverted index builder (paper §6.7, after Culpepper & Moffat [6]).

Lists with average gap ≤ B (i.e. len ≥ n_docs/B) become bitmaps; the rest are
compressed with the configured codec.  The corpus is split into ``n_parts``
doc-id ranges — the paper's L3-cache partitioning, which at cluster scale maps
1:1 onto data-parallel shards (DESIGN.md §2.5).

``codec_name="auto"`` turns on the build-time storage autotuner (DESIGN.md
§2.13): per posting list it computes closed-form byte estimates for every
codec family from the list's delta statistics (length, density, skew — no
trial encodes), combines them with a *measured* cost table (decode ns/int
per codec + gallop ns/probe, emitted by ``benchmarks/bench_decode.py
--json``; default table checked into ``configs/paper_index.py``), and picks
the family + skip policy minimizing estimated serve-plus-storage cost.
Every choice is lossless, so an autotuned index answers queries
byte-identically to a single-codec build — the differential tests assert
exactly that.
"""

from __future__ import annotations

import dataclasses
import itertools
import json
from typing import Any

import numpy as np

from repro.core import bitmap as bm
from repro.core import codecs as codec_lib


@dataclasses.dataclass
class TermPosting:
    kind: str                  # 'list' | 'bitmap' | 'empty'
    payload: Any               # PackedList/PatchedList/VarintList/… | words
    n: int                     # postings in this part
    raw: np.ndarray | None = None   # kept for oracle checks in tests
    skip_ok: bool = True       # autotuner skip policy: False forces the
                               # decoded path even for skip-capable payloads


_part_uids = itertools.count()


@dataclasses.dataclass
class IndexPart:
    doc_lo: int
    doc_hi: int
    terms: dict[int, TermPosting]
    # process-unique id for cache keying: id(part) can be reused by the
    # allocator after a part is freed, which would let a long-lived
    # DecodeCache serve stale lists across index rebuilds
    uid: int = dataclasses.field(default_factory=lambda: next(_part_uids))


@dataclasses.dataclass
class HybridIndex:
    n_docs: int
    B: int                      # bitmap threshold (0 = no bitmaps)
    codec_name: str
    parts: list[IndexPart]

    def stats(self) -> dict:
        """Storage accounting by payload type via the codec registry
        (``codecs.codec_for``): bits/int and bytes/int over the whole
        index plus per-family list counts — the compression numbers
        serve.py and bench_engine report alongside q/s."""
        bits = 0.0
        n = 0
        counts: dict[str, int] = {}
        fam_bits: dict[str, float] = {}
        for part in self.parts:
            for tp in part.terms.values():
                n += tp.n
                if tp.kind == "bitmap":
                    fam, b = "bitmap", float(int(tp.payload.size) * 32)
                elif tp.kind == "list":
                    fam = codec_lib.family_of(tp.payload)
                    b = (codec_lib.codec_for(tp.payload)
                         .bits_per_int(tp.payload) * tp.n)
                else:
                    continue
                bits += b
                counts[fam] = counts.get(fam, 0) + 1
                fam_bits[fam] = fam_bits.get(fam, 0.0) + b
        return {"bits_per_int": bits / max(n, 1),
                "bytes_per_int": bits / 8 / max(n, 1),
                "postings": n,
                "codec_counts": counts,
                "codec_bytes": {k: int(v // 8) for k, v in fam_bits.items()}}


# --------------------------------------------------------------------------
# build-time storage autotuner (DESIGN.md §2.13)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class CostModel:
    """Measured per-codec costs driving per-list codec + skip selection.

    ``decode_ns_per_int`` and ``dispatch_ns_per_list`` come straight from
    ``bench_decode.py --json`` (keys are codec names, e.g. ``bp-d1`` /
    ``varint``); the modeled decode wall-clock of one list is the fixed
    per-decode dispatch term plus ``n ·`` the per-int term
    (``_decode_cost``), and a family's score adds ``space_ns_per_byte ·
    bytes`` with bytes estimated closed-form from the list's delta
    statistics.  The dispatch term is what makes short lists interesting:
    on this container a device decode costs ~200–400 µs before the first
    int lands, so a host-decoded composite/varint list beats bitpack on
    *measured* wall clock below ~1 K ints even though its per-int cost is
    higher.  ``gallop_ns_per_probe`` prices the packed skip path: a long
    bitpacked list keeps ``skip_ok`` only when probing its skip index is
    estimated cheaper than decoding it outright.
    """
    decode_ns_per_int: dict[str, float]
    dispatch_ns_per_list: dict[str, float] = dataclasses.field(
        default_factory=dict)
    gallop_ns_per_probe: float = 90.0
    space_ns_per_byte: float = 2.0
    # a skip probe touches ~one candidate row per rare posting; this is
    # the reference candidate cardinality the skip-vs-decode comparison
    # assumes (the serve-time ratio test still gates per query).
    ref_probes: int = 4096

    def decode_ns(self, family: str) -> float:
        t = self.decode_ns_per_int
        return float(t.get(f"{family}-d1", t.get(family, 1.0)))

    def dispatch_ns(self, family: str) -> float:
        t = self.dispatch_ns_per_list
        return float(t.get(f"{family}-d1", t.get(family, 0.0)))

    @classmethod
    def resolve(cls, table=None) -> "CostModel":
        """table: None → checked-in default (configs.paper_index), str →
        path to a ``bench_decode --json`` dump, dict → inline table."""
        if table is None:
            from repro.configs.paper_index import DEFAULT_COST_TABLE
            table = DEFAULT_COST_TABLE
        elif isinstance(table, str):
            with open(table) as f:
                table = json.load(f)
        return cls(
            decode_ns_per_int=dict(table.get("decode_ns_per_int", {})),
            dispatch_ns_per_list=dict(table.get("dispatch_ns_per_list", {})),
            gallop_ns_per_probe=float(table.get("gallop_ns_per_probe", 90.0)),
            space_ns_per_byte=float(table.get("space_ns_per_byte", 2.0)))


def list_stats(seg: np.ndarray, span: int) -> dict:
    """Per-list statistics the autotuner scores on: length, density, and
    gap skew (max/mean delta ratio — high skew favors byte-granular and
    patched codecs over a per-block uniform bit width)."""
    n = int(seg.size)
    d = np.diff(seg.astype(np.int64), prepend=np.int64(0))
    mean_gap = float(d.mean()) if n else 0.0
    return {"n": n,
            "density": n / max(span, 1),
            "skew": float(d.max()) / max(mean_gap, 1e-9) if n else 0.0}


def _est_bytes(seg: np.ndarray) -> dict[str, float]:
    """Closed-form storage estimate per codec family from the D1 deltas —
    no trial encodes.  Mirrors each encoder's actual layout: bitpack pads
    to full blocks at the adaptive block size and pays the per-block max
    width; streamvbyte pays whole bytes + 2-bit control codes on 128-padded
    blocks; varint pays 7-bit groups; composite pays bitpack on the full-
    block prefix and varint on the tail."""
    n = int(seg.size)
    d = np.diff(seg.astype(np.int64), prepend=np.int64(0)).astype(np.uint64)
    bl = np.zeros(n, dtype=np.int64)
    nz = d > 0
    bl[nz] = np.floor(
        np.log2(d[nz].astype(np.float64))).astype(np.int64) + 1

    def block_bytes(rows: int, lens: np.ndarray) -> float:
        per = rows * 128
        k = max(-(-max(len(lens), 1) // per), 1)
        padded = np.zeros(k * per, np.int64)
        padded[: len(lens)] = lens
        widths = padded.reshape(k, per).max(axis=1)
        return float(widths.sum()) * per / 8 + k * 5     # +width/max meta

    rows = 8 if n <= 8192 else 32
    varint_b = float(np.maximum(-(-bl // 7), 1).sum())
    svb_pad = (-n) % 128
    svb_b = (float(np.maximum(-(-bl // 8), 1).sum()) + svb_pad
             + (n + svb_pad) / 4 + max(-(-n // 128), 1) * 8)
    bp_b = block_bytes(rows, bl)
    per8 = 8 * 128
    n_head = (n // per8) * per8
    comp_b = ((block_bytes(8, bl[:n_head]) if n_head else 0.0)
              + float(np.maximum(-(-bl[n_head:] // 7), 1).sum()))
    return {"bp": bp_b, "streamvbyte": svb_b, "varint": varint_b,
            "composite": comp_b}


# Below this many ints a bitpacked list can't reach SKIP_MIN_BLOCKS blocks
# at the adaptive block size, so packed serving is off the table and the
# decode-cost comparison decides alone.
_SKIP_MIN_INTS = 4 * 8 * 128


def _decode_cost(fam: str, n: int, cm: CostModel) -> float:
    """Modeled wall-clock ns to decode one n-int list: the family's fixed
    per-decode dispatch term plus a linear per-int term.  Composite is
    derived from its parts (bp8 head + varint tail) because its blend
    depends on n — the flat ``composite-d1`` table entry was measured at
    2^16 ints where the tail is negligible, which badly underestimates a
    short all-tail list."""
    if fam == "composite":
        per = 8 * 128
        n_head = (n // per) * per
        cost = cm.dispatch_ns("varint") + (n - n_head) * cm.decode_ns("varint")
        if n_head:
            cost += cm.dispatch_ns("bp8") + n_head * cm.decode_ns("bp8")
        return cost
    if fam == "bp" and n <= 8192:
        fam = "bp8"     # bitpack.encode adapts to 8-row blocks here
    return cm.dispatch_ns(fam) + n * cm.decode_ns(fam)


def autotune_choice(seg: np.ndarray, span: int, cm: CostModel,
                    mode: str = "d1") -> tuple[str, bool]:
    """Pick (codec name, skip_ok) for one posting list."""
    n = int(seg.size)
    if n >= _SKIP_MIN_INTS:
        # long lists: bitpack — the only skip-capable layout — and keep the
        # skip index only when probing beats decoding at reference load
        skip_ok = (cm.gallop_ns_per_probe * cm.ref_probes
                   < _decode_cost("bp", n, cm))
        return f"bp-{mode}", skip_ok
    est = _est_bytes(seg)
    score = {fam: _decode_cost(fam, n, cm) + cm.space_ns_per_byte * b
             for fam, b in est.items()}
    fam = min(score, key=score.get)
    name = "varint" if fam == "varint" else f"{fam}-{mode}"
    return name, fam == "bp"


def build(postings: list[np.ndarray], n_docs: int, codec_name: str = "bp-d1",
          B: int = 0, n_parts: int = 1, keep_raw: bool = False,
          varint_tail_below: int = 1024,
          precompute_layouts: bool = True,
          cost_table=None) -> HybridIndex:
    """varint_tail_below: lists shorter than this are stored Varint — the
    paper's tail-codec rule (block packing pays block/n × padding overhead on
    tiny lists; EXPERIMENTS §Perf c4).  ``codec_name="auto"`` replaces the
    fixed codec + tail rule with the cost-model autotuner (module docstring);
    ``cost_table`` feeds it a ``bench_decode --json`` table (path or dict,
    None = the checked-in default).

    precompute_layouts: project every skip-capable list onto its self-padded
    batch-uniform PackedLayout at build time (memoized per payload uid in
    the posting-source layer), so serving never pays the projection on the
    query path (DESIGN.md §2.8)."""
    auto = codec_name == "auto"
    cm = CostModel.resolve(cost_table) if auto else None
    codec = codec_lib.get_codec(codec_name)
    tail_codec = codec_lib.get_codec("varint")
    bounds = np.linspace(0, n_docs, n_parts + 1).astype(np.int64)
    parts = []
    for p in range(n_parts):
        lo, hi = int(bounds[p]), int(bounds[p + 1])
        span = max(hi - lo, 1)
        terms: dict[int, TermPosting] = {}
        for tid, docs in enumerate(postings):
            seg = docs[(docs >= lo) & (docs < hi)] - lo
            if seg.size == 0:
                terms[tid] = TermPosting("empty", None, 0)
                continue
            avg_gap = span / seg.size
            if B > 0 and avg_gap <= B:
                terms[tid] = TermPosting(
                    "bitmap", bm.build_np(seg, span), int(seg.size),
                    raw=seg if keep_raw else None)
            elif auto:
                name, skip_ok = autotune_choice(seg, span, cm)
                terms[tid] = TermPosting(
                    "list", codec_lib.get_codec(name).encode(seg),
                    int(seg.size), raw=seg if keep_raw else None,
                    skip_ok=skip_ok)
            else:
                c = tail_codec if (codec_name != "varint"
                                   and seg.size < varint_tail_below) else codec
                terms[tid] = TermPosting(
                    "list", c.encode(seg), int(seg.size),
                    raw=seg if keep_raw else None)
        parts.append(IndexPart(lo, hi, terms))
    if precompute_layouts:
        from repro.index import source
        source.precompute_layouts(parts)
    return HybridIndex(n_docs=n_docs, B=B, codec_name=codec_name, parts=parts)


def warmup_serving(index: HybridIndex, queries: list[list[int]] | None = None,
                   *, plan=None, batch_size: int = 32, backend: str = "jax",
                   pool=None, **kwargs) -> dict:
    """Build-time AOT signature warmup (DESIGN.md §2.10): precompile the
    fused family ladder so the first served batch never stalls on jit
    compiles.  ``queries`` should be a representative workload sample when
    one exists (e.g. a replayed log slice); None synthesizes one from the
    index term stats.  Returns the ``batch.warmup`` report and the plan it
    warmed (pass both to the serving loop)."""
    from repro.index import batch as batch_lib
    if plan is None:
        plan = batch_lib.FusionPlan()
    report = batch_lib.warmup(index, queries, plan=plan,
                              batch_size=batch_size, backend=backend,
                              pool=pool, **kwargs)
    report["plan"] = plan
    return report


def build_sharded(postings: list[np.ndarray], n_docs: int, *, n_shards: int,
                  codec_name: str = "bp-d1", B: int = 0,
                  n_parts: int | None = None, keep_raw: bool = False,
                  varint_tail_below: int = 1024,
                  capacity_ints: int = 1 << 26, warm: bool = True,
                  cost_table=None):
    """Per-part build placed onto data-parallel shards (DESIGN.md §2.5).

    Builds ``n_parts`` doc-id-range parts (default ``n_shards`` — the 1:1
    part↔shard mapping the paper's partitioning suggests at cluster scale)
    and returns a ``repro.index.shard.ShardedIndex`` carrying the
    part→shard→device placement map, with each shard's working set staged
    on its own device when ``warm``."""
    if n_parts is None:
        n_parts = n_shards
    idx = build(postings, n_docs, codec_name=codec_name, B=B,
                n_parts=n_parts, keep_raw=keep_raw,
                varint_tail_below=varint_tail_below, cost_table=cost_table)
    from repro.index import shard as shard_lib
    return shard_lib.shard_index(idx, n_shards, capacity_ints=capacity_ints,
                                 warm=warm)
