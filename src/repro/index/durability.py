"""Durable lifecycle for the mutable segmented index (DESIGN.md §2.15).

``MutableIndex`` (segments.py) keeps every un-sealed add, every tombstone
and the whole segment composition in process memory — a crash loses all
of it.  This module gives the index a crash-safe on-disk lifecycle built
from two primitives, both chosen so that *no* crash instant can leave the
directory unrecoverable:

write-ahead log
    Every mutation (``add``/``delete``/``seal``) is appended to
    ``wal-<seq>.log`` *before* it is applied in memory.  Records are
    CRC-framed: an 11-byte header (magic ``WA``, record type, payload
    length, CRC-32 of the payload) followed by a compact-JSON payload.
    Replay stops at the first frame that is short, mis-magicked or fails
    its CRC — a torn trailing record is physically truncated on recovery
    and never propagated.  Because the append happens before the apply,
    a crash during the append itself loses only the mutation that was
    *in flight* (which the caller never saw complete), never one it did.

atomic snapshots
    ``checkpoint`` persists the full serving state using the
    tmp-then-rename + manifest-last discipline proven in
    ``checkpoint/manager.py``: segment payload files, the mutable-segment
    image and the tombstone list are each written to a ``.tmp`` path and
    renamed before the manifest that references them is itself
    tmp-written and renamed.  The manifest rename is the commit point —
    before it the old manifest is intact, after it every referenced file
    already exists.  Sealed segments are persisted *once*, at creation
    (seal / bootstrap / merge), as their raw per-term local postings;
    ``builder.build`` is deterministic, so rebuilding a segment from its
    postings file yields byte-identical serving behaviour.

Checkpoints rotate the WAL: manifest ``seq`` names its WAL file, so a
recovered state is exactly (newest readable manifest) + (replay of every
WAL with ``seq >= manifest.seq``, in order) — the same replay order a
single-file log would have, but with the already-snapshotted prefix
skipped by construction.  ``recover`` falls back to the previous manifest
if the newest is damaged, exactly like ``CheckpointManager.restore``.

Every failure seam here is instrumented with ``launch.faults`` injection
points (``wal.append.*``, ``snapshot.write``, ``snapshot.rename``) so the
fault-matrix tests can crash at each one and assert the recovery
differential.
"""

from __future__ import annotations

import json
import os
import struct
import threading
import zlib

import numpy as np

from repro.launch import faults as faults_lib


_MAGIC = b"WA"
_HDR = struct.Struct("<2sBII")          # magic, rtype, length, crc32
_MAX_RECORD = 1 << 24                   # frame-length sanity bound

_REC_TYPES = {"add": 1, "delete": 2, "seal": 3}
_REC_NAMES = {v: k for k, v in _REC_TYPES.items()}


class WalError(RuntimeError):
    """Misuse of the durable log (not a recoverable on-disk condition)."""


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _atomic_write(path: str, data: bytes, sync: bool) -> None:
    tmp = path + ".tmp"
    with open(tmp, "wb") as fh:
        fh.write(data)
        if sync:
            fh.flush()
            os.fsync(fh.fileno())
    os.rename(tmp, path)


def read_wal(path: str) -> tuple[list[tuple[str, dict]], int, bool]:
    """Parse one WAL file.  Returns ``(records, good_bytes, torn)`` where
    ``good_bytes`` is the offset of the first byte past the last complete
    valid record and ``torn`` says whether trailing bytes past it exist
    (short frame, bad magic, bad CRC, or unparseable payload — all are
    truncation cases, never errors: a crash mid-append is expected)."""
    records: list[tuple[str, dict]] = []
    good = 0
    size = os.path.getsize(path)
    with open(path, "rb") as fh:
        while True:
            hdr = fh.read(_HDR.size)
            if len(hdr) < _HDR.size:
                return records, good, good < size
            try:
                magic, rtype, length, crc = _HDR.unpack(hdr)
            except struct.error:
                return records, good, True
            if (magic != _MAGIC or rtype not in _REC_NAMES
                    or length > _MAX_RECORD):
                return records, good, True
            payload = fh.read(length)
            if len(payload) < length or zlib.crc32(payload) != crc:
                return records, good, True
            try:
                obj = json.loads(payload.decode())
            except (UnicodeDecodeError, json.JSONDecodeError):
                return records, good, True
            records.append((_REC_NAMES[rtype], obj))
            good += _HDR.size + length


class DurableLog:
    """One durable directory: ``wal-<seq>.log`` + ``manifest-<seq>.json``
    epochs plus a content-addressed-ish ``segments/`` store of raw
    per-term postings written once per sealed segment.

    A fresh index calls ``start_fresh`` (refusing a non-empty directory —
    that state belongs to ``MutableIndex.recover``); recovery re-attaches
    with ``_attach`` after replay.  ``sync=True`` adds fsync barriers for
    real kill-9 durability; tests drive crashes through the injector
    instead, so the default stays fast.
    """

    def __init__(self, directory: str, *, sync: bool = False,
                 injector: "faults_lib.FaultInjector | None" = None,
                 keep: int = 2):
        self.dir = directory
        self.segdir = os.path.join(directory, "segments")
        self.sync = sync
        self.injector = injector
        self.keep = max(keep, 1)
        self.seq = -1
        self._wal_f = None
        self._seg_counter: int | None = None
        self._pinned: set[str] = set()     # persisted but not yet in a manifest
        self._lock = threading.Lock()
        os.makedirs(self.segdir, exist_ok=True)

    # -- lifecycle ---------------------------------------------------------

    def start_fresh(self) -> None:
        if manifest_seqs(self.dir):
            raise WalError(
                f"{self.dir} already holds a durable index — "
                f"use MutableIndex.recover() instead of a fresh attach")
        self.seq = -1

    def _attach(self, seq: int) -> None:
        """Continue an existing directory at epoch ``seq`` (recovery path:
        the caller has already replayed and truncated the WAL tail)."""
        self.seq = seq
        self._open_wal(seq)

    def close(self) -> None:
        with self._lock:
            if self._wal_f is not None:
                self._wal_f.close()
                self._wal_f = None

    def _fire(self, point: str):
        if self.injector is not None:
            return self.injector.fire(point)
        return None

    def _open_wal(self, seq: int) -> None:
        if self._wal_f is not None:
            self._wal_f.close()
        self._wal_f = open(self.wal_path(seq), "ab")

    def wal_path(self, seq: int) -> str:
        return os.path.join(self.dir, f"wal-{seq:08d}.log")

    def manifest_path(self, seq: int) -> str:
        return os.path.join(self.dir, f"manifest-{seq:08d}.json")

    # -- the write-ahead log ----------------------------------------------

    def append(self, rtype: str, payload: dict) -> None:
        """Frame and append one record.  MUST be called before the
        mutation is applied in memory — that ordering is the entire
        durability argument for the un-sealed tail."""
        with self._lock:
            if self._wal_f is None:
                raise WalError("durable log has no open WAL epoch")
            body = json.dumps(payload, separators=(",", ":")).encode()
            frame = _HDR.pack(_MAGIC, _REC_TYPES[rtype], len(body),
                              zlib.crc32(body)) + body
            action = self._fire(f"wal.append.{rtype}")
            if action == "torn":
                # simulated mid-append power cut: a partial frame lands
                # on disk, then the "process" dies.  Recovery must
                # truncate this tail, never replay it.
                self._wal_f.write(frame[: len(frame)
                                        - max(1, len(frame) // 3)])
                self._wal_f.flush()
                raise faults_lib.InjectedCrash(
                    f"torn record at wal.append.{rtype}")
            self._wal_f.write(frame)
            self._wal_f.flush()
            if self.sync:
                os.fsync(self._wal_f.fileno())

    # -- segment store -----------------------------------------------------

    def _next_seg_number(self) -> int:
        if self._seg_counter is None:
            mx = -1
            for name in os.listdir(self.segdir):
                if name.startswith("seg-") and name.endswith(".npz"):
                    try:
                        mx = max(mx, int(name[4:-4]))
                    except ValueError:
                        pass
            self._seg_counter = mx + 1
        n = self._seg_counter
        self._seg_counter += 1
        return n

    def persist_segment(self, seg, postings) -> str:
        """Write one sealed segment's raw per-term local postings (written
        exactly once, at segment creation, while the postings are in
        hand).  Pinned against pruning until a manifest references it."""
        with self._lock:
            if seg.file is not None:
                return seg.file
            name = f"seg-{self._next_seg_number():08d}.npz"
            path = os.path.join(self.segdir, name)
            tmp = path + ".tmp"
            arrs = {f"t{t}": np.asarray(p, dtype=np.int64)
                    for t, p in enumerate(postings)}
            with open(tmp, "wb") as fh:
                np.savez_compressed(
                    fh, _meta=np.asarray([seg.doc_base, seg.doc_hi],
                                         dtype=np.int64), **arrs)
                if self.sync:
                    fh.flush()
                    os.fsync(fh.fileno())
            os.rename(tmp, path)
            seg.file = name
            self._pinned.add(name)
            return name

    @staticmethod
    def load_segment_postings(path: str) -> list[np.ndarray]:
        with np.load(path) as z:
            n_terms = sum(1 for k in z.files if k != "_meta")
            return [np.asarray(z[f"t{t}"], dtype=np.int64)
                    for t in range(n_terms)]

    # -- atomic snapshots --------------------------------------------------

    def checkpoint(self, state: dict) -> int:
        """Commit one full-state snapshot and open a fresh WAL epoch.

        ``state`` carries: ``config`` (MutableIndex constructor args),
        ``segments`` (base/hi/file entries, every file already persisted),
        ``mseg_base``/``mseg_n_docs``/``mseg_postings`` (the un-sealed
        write buffer — snapshotting it is what lets rotation discard the
        old WAL without losing post-seal adds), ``dead_ids``,
        ``next_doc_id``, ``vocab``, ``counters``.

        Write order is the atomicity argument: mutable-segment image,
        tombstone list, then the manifest (tmp-then-rename each).  The
        manifest rename is the commit point; a crash anywhere before it
        leaves the previous manifest authoritative and every new file an
        ignorable orphan."""
        with self._lock:
            self._fire("snapshot.write")
            seq = self.seq + 1

            mseg_name = f"mseg-{seq:08d}.npz"
            buf_path = os.path.join(self.dir, mseg_name)
            tmp = buf_path + ".tmp"
            arrs = {f"t{t}": np.asarray(lst, dtype=np.int64)
                    for t, lst in state["mseg_postings"].items()}
            with open(tmp, "wb") as fh:
                np.savez_compressed(
                    fh, _meta=np.asarray([state["mseg_base"],
                                          state["mseg_n_docs"]],
                                         dtype=np.int64), **arrs)
                if self.sync:
                    fh.flush()
                    os.fsync(fh.fileno())
            os.rename(tmp, buf_path)

            dead_name = f"dead-{seq:08d}.npy"
            buf = __import__("io").BytesIO()
            np.save(buf, np.asarray(state["dead_ids"], dtype=np.int64))
            _atomic_write(os.path.join(self.dir, dead_name),
                          buf.getvalue(), self.sync)

            manifest = {
                "seq": seq,
                "wal": f"wal-{seq:08d}.log",
                "config": state["config"],
                "segments": state["segments"],
                "mseg": {"base": int(state["mseg_base"]),
                         "n_docs": int(state["mseg_n_docs"]),
                         "file": mseg_name},
                "dead": dead_name,
                "next_doc_id": int(state["next_doc_id"]),
                "vocab": int(state["vocab"]),
                "counters": {k: int(v)
                             for k, v in state["counters"].items()},
            }
            final = self.manifest_path(seq)
            tmp = final + ".tmp"
            with open(tmp, "w") as fh:
                json.dump(manifest, fh)
                if self.sync:
                    fh.flush()
                    os.fsync(fh.fileno())
            self._fire("snapshot.rename")
            os.rename(tmp, final)              # the commit point
            if self.sync:
                _fsync_dir(self.dir)

            self.seq = seq
            self._open_wal(seq)
            for ent in state["segments"]:
                self._pinned.discard(ent["file"])
            self._prune()
            return seq

    def _prune(self) -> None:
        seqs = manifest_seqs(self.dir)
        kept = set(seqs[-self.keep:])
        referenced: set[str] = set()
        seg_referenced: set[str] = set(self._pinned)
        for s in kept:
            try:
                with open(self.manifest_path(s)) as fh:
                    man = json.load(fh)
            except Exception:
                continue
            referenced.update((man["wal"], man["mseg"]["file"],
                               man["dead"], f"manifest-{s:08d}.json"))
            seg_referenced.update(e["file"] for e in man["segments"])
        referenced.add(f"wal-{self.seq:08d}.log")
        for name in os.listdir(self.dir):
            if name.endswith(".tmp") or (
                    name.startswith(("manifest-", "wal-", "mseg-", "dead-"))
                    and name not in referenced):
                try:
                    os.remove(os.path.join(self.dir, name))
                except OSError:
                    pass
        for name in os.listdir(self.segdir):
            if name.endswith(".tmp") or (name.startswith("seg-")
                                         and name not in seg_referenced):
                try:
                    os.remove(os.path.join(self.segdir, name))
                except OSError:
                    pass


# --------------------------------------------------------------------------
# recovery
# --------------------------------------------------------------------------

def manifest_seqs(directory: str) -> list[int]:
    out = []
    if os.path.isdir(directory):
        for name in os.listdir(directory):
            if name.startswith("manifest-") and name.endswith(".json"):
                try:
                    out.append(int(name[9:-5]))
                except ValueError:
                    pass
    return sorted(out)


def _wal_seqs(directory: str) -> list[int]:
    out = []
    for name in os.listdir(directory):
        if name.startswith("wal-") and name.endswith(".log"):
            try:
                out.append(int(name[4:-4]))
            except ValueError:
                pass
    return sorted(out)


def _load_manifest(directory: str, seq: int) -> dict:
    """Load and *validate* one manifest: every referenced file must exist
    (the manifest-last discipline makes that true for any renamed
    manifest, so a failure here means damage — fall back to the previous
    epoch, like ``CheckpointManager.restore``)."""
    with open(os.path.join(directory, f"manifest-{seq:08d}.json")) as fh:
        man = json.load(fh)
    for ent in man["segments"]:
        p = os.path.join(directory, "segments", ent["file"])
        if not os.path.exists(p):
            raise FileNotFoundError(p)
    for name in (man["mseg"]["file"], man["dead"]):
        p = os.path.join(directory, name)
        if not os.path.exists(p):
            raise FileNotFoundError(p)
    return man


def recover(directory: str, *, plan=None,
            injector: "faults_lib.FaultInjector | None" = None,
            sync: bool = False, keep: int = 2):
    """Rebuild a ``MutableIndex`` from a durable directory to a state
    byte-identical to the pre-crash index.

    Replay order: newest readable manifest → rebuild every sealed segment
    from its persisted raw postings (``builder.build`` is deterministic,
    so the rebuilt payloads serve identically) → restore the
    mutable-segment image, tombstones and counters → replay every WAL
    epoch with ``seq >= manifest.seq`` in order through the normal
    ``add``/``delete``/``seal`` paths (appends suppressed), truncating a
    torn tail → commit a fresh checkpoint so the next epoch starts from
    a compact snapshot."""
    from repro.index import segments as seg_lib

    seqs = manifest_seqs(directory)
    if not seqs:
        raise FileNotFoundError(f"no manifest in {directory}")
    man = None
    last_err: Exception | None = None
    for s in reversed(seqs):
        try:
            man = _load_manifest(directory, s)
            chosen = s
            break
        except Exception as e:             # damaged → previous epoch
            last_err = e
    if man is None:
        raise FileNotFoundError(
            f"no readable manifest in {directory}: {last_err}")

    cfg = dict(man["config"])
    mi = seg_lib.MutableIndex(plan=plan, **cfg)
    with mi._lock:
        segs = []
        for ent in man["segments"]:
            postings = DurableLog.load_segment_postings(
                os.path.join(directory, "segments", ent["file"]))
            seg = mi._build_segment(int(ent["base"]),
                                    int(ent["hi"]) - int(ent["base"]),
                                    postings)
            seg.file = ent["file"]
            segs.append(seg)

        mseg = seg_lib.MutableSegment(int(man["mseg"]["base"]))
        with np.load(os.path.join(directory, man["mseg"]["file"])) as z:
            for k in z.files:
                if k == "_meta":
                    continue
                a = z[k]
                if a.size:
                    mseg.postings[int(k[1:])] = [int(x) for x in a]
        mseg.n_docs = int(man["mseg"]["n_docs"])

        mi._vocab = int(man["vocab"])
        mi._next_id = int(man["next_doc_id"])
        mi._ensure_dead(mi._next_id + 1)
        dead = np.load(os.path.join(directory, man["dead"]))
        if dead.size:
            mi._dead[dead] = True
        mi._n_dead = int(dead.size)
        mi.n_seals = int(man["counters"]["n_seals"])
        mi.n_merges = int(man["counters"]["n_merges"])

        gen = mi._new_generation(segs, carry=None)
        mi._state = (gen, mseg)
        mi._gen_counter = max(mi._gen_counter,
                              int(man["counters"]["gen_counter"]))

    log = DurableLog(directory, sync=sync, injector=injector, keep=keep)
    mi._wal = log
    mi._wal_replaying = True
    n_replayed = 0
    try:
        for w in _wal_seqs(directory):
            if w < chosen:
                continue
            path = log.wal_path(w)
            records, good, torn = read_wal(path)
            if torn:
                with open(path, "r+b") as fh:   # truncate, never replay
                    fh.truncate(good)
            for rtype, payload in records:
                if rtype == "add":
                    mi.add(payload["terms"])
                elif rtype == "delete":
                    mi.delete(int(payload["doc"]))
                elif rtype == "seal":
                    mi.seal()
                n_replayed += 1
    finally:
        mi._wal_replaying = False

    all_seqs = set(manifest_seqs(directory)) | set(_wal_seqs(directory))
    log._attach(max(all_seqs))
    mi._wal_checkpoint()
    mi._wal_replayed = n_replayed
    return mi
