"""Sharded query fan-out: index parts on data-parallel devices
(DESIGN.md §2.5, §2.9).

The paper partitions posting lists into cache-sized doc-id ranges and
intersects per partition; at cluster scale those partitions *are* the unit
of data parallelism.  This module maps index parts 1:1 (contiguously, when
counts differ) onto shards, pins each shard's ``ResidentPool`` working set
to its own device, fans every query batch out to all shards, and
concatenates per-part hits in part order — byte-identical to the
single-device engine.

Execution model — shard along the batch axis, not the program:

  The batched scheduler's device programs are row-independent (every
  (query, part) work item is one row of a vmapped program; the only scanned
  axis is the fold axis J, which is not sharded).  So the sharded executor
  does NOT build new per-shard programs: it assembles each shard's rows on
  that shard's device, glues the slices into one global operand with
  ``jax.make_array_from_single_device_arrays`` under a plain
  ``NamedSharding(Mesh(devices, ('data',)), P('data', ...))``, and calls the
  *same* jitted group program the single-device path uses.  XLA's SPMD
  partitioner splits the row axis across devices with zero collectives —
  each device intersects exactly its shard's rows, concurrently.  Group
  keys, bucketing, and per-item math are untouched, which is what makes
  sharded == sequential a structural identity rather than a numerical
  accident (``tests/test_shard.py`` locks it in).

  AxisType constraint: meshes here are plain ``Mesh`` objects —
  ``jax.sharding.AxisType`` does not exist on the pinned jax 0.4.37, and
  nothing in this dataflow needs it (every axis is Auto).  The whole layer
  runs under ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` for
  tests/CI and on real device fleets unchanged.

  More shards than devices is allowed (shards fold onto devices
  contiguously, ``n_shards %% n_devices == 0``), which keeps the shard
  count a *logical* choice: the same 4-shard index serves on 1, 2, or 4
  devices, and the differential tests run on whatever the host offers.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.index import batch as batch_lib
from repro.index import pipeline as pipe_lib
from repro.index import source
from repro.index.builder import HybridIndex
from repro.index.engine import QueryResult


@dataclasses.dataclass
class PartPools:
    """Per-part pool routing: ``schedule`` resolves each (query, part) item
    through the pool of the shard that owns the part, so staged buffers land
    on (and are gathered from) the owning shard's device."""
    pools: list
    part_shard: list

    def for_part(self, pi: int):
        return self.pools[self.part_shard[pi]]


@dataclasses.dataclass
class ShardedIndex:
    """A HybridIndex plus its shard topology: part→shard map, shard→device
    placement, and one device-pinned ResidentPool per shard."""
    index: HybridIndex
    n_shards: int
    mesh: object                      # 1-D ('data',) Mesh, AxisType-free
    part_shard: list                  # part ordinal -> shard id (contiguous)
    placement: list                   # shard id -> jax Device
    pools: list                       # shard id -> source.ResidentPool

    @property
    def pool_map(self) -> PartPools:
        return PartPools(self.pools, self.part_shard)

    @property
    def devices(self) -> list:
        return list(self.mesh.devices.flat)

    def warm(self, stats: dict | None = None) -> dict:
        """Stage every shard's working set on its own device (build-time
        staging, per the resolve policy — skip-served lists stay packed)."""
        for sid, pool in enumerate(self.pools):
            parts = [p for p, s in zip(self.index.parts, self.part_shard)
                     if s == sid]
            view = HybridIndex(n_docs=self.index.n_docs, B=self.index.B,
                               codec_name=self.index.codec_name, parts=parts)
            pool.warm(view, stats)
        return self.stats()

    def stats(self) -> dict:
        """Placement-map accounting: which parts and how many resident ints
        live on which device, per shard."""
        shards = []
        for sid, pool in enumerate(self.pools):
            ps = pool.stats()
            shards.append({
                "shard": sid,
                "device": str(self.placement[sid]),
                "parts": [p for p, s in enumerate(self.part_shard)
                          if s == sid],
                **ps,
            })
        return {"n_shards": self.n_shards,
                "n_devices": len(self.devices),
                "shards": shards}


def shard_index(index: HybridIndex, n_shards: int, devices=None,
                capacity_ints: int = 1 << 26, warm: bool = True
                ) -> ShardedIndex:
    """Place an index's parts onto ``n_shards`` data-parallel shards.

    Parts map contiguously onto shards (1:1 when ``n_parts == n_shards``,
    the intended production shape); shards map contiguously onto the mesh
    devices.  With fewer devices than shards, consecutive shards share a
    device — the dataflow is identical, only the physical parallelism
    shrinks — so correctness never depends on the host's device count.
    """
    from repro.launch.mesh import make_index_mesh
    assert n_shards >= 1, n_shards
    if devices is None:
        ndev = len(jax.devices())
        # widest mesh that divides the shard count evenly
        width = max(d for d in range(1, min(n_shards, ndev) + 1)
                    if n_shards % d == 0)
        mesh = make_index_mesh(width)
    else:
        # explicit placement: mesh over exactly these devices, in order
        mesh = jax.sharding.Mesh(np.array(devices), ("data",))
    devs = list(mesh.devices.flat)
    assert n_shards % len(devs) == 0, (n_shards, len(devs))
    per_dev = n_shards // len(devs)
    placement = [devs[s // per_dev] for s in range(n_shards)]
    n_parts = len(index.parts)
    part_shard = [min(p * n_shards // max(n_parts, 1), n_shards - 1)
                  for p in range(n_parts)]
    pools = [source.ResidentPool(capacity_ints=capacity_ints, device=d)
             for d in placement]
    sharded = ShardedIndex(index=index, n_shards=n_shards, mesh=mesh,
                           part_shard=part_shard, placement=placement,
                           pools=pools)
    if warm:
        sharded.warm()
    return sharded


# --------------------------------------------------------------------------
# shard-axis glue
# --------------------------------------------------------------------------

def _spec(ndim: int, axis: int) -> P:
    return P(*(["data" if i == axis else None for i in range(ndim)]))


def _glue(sharded: ShardedIndex, slices: list, axis: int):
    """Glue per-shard device slices into one global array sharded along
    ``axis``.  Single-device meshes concatenate eagerly (everything already
    lives there); multi-device meshes zero-copy assemble the committed
    slices with ``make_array_from_single_device_arrays``."""
    devs = sharded.devices
    if len(devs) == 1:
        return jnp.concatenate(slices, axis=axis)
    per_dev = len(slices) // len(devs)
    dev_slices = [slices[d * per_dev] if per_dev == 1
                  else jnp.concatenate(
                      slices[d * per_dev: (d + 1) * per_dev], axis=axis)
                  for d in range(len(devs))]
    # commit stragglers (zero-row fold stacks are built uncommitted)
    dev_slices = [jax.device_put(s, d) for s, d in zip(dev_slices, devs)]
    shape = list(dev_slices[0].shape)
    shape[axis] *= len(devs)
    sharding = NamedSharding(sharded.mesh, _spec(len(shape), axis))
    return jax.make_array_from_single_device_arrays(
        tuple(shape), sharding, dev_slices)


def _put_host(sharded: ShardedIndex, arr: np.ndarray, axis: int):
    """Upload one host-side operand (active masks, candidate block ids)
    sharded along ``axis`` — each device receives only its slice."""
    if len(sharded.devices) == 1:
        return jnp.asarray(arr)
    sharding = NamedSharding(sharded.mesh, _spec(arr.ndim, axis))
    return jax.device_put(arr, sharding)


# --------------------------------------------------------------------------
# sharded launch (the fan-out) — collect is batch_lib.collect_batch
# --------------------------------------------------------------------------

def _flat_items(per_shard: list, Bq: int) -> list:
    """Collect-order item list of one sharded chunk: shard-contiguous rows,
    None in the per-shard padding slots (skipped by ``collect_batch``)."""
    return [it for sub in per_shard
            for it in list(sub) + [None] * (Bq - len(sub))]


def _launch_svs_sharded(sharded: ShardedIndex, key, per_shard: list,
                        backend: str, stats: dict | None):
    """One device program covering all shards' items of one group chunk:
    rows are laid out shard-contiguously ((shard, slot) flattened), operands
    assembled per shard on the owning device and glued along the row axis.
    Fused megagroup keys pin the arity ceilings (batch.fuse_groups), so
    every shard's slice assembles at the same fused shapes.  Returns
    (flat item list with None pads, vals, counts)."""
    S = sharded.n_shards
    all_items = [it for sub in per_shard for it in sub]
    Bq = batch_lib._bucket_rows(max(len(sub) for sub in per_shard))
    if key.fused:
        J, Jb, Jp = key.fused
    else:
        J = max((len(it.folds) for it in all_items), default=0)
        Jb = max((batch_lib._n_bitmaps(it) for it in all_items), default=0)
        Jp = (max((len(it.psrc) for it in all_items), default=0)
              if key.packed is not None else 0)
    Rs, Fs, As, Pk, Ws = [], [], [], [], []
    for sid in range(S):
        R, F, act, pkparts, W, _, _, _ = batch_lib._assemble_svs(
            key, per_shard[sid], sharded.pools[sid],
            bp=Bq, j=J, jb=Jb, jp=Jp)
        Rs.append(R)
        Fs.append(F)
        As.append(act)
        Pk.append(pkparts)
        Ws.append(W)
    R = _glue(sharded, Rs, axis=0)                      # (S·Bq, M)
    F = _glue(sharded, Fs, axis=1)                      # (J, S·Bq, N)
    active = _put_host(sharded, np.concatenate(As, axis=1), axis=1)
    pk = pk_active = None
    mode, rows = "d1", 32
    if key.packed is not None:
        rows, mode = key.packed[4], key.packed[5]
        # actual partial-decode volume at the launching key's c_pad (see
        # batch._launch_svs_group — fusion may have raised the bucket)
        source._bump(stats, "decoded_ints",
                     sum(len(it.psrc) for it in all_items)
                     * key.packed[2] * rows * 128)
        stacked = [_glue(sharded, [p[0][o] for p in Pk], axis=1)
                   for o in range(6)]
        PBk = _put_host(sharded,
                        np.concatenate([p[1] for p in Pk], axis=1), axis=1)
        pk = batch_lib._compose_pk(stacked, PBk)
        pk_active = _put_host(
            sharded, np.concatenate([p[2] for p in Pk], axis=1), axis=1)
    W = _glue(sharded, Ws, axis=1) if Jb else None      # (Jb, S·Bq, W)
    if stats is not None:
        stats.setdefault("signatures", set()).add(
            ("svs-sharded", key, S, Bq, J, Jb))
    # same interpret-mode occupancy guard as batch._launch_svs_group, at
    # the sharded grid's S·Bq batch rows
    backend = batch_lib._effective_backend(key, all_items, backend, stats,
                                           bp=S * Bq)
    vals, counts = batch_lib._svs_program(
        R, F, active, pk, pk_active, W, key.algo, backend, mode, rows)
    return _flat_items(per_shard, Bq), vals, counts


def _launch_bitmap_sharded(sharded: ShardedIndex, key, per_shard: list,
                           stats: dict | None):
    S = sharded.n_shards
    all_items = [it for sub in per_shard for it in sub]
    Bq = batch_lib._bucket_rows(max(len(sub) for sub in per_shard))
    J = (key.fused[0] if key.fused else
         max((batch_lib._n_bitmaps(it) for it in all_items), default=1))
    Ws = [batch_lib._assemble_bitmap(key, per_shard[sid],
                                     sharded.pools[sid], bp=Bq, j=J)[0]
          for sid in range(S)]
    words = _glue(sharded, Ws, axis=0)                  # (S·Bq, J, W)
    if stats is not None:
        stats.setdefault("signatures", set()).add(
            ("bm-sharded", key, S, Bq, J))
    vals, counts = batch_lib._bitmap_and_program(words)
    return _flat_items(per_shard, Bq), vals, counts


def launch_groups_sharded(sharded: ShardedIndex, groups, *, n_queries: int,
                          backend: str = "jax", max_results: int = 1 << 16,
                          max_group_size: int = batch_lib.MAX_GROUP_SIZE,
                          stats: dict | None = None, timings=None
                          ) -> batch_lib.PendingBatch:
    """Dispatch every group chunk as one SPMD program across the shard
    devices, without materializing results (the fan-out half; the existing
    ``batch.collect_batch`` is the concatenate half — item part ordinals
    order per-query results exactly as the single-device engine does).
    With fused megagroups the per-batch dispatch collapse multiplies by
    the shard count: one program per family covers *all* shards' rows.
    ``timings`` splits per-shard assembly + glue from the program enqueue
    (same contract as ``batch.launch_groups``)."""
    launched = []
    n_dispatches = 0
    c0 = batch_lib._compile_count() if stats is not None else 0
    for key, items in groups.items():
        per = [[] for _ in range(sharded.n_shards)]
        for it in items:
            per[sharded.part_shard[it.pi]].append(it)
        # lockstep chunking: the int budget bounds *per-device* operand
        # rows, so chunk by the widest shard's slice
        step = batch_lib._chunk_size(key, items, max_group_size)
        width = max(len(sub) for sub in per)
        for lo in range(0, max(width, 1), step):
            sub = [s[lo: lo + step] for s in per]
            t0 = time.perf_counter()
            if key.kind == "bitmap":
                flat, vals, counts = _launch_bitmap_sharded(
                    sharded, key, sub, stats)
            else:
                flat, vals, counts = _launch_svs_sharded(
                    sharded, key, sub, backend, stats)
            if timings is not None:
                # the sharded launchers interleave assembly and the single
                # program call; attribute the whole span to assemble+glue
                # and let `block` absorb device time, as §2.9 documents
                timings.assemble += time.perf_counter() - t0
            launched.append((key, flat, vals, counts))
            n_dispatches += 1
    batch_lib.accumulate_launch_stats(stats, groups, n_dispatches)
    if stats is not None:
        stats["n_compiles"] = (stats.get("n_compiles", 0)
                               + batch_lib._compile_count() - c0)
    return batch_lib.PendingBatch(n_queries=n_queries,
                                  max_results=max_results,
                                  launched=launched, stats=stats)


def execute_sharded(sharded: ShardedIndex, queries: list, *,
                    batch_size: int = 32, depth: int = 2,
                    backend: str = "jax", max_results: int = 1 << 16,
                    max_group_size: int = batch_lib.MAX_GROUP_SIZE,
                    fuse: bool = True,
                    plan: "batch_lib.FusionPlan | None" = None,
                    stats: dict | None = None,
                    timings: "pipe_lib.StageTimings | None" = None
                    ) -> list[QueryResult]:
    """Answer ``queries`` against the sharded index, pipelined at ``depth``
    (DESIGN.md §2.9): every batch fans out to all shards in one dispatch
    and results concatenate in part order — byte-identical to
    ``engine.query`` / ``batch.execute_batch`` on the unsharded index.
    ``fuse``/``plan`` coarsen each batch into megagroup families before
    the fan-out (DESIGN.md §2.10), so the per-batch dispatch count is
    O(#families) regardless of shard count."""
    pool_map = sharded.pool_map
    if fuse and plan is None:
        plan = batch_lib.FusionPlan()

    def schedule_fn(chunk, stats):
        groups = batch_lib.schedule(sharded.index, chunk, pool=pool_map,
                                    stats=stats)
        if fuse:
            groups = batch_lib.fuse_groups(groups, plan=plan, stats=stats)
        return groups

    def launch_fn(groups, n_queries, stats):
        return launch_groups_sharded(
            sharded, groups, n_queries=n_queries, backend=backend,
            max_results=max_results, max_group_size=max_group_size,
            stats=stats, timings=timings)

    return pipe_lib.execute_pipelined(
        sharded.index, queries, batch_size=batch_size, depth=depth,
        max_results=max_results, stats=stats, timings=timings,
        schedule_fn=schedule_fn, launch_fn=launch_fn)
