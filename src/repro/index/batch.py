"""Batched query execution: shape-bucketed scheduling + device-side SvS.

The sequential engine (``repro.index.engine``) answers one query at a time
and bounces candidates to host between every SvS fold — exactly the dispatch
overhead the paper warns fast decoders drown in.  This module keeps whole
query *batches* inside the vectorized regime:

  1. **Schedule.** Every (query, index-part) work item is assigned a shape
     signature: (pow2 bucket of the shortest list M, pow2 bucket of the
     longest fold list N, bitmap word count, intersect algorithm, packed
     signature).  Fold terms resolve through the posting-source layer
     (``repro.index.source``): short lists decode (and cache), long
     skip-capable lists stay *packed* and carry a batch-uniform layout —
     words/widths/offsets/maxes buckets plus the host-precomputed candidate
     block ids — so compressed long lists are never fully decoded in the
     batch regime either.  Term counts are *not* part of the signature —
     queries of different arity merge into one program, padded to the
     group's max fold/probe count with masked no-op folds and all-ones
     bitmap rows (probe identities) — and the batch dimension is bucketed
     on a ×1.5 ladder, so the compile count stays O(log² n_docs · log B)
     overall.
  2. **Execute.** Each group runs as a *single* device program: the batch of
     shortest lists (B, M) is intersected with the stacked decoded fold
     lists (J, B, N) by a ``lax.scan`` whose body is a vmapped intersect +
     compact, then with the stacked *packed* folds (tuple of (Jp, B, ...)
     layout arrays, each step a skip-aware partial decode of candidate
     blocks only), then the surviving candidates are probed against the
     stacked bitmap terms (J_b, B, W) — candidates never round-trip to host
     between terms.  Fold order is decoded-then-packed, which is safe
     because set intersection commutes and the candidate buffer stays
     sorted under ``compact``.  All-bitmap queries reduce to a batched AND
     + popcount.  Without a pool, stacking happens host-side in numpy (one
     device transfer per operand); with a ``source.ResidentPool`` the
     operands are device-resident and each one assembles as a single
     row-arena gather — no decode, no padding memcpy, no H2D transfer,
     and no per-row dispatch cost (DESIGN.md §2.8).
  3. **Aggregate.** Per-item results are re-assembled per query in index-part
     order, matching the sequential engine byte for byte.

This module is DESIGN.md §2.7 (scheduler + group-key scheme); §2.8 covers
the resident/pipelined serving built on it and §2.9 the sharded fan-out.
Invariants callers rely on:

  * **Group-signature stability** — ``GroupKey`` describes operand
    *shapes* only (pow2 buckets, block geometry, algorithm).  Residency,
    arenas, caches, and sharding change where a row lives or which device
    computes it, never its shape, so every serving mode compiles the same
    per-signature programs and the compile count stays bounded.  The
    sharded executor additionally relies on group programs being
    row-independent (the only scanned axis is the fold axis), which is
    what lets it split the row axis across devices unchanged.
  * **Byte-identical aggregation** — per-query results concatenate in
    part order (items carry their part ordinal; ``collect_batch`` sorts
    by it), preserving global doc-id sortedness, so batched ==
    pipelined == sharded == sequential, element for element.
  * **Padding is inert** — padded batch rows, masked no-op folds,
    identity bitmap rows, and all-pad packed layouts never contribute to
    any active row's result.

Launch and collect are split (``launch_groups`` dispatches every group
program and returns a ``PendingBatch`` of un-materialized device results;
``collect_batch`` blocks and aggregates) so ``repro.index.pipeline`` can
overlap host scheduling of batch k+1 with device execution of batch k.
``execute_batch`` composes the two and is byte-identical to the sequential
engine.  On non-CPU backends the candidate buffer is donated to the device
program — it is freshly assembled per dispatch and never reused, so XLA can
reuse its pages for the output.

Algorithm choice: under ``vmap`` the tiled merge runs lock-step across the
batch — the slowest row sets the step count and its data-dependent early
exit is lost — so the batched dispatcher biases much harder toward galloping
than the sequential ratio rule (``BATCH_TILED_MAX_RATIO`` vs the paper's
50×; re-derived in ``benchmarks/bench_engine.py``).

Backends: ``backend="jax"`` uses the jnp searchsorted / tile-merge paths from
``core.intersect``; ``backend="pallas"`` routes every fold through the Pallas
galloping kernel (``kernels.ops.intersect_gallop_batch``).
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from functools import lru_cache, partial

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.core import bitmap as bm
from repro.core import codecs as codec_lib
from repro.core import intersect as its
from repro.index import source
from repro.index.builder import HybridIndex
from repro.index.engine import QueryResult

MAX_GROUP_SIZE = 128          # hard cap on items per device program
GROUP_INT_BUDGET = 1 << 25    # cap operand ints per program: B·(J·N+M+J_b·W)
BATCH_TILED_MAX_RATIO = 4.0   # vmapped tile-merge loses early exit; see above

# Donating the candidate buffer lets XLA alias its pages for the output; it
# is always freshly stacked per dispatch so nothing aliases it on the host.
# CPU has no donation support (XLA warns and ignores), so gate it.
_DONATE_CANDIDATES = (0,) if jax.default_backend() != "cpu" else ()


@dataclasses.dataclass(frozen=True)
class GroupKey:
    """Shape signature shared by all work items of one device program.
    Term counts are deliberately NOT part of the key: queries of different
    arity merge into one program, padded to the group's max fold/probe count
    with masked no-op folds and all-ones bitmap rows (probe identities).
    Packed folds replace the fold-length bucket with their block-layout
    buckets: (k_pad blocks, t_pad word rows, c_pad candidate blocks,
    e_pad exceptions, block_rows, delta mode)."""
    kind: str              # 'svs' (≥1 list term) | 'bitmap' (all-bitmap)
    m_bucket: int          # candidate buffer length M
    n_bucket: int          # decoded fold-list pad length N
    words: int             # bitmap word count W (0 when no bitmaps)
    algo: str              # 'tiled' | 'gallop' | '-'
    packed: tuple | None = None   # (k_pad, t_pad, c_pad, e_pad, rows, mode)


@dataclasses.dataclass
class _Item:
    qi: int                # query index within the submitted batch
    pi: int                # index-part ordinal (aggregation order)
    doc_lo: int
    r: object = None                      # (M,) seed: np (host) | jnp (pool)
    folds: list | None = None             # host: J × (N,) np
                                          # pool: J × DecodedSource
    psrc: list | None = None              # Jp × (layout, blk_p) — layout is
                                          # the self-padded np PackedLayout
                                          # (host) or the PackedSource
                                          # itself (pool; arena-assembled)
    bm_words: np.ndarray | None = None    # host: (J_b, W) bitmap word rows
    bm_dev: list | None = None            # pool: J_b × (W,) resident rows
    bm_keys: list | None = None           # pool: J_b × pool keys (arenas)
    rsrc: object = None                   # pool: seed DecodedSource


@lru_cache(maxsize=None)
def _stacker(n: int):
    """Jitted n-ary row stack.  ``jnp.stack`` on a list dispatches one
    eager expand_dims per row — ~45µs each on a host backend, which made
    operand assembly the dominant serving cost; a jitted stacker is one
    dispatch for the whole stack (~8× cheaper at 128 rows).  Memoized per
    arity; jit itself re-specializes per row shape/dtype, and with inputs
    committed to one device the stack runs (and its result stays) there —
    which is what keeps per-shard slices on their own devices."""
    return jax.jit(lambda *xs: jnp.stack(xs))


def _stack_rows(rows: list) -> jnp.ndarray:
    return _stacker(len(rows))(*rows)


def _bucket_rows(b: int) -> int:
    """Batch-dim bucket: ~×1.5 geometric ladder (1,2,3,4,6,9,13,19,28,…).
    Bounds the compile count per signature at O(log B) while wasting at
    most 1/3 of rows on padding (a pow2 ladder wastes up to 2×, which
    shows up directly as lost throughput on small groups)."""
    size = 1
    while size < b:
        size = size * 3 // 2 if size >= 2 else size + 1
    return size


def _extend_np(vals: np.ndarray, size: int) -> np.ndarray:
    return vals if vals.shape[0] == size else its.pad_to(vals, size)


def schedule(index: HybridIndex, queries: list[list[int]], cache=None,
             skip: bool = True, stats: dict | None = None,
             pool: "source.ResidentPool | None" = None
             ) -> dict[GroupKey, list[_Item]]:
    """Bucket every (query, part) work item by shape signature.  Terms
    resolve through the posting-source layer here (host side, optionally
    cached): short lists decode, long skip-capable lists keep their packed
    layout plus host-searched candidate block ids.  With a ResidentPool the
    items carry *references to resident device buffers*; without one they
    carry host numpy arrays.  Everything downstream of this point is device
    programs over stacked operands."""
    codec = codec_lib.get_codec(index.codec_name)
    # sharded serving hands in one device-pinned pool per part (an object
    # with .for_part); plain serving hands in a single pool or None
    pool_of = (pool.for_part if hasattr(pool, "for_part")
               else (lambda pi: pool))
    groups: dict[GroupKey, list[_Item]] = defaultdict(list)
    for qi, term_ids in enumerate(queries):
        for pi, part in enumerate(index.parts):
            pool = pool_of(pi)
            tps = [part.terms[t] for t in term_ids]
            if any(tp.kind == "empty" for tp in tps):
                continue
            pairs = [(t, tp) for t, tp in zip(term_ids, tps)
                     if tp.kind == "list"]
            pairs.sort(key=lambda p: p[1].n)
            bm_pairs = [(t, tp) for t, tp in zip(term_ids, tps)
                        if tp.kind == "bitmap"]
            W = len(bm_pairs[0][1].payload) if bm_pairs else 0
            bm_words = bm_dev = bm_keys = None
            if bm_pairs:
                if pool is not None:
                    # (key, host row) pairs: the arena assembler must not
                    # depend on store residency (tiny pools evict between
                    # schedule and assembly)
                    bm_keys = [(("bm", part.uid, t), np.asarray(tp.payload))
                               for t, tp in bm_pairs]
                    bm_dev = [pool.stage_bitmap(k, w) for k, w in bm_keys]
                else:
                    bm_words = np.stack([tp.payload for _, tp in bm_pairs])
            if not pairs:
                key = GroupKey("bitmap", 0, 0, W, "-")
                groups[key].append(_Item(qi, pi, part.doc_lo,
                                         bm_words=bm_words, bm_dev=bm_dev,
                                         bm_keys=bm_keys))
                continue
            seed_t, seed_tp = pairs[0]
            seed = source.resolve(part, seed_t, seed_tp, codec, cache=cache,
                                  r_count=None, stats=stats, pool=pool)
            seed_np = (seed.vals_np if seed.vals_np is not None
                       else np.asarray(seed.vals))
            M = seed_np.shape[0]
            dec, packed = [], []
            for t, tp in pairs[1:]:
                src = source.resolve(part, t, tp, codec, cache=cache,
                                     r_count=seed_tp.n, skip=skip,
                                     stats=stats, pool=pool)
                if isinstance(src, source.PackedSource):
                    packed.append((t, tp, src))
                else:
                    dec.append(src)
            psig, psrc = None, None
            if packed:
                # stacking along the fold axis needs one block geometry:
                # keep the longest fold's (block_rows, mode), decode the
                # rare mismatch (adaptive block sizing on mid-length lists)
                ref = max(packed, key=lambda p: p[2].n)[2]
                rows, mode = ref.block_rows, ref.mode
                keep, demote = [], []
                for p in packed:
                    same = (p[2].block_rows == rows and p[2].mode == mode)
                    (keep if same else demote).append(p)
                for t, tp, _ in demote:
                    # cache=None / pool=None: a demoted long list must not
                    # evict the int-budgeted stores' hot short lists — and
                    # staging it resident would permanently win over
                    # want_skip, disabling its block-max skip path over a
                    # one-off grouping accident
                    src = source.resolve(part, t, tp, codec, cache=None,
                                         skip=False, stats=stats, pool=None)
                    dec.append(src)
                r_valid = seed_np[: seed.n]
                cand = [(s, s.candidate_block_ids(r_valid))
                        for _, _, s in keep]
                k_pad = max(s.self_pads()[0] for s, _ in cand)
                t_pad = max(s.self_pads()[1] for s, _ in cand)
                c_pad = max(its.pow2_bucket(len(b), floor=source.CAND_FLOOR)
                            for _, b in cand)
                e_max = max(s.num_exceptions for s, _ in cand)
                e_pad = its.pow2_bucket(e_max, floor=1) if e_max else 0
                psig = (k_pad, t_pad, c_pad, e_pad, rows, mode)
                if pool is not None:
                    # keep the PackedSource itself: the arena assembler
                    # materializes its group-padded layout rows on demand
                    # (memoized host-side, one device matrix per operand)
                    psrc = [(s, source.pad_block_ids(b, c_pad, k_pad))
                            for s, b in cand]
                else:
                    # memoized at the payload's own pads; the stacker
                    # zero-extends into the group slot (no per-group re-pad)
                    psrc = [(source.cached_layout_np(s, s.self_pads(), stats),
                             source.pad_block_ids(b, c_pad, k_pad))
                            for s, b in cand]
                source._bump(stats, "skip_folds", len(psrc))
                source._bump(stats, "decoded_ints",
                             len(psrc) * c_pad * rows * 128)
            N = max((s.vals.shape[0] for s in dec), default=128)
            if pool is not None:
                r_op = seed.vals
                folds = dec                          # padded at stack time
            else:
                r_op = seed_np
                folds = [_extend_np(s.vals_np if s.vals_np is not None
                                    else np.asarray(s.vals), N) for s in dec]
            algo = ("tiled" if N / M <= BATCH_TILED_MAX_RATIO else "gallop")
            key = GroupKey("svs", M, N, W, algo, psig)
            groups[key].append(_Item(qi, pi, part.doc_lo, r=r_op,
                                     rsrc=seed if pool is not None else None,
                                     folds=folds, psrc=psrc,
                                     bm_words=bm_words, bm_dev=bm_dev,
                                     bm_keys=bm_keys))
    return groups


# --------------------------------------------------------------------------
# device programs (one dispatch per GroupKey chunk)
# --------------------------------------------------------------------------

def _fold_pallas(r, folds, fold_active):
    """Pallas-backend fold: every step gallops through the TPU kernel;
    rows with an inactive slot pass through the step unchanged."""
    from repro.kernels import ops as kernel_ops
    return its.masked_svs_scan(r, folds, fold_active,
                               kernel_ops.intersect_gallop_batch)


def _probe_scan(r, words):
    """Probe candidates (B, M) against stacked bitmap terms (J_b, B, W)."""
    def step(rr, w):
        mask = jax.vmap(bm.probe)(w, rr, rr != its.SENTINEL)
        rr, _ = its.compact_batch(rr, mask)
        return rr, None

    r, _ = lax.scan(step, r, words)
    return r, its.count_valid(r)


@partial(jax.jit, static_argnames=("algo", "backend", "mode", "block_rows"),
         donate_argnums=_DONATE_CANDIDATES)
def _svs_program(r, folds, fold_active, pk, pk_active, words, algo: str,
                 backend: str, mode: str, block_rows: int):
    """One device program per group chunk: decoded folds → packed folds →
    bitmap probes, candidates staying on device throughout.  ``pk`` is the
    tuple of stacked batch-uniform packed operands (or None); ``words`` the
    stacked bitmap rows (or None).  ``r`` is donated off-CPU (see module
    docstring)."""
    if folds.shape[0]:
        if backend == "pallas":
            r, _ = _fold_pallas(r, folds, fold_active)
        else:
            r, _ = its.svs_fold_batch(r, folds, algo=algo,
                                      fold_active=fold_active)
    if pk is not None:
        if backend == "pallas":
            from repro.kernels import ops as kernel_ops
            packed_fn = kernel_ops.intersect_packed_batch
        else:
            packed_fn = its.intersect_packed_batch
        r, _ = its.masked_svs_scan(
            r, pk, pk_active,
            lambda rr, op: packed_fn(rr, *op, mode=mode,
                                     block_rows=block_rows))
    if words is not None:
        r, _ = _probe_scan(r, words)
    return r, its.count_valid(r)


@jax.jit
def _bitmap_and_program(words):
    """All-bitmap queries: AND-reduce (B, J, W) word stacks + popcount."""
    out = words[:, 0]
    for j in range(1, words.shape[1]):
        out = out & words[:, j]
    counts = jnp.sum(lax.population_count(out).astype(jnp.int32), axis=-1)
    return out, counts


def _stack_packed(key: GroupKey, items: list[_Item], Bp: int,
                  jp: int | None = None):
    """Stack the per-item packed layouts into uniform (Jp, Bp, ...) numpy
    operands.  Layouts arrive self-padded (the memoized projection); each
    slot zero-extends into the group buckets — pad blocks have width 0 and
    in-bounds offsets, and block ids beyond the real count never appear in
    the candidate list, so the extension is never decoded.  Inactive (j, b)
    slots keep all-pad block ids (→ all-SENTINEL decode) and are
    additionally masked by the active flags.  Returns (six host operand
    stacks, candidate block ids, active) — callers compose/upload."""
    k_pad, t_pad, c_pad, e_pad, rows, _ = key.packed
    Jp = (max((len(it.psrc) for it in items), default=0)
          if jp is None else jp)
    PW = np.zeros((Jp, Bp, t_pad, 128), np.uint32)
    PWid = np.zeros((Jp, Bp, k_pad), np.int32)
    POf = np.zeros((Jp, Bp, k_pad), np.int32)
    PMx = np.zeros((Jp, Bp, k_pad), np.uint32)
    PBk = np.full((Jp, Bp, c_pad), k_pad, np.int32)
    PEp = np.full((Jp, Bp, e_pad), -1, np.int32)
    PEa = np.zeros((Jp, Bp, e_pad), np.uint32)
    active = np.zeros((Jp, Bp), bool)
    for b, it in enumerate(items):
        for j, (lay, blk_p) in enumerate(it.psrc):
            K, T, E = (lay.widths.shape[0], lay.words.shape[0],
                       lay.exc_pos.shape[0])
            PW[j, b, :T] = lay.words
            PWid[j, b, :K] = lay.widths
            POf[j, b, :K] = lay.offsets
            PMx[j, b, :K] = lay.maxes
            PBk[j, b] = blk_p
            if e_pad and E:
                PEp[j, b, :E] = lay.exc_pos
                PEa[j, b, :E] = lay.exc_add
            active[j, b] = True
    return [PW, PWid, POf, PMx, PEp, PEa], PBk, active


# Arena gather: 2 arguments per assembled operand regardless of row count
# (the whole point of RowArena — see source.py); executes on the arena
# buffer's device, so per-shard slices stay on their shard's device.
_GATHER = jax.jit(lambda buf, idx: buf[idx])


def _stack_packed_arena(key: GroupKey, items: list[_Item], Bp: int,
                        pool: "source.ResidentPool",
                        jp: int | None = None):
    """Pool-mode packed stacking: gather each of the six layout operands
    from its RowArena with one (Jp·Bp,) index vector — slot 0 is the
    all-pad layout, so inactive grid positions decode to SENTINEL exactly
    like the host-stacked path.  Only the per-query candidate block ids
    cross to the device.  Returns (six device operand stacks, candidate
    block ids, active)."""
    k_pad, t_pad, c_pad, e_pad, rows, _ = key.packed
    pads = (k_pad, t_pad, e_pad)
    Jp = (max((len(it.psrc) for it in items), default=0)
          if jp is None else jp)
    arenas = [pool.layout_arena(pads, o) for o in range(6)]
    idx = np.zeros((Jp, Bp), np.int32)          # 0 = all-pad layout slot
    PBk = np.full((Jp, Bp, c_pad), k_pad, np.int32)
    active = np.zeros((Jp, Bp), bool)
    for b, it in enumerate(items):
        for j, (src, blk_p) in enumerate(it.psrc):
            slot = arenas[0].slots.get(src.key)
            if slot is None:
                lay = source.cached_layout_np(src, pads)
                ops = (lay.words, lay.widths, lay.offsets, lay.maxes,
                       lay.exc_pos, lay.exc_add)
                for a, row in zip(arenas, ops):
                    slot = a.slot(src.key, lambda r=row: np.asarray(r))
            idx[j, b] = slot
            PBk[j, b] = blk_p
            active[j, b] = True
    gidx = jnp.asarray(idx.reshape(-1))
    stacked = [_GATHER(a.buffer(), gidx).reshape(
                   (Jp, Bp) + a.rows_np[0].shape)
               for a in arenas]
    return stacked, PBk, active


def _compose_pk(stacked, PBk):
    """Order the packed program operand tuple from six stacked arrays +
    candidate block ids (device or host; the jit call uploads host parts)."""
    return (stacked[0], stacked[1], stacked[2], stacked[3],
            jnp.asarray(PBk), stacked[4], stacked[5])


def _n_bitmaps(it: _Item) -> int:
    return (it.bm_words.shape[0] if it.bm_words is not None
            else len(it.bm_dev) if it.bm_dev is not None else 0)


def _arena_ok(items: list[_Item]) -> bool:
    """Arena assembly needs a host copy + identity key for every value row;
    cache-hit sources carry neither (their numpy copy was dropped at cache
    fill), so groups containing them fall back to the row-stack path."""
    for it in items:
        if it.rsrc is None or it.rsrc.vals_np is None or not it.rsrc.key:
            return False
        for f in it.folds:
            if f.vals_np is None or not f.key:
                return False
    return True


def _assemble_svs(key: GroupKey, items: list[_Item],
                  pool: "source.ResidentPool | None", *,
                  bp: int | None = None, j: int | None = None,
                  jb: int | None = None, jp: int | None = None):
    """Build the operands of one svs group chunk.  Host mode stacks numpy
    and pays one H2D per operand; pool mode gathers resident rows (committed
    to the pool's device).  ``bp``/``j``/``jb``/``jp`` override the
    chunk-derived paddings so the sharded executor can assemble uniform
    per-shard slices (``repro.index.shard``); None derives them from the
    items — the single-device path, unchanged."""
    B = len(items)
    Bp = _bucket_rows(B) if bp is None else bp
    J = (max((len(it.folds) for it in items), default=0)
         if j is None else j)
    Jb = (max((_n_bitmaps(it) for it in items), default=0)
          if jb is None else jb)
    active = np.zeros((J, Bp), dtype=bool)
    if pool is not None and _arena_ok(items):
        # arena fast path: one gather per operand (DESIGN.md §2.8/§2.9)
        fa_m = pool.fold_arena(key.m_bucket)
        ridx = np.zeros(Bp, np.int32)               # 0 = sentinel row
        for b, it in enumerate(items):
            ridx[b] = fa_m.slot(
                it.rsrc.key,
                lambda s=it.rsrc: _extend_np(s.vals_np, key.m_bucket))
        R = _GATHER(fa_m.buffer(), jnp.asarray(ridx))
        if J:
            fa_n = pool.fold_arena(key.n_bucket)
            fidx = np.zeros((J, Bp), np.int32)
            for b, it in enumerate(items):
                for jj, f in enumerate(it.folds):
                    fidx[jj, b] = fa_n.slot(
                        f.key,
                        lambda s=f: _extend_np(s.vals_np, key.n_bucket))
                    active[jj, b] = True
            F = _GATHER(fa_n.buffer(),
                        jnp.asarray(fidx.reshape(-1))
                        ).reshape(J, Bp, key.n_bucket)
        else:
            F = jnp.zeros((0, Bp, key.n_bucket), jnp.int32)
        W = None
        if Jb:
            wa = pool.bitmap_arena(key.words)
            widx = np.zeros((Jb, Bp), np.int32)     # 0 = probe identity
            for b, it in enumerate(items):
                for jj, (bk, wnp) in enumerate(it.bm_keys or ()):
                    widx[jj, b] = wa.slot(bk, lambda w=wnp: w)
            W = _GATHER(wa.buffer(),
                        jnp.asarray(widx.reshape(-1))
                        ).reshape(Jb, Bp, key.words)
    elif pool is not None:
        R = _stack_rows([it.r for it in items]
                        + [pool.sentinel_row(key.m_bucket)] * (Bp - B))
        rows = []
        for j in range(J):
            for b in range(Bp):
                it = items[b] if b < B else None
                if it is not None and j < len(it.folds):
                    rows.append(pool.padded(it.folds[j], key.n_bucket))
                    active[j, b] = True
                else:
                    rows.append(pool.sentinel_row(key.n_bucket))
        F = (_stack_rows(rows).reshape(J, Bp, key.n_bucket) if J
             else jnp.zeros((0, Bp, key.n_bucket), jnp.int32))
        W = None
        if Jb:
            wrows = []
            for j in range(Jb):
                for b in range(Bp):
                    it = items[b] if b < B else None
                    if it is not None and it.bm_dev and j < len(it.bm_dev):
                        wrows.append(it.bm_dev[j])
                    else:
                        # inactive slots are all-ones — the probe identity
                        wrows.append(pool.ones_row(key.words))
            W = _stack_rows(wrows).reshape(Jb, Bp, key.words)
    else:
        Rnp = np.full((Bp, key.m_bucket), its.SENTINEL, dtype=np.int32)
        for b, it in enumerate(items):
            Rnp[b] = it.r
        R = jnp.asarray(Rnp)                                    # (Bp, M)
        F = np.full((J, Bp, key.n_bucket), its.SENTINEL, dtype=np.int32)
        for b, it in enumerate(items):
            for j, fold in enumerate(it.folds):
                F[j, b] = fold
                active[j, b] = True
        F = jnp.asarray(F)                                      # (J, Bp, N)
        W = None
        if Jb:
            # inactive slots are all-ones rows — the probe identity
            Wnp = np.full((Jb, Bp, key.words), 0xFFFFFFFF, dtype=np.uint32)
            for b, it in enumerate(items):
                if it.bm_words is not None:
                    for j in range(it.bm_words.shape[0]):
                        Wnp[j, b] = it.bm_words[j]
            W = jnp.asarray(Wnp)
    pkparts = None
    if key.packed is not None:
        if pool is not None:
            pkparts = _stack_packed_arena(key, items, Bp, pool, jp=jp)
        else:
            pkparts = _stack_packed(key, items, Bp, jp=jp)
    return R, F, active, pkparts, W, Bp, J, Jb


def _launch_svs_group(key: GroupKey, items: list[_Item], backend: str,
                      pool, stats: dict | None):
    """Dispatch one svs device program; returns un-materialized device
    results (vals, counts).  The batch dimension is bucketed (sentinel-
    padded rows, results sliced back at collect time) so the compile count
    stays bounded by the signature space."""
    R, F, active, pkparts, W, Bp, J, Jb = _assemble_svs(key, items, pool)
    pk = pk_active = None
    if pkparts is not None:
        stacked, PBk, pk_act = pkparts
        pk = _compose_pk(stacked, PBk)
        pk_active = jnp.asarray(pk_act)
    mode, rows = "d1", 32
    if key.packed is not None:
        rows, mode = key.packed[4], key.packed[5]
    if stats is not None:
        stats.setdefault("signatures", set()).add(("svs", key, Bp, J, Jb))
    return _svs_program(R, F, jnp.asarray(active), pk, pk_active, W,
                        key.algo, backend, mode, rows)


def _assemble_bitmap(key: GroupKey, items: list[_Item], pool, *,
                     bp: int | None = None, j: int | None = None):
    """Stacked (Bp, J, W) word rows of one all-bitmap group chunk (device
    array in pool mode, host numpy otherwise).  ``bp``/``j`` override the
    chunk-derived paddings for sharded per-shard slices."""
    B = len(items)
    Bp = _bucket_rows(B) if bp is None else bp
    J = (max((_n_bitmaps(it) for it in items), default=1)
         if j is None else j)
    if pool is not None and all(it.bm_keys is not None for it in items):
        # arena fast path: missing terms of real rows gather the all-ones
        # AND identity (slot 0); padded batch rows gather all-zero (slot 1)
        wa = pool.bitmap_arena(key.words)
        widx = np.zeros((Bp, J), np.int32)
        widx[B:, :] = source.ResidentPool.BM_ZERO_SLOT
        for b, it in enumerate(items):
            for jj, (bk, wnp) in enumerate(it.bm_keys):
                widx[b, jj] = wa.slot(bk, lambda w=wnp: w)
        words = _GATHER(wa.buffer(),
                        jnp.asarray(widx.reshape(-1))
                        ).reshape(Bp, J, key.words)
    elif pool is not None:
        rows = []
        for b in range(Bp):
            it = items[b] if b < B else None
            for j in range(J):
                if it is not None and j < len(it.bm_dev):
                    rows.append(it.bm_dev[j])
                elif it is not None:
                    rows.append(pool.ones_row(key.words))   # AND identity
                else:
                    rows.append(pool.zeros_row(key.words))  # popcount 0
        words = _stack_rows(rows).reshape(Bp, J, key.words)
    else:
        # real rows pad missing terms with all-ones (AND identity); padded
        # batch rows stay all-zero so their popcount is 0
        wnp = np.zeros((Bp, J, key.words), dtype=np.uint32)
        for b, it in enumerate(items):
            wnp[b] = 0xFFFFFFFF
            wnp[b, : it.bm_words.shape[0]] = it.bm_words
        words = jnp.asarray(wnp)
    return words, Bp, J


def _launch_bitmap_group(key: GroupKey, items: list[_Item], pool,
                         stats: dict | None):
    words, Bp, J = _assemble_bitmap(key, items, pool)
    if stats is not None:
        stats.setdefault("signatures", set()).add(("bm", key, Bp, J))
    return _bitmap_and_program(words)


def _chunk_size(key: GroupKey, items: list[_Item],
                max_group_size: int) -> int:
    """Items per device program: flat cap ∧ operand-int budget (so huge
    J·N fold stacks shrink the batch instead of exploding device memory)."""
    if key.kind == "bitmap":
        J = max((it.bm_words.shape[0] if it.bm_words is not None
                 else len(it.bm_dev)) for it in items)
        per_item = J * key.words
    else:
        J = max(len(it.folds) for it in items)
        Jb = max((it.bm_words.shape[0] if it.bm_words is not None
                  else len(it.bm_dev) if it.bm_dev is not None else 0)
                 for it in items)
        per_item = J * key.n_bucket + key.m_bucket + Jb * key.words
        if key.packed is not None:
            k_pad, t_pad, c_pad, e_pad, rows, _ = key.packed
            Jp = max(len(it.psrc) for it in items)
            # compressed words + per-block metadata + the partial decode
            # buffer the program materializes (c_pad blocks of rows×128)
            per_item += Jp * (t_pad * 128 + 3 * k_pad + c_pad
                              + 2 * e_pad + c_pad * rows * 128)
    return max(1, min(max_group_size, GROUP_INT_BUDGET // max(per_item, 1)))


# --------------------------------------------------------------------------
# launch / collect (the pipeline split) and the public entry point
# --------------------------------------------------------------------------

@dataclasses.dataclass
class PendingBatch:
    """Dispatched-but-unmaterialized batch: device result handles per group
    chunk.  JAX async dispatch means the device is (or will be) executing
    these while the host moves on; ``collect_batch`` blocks on them."""
    n_queries: int
    max_results: int
    launched: list          # [(key, chunk_items, vals_dev, counts_dev)]
    stats: dict | None


def launch_groups(groups: dict[GroupKey, list[_Item]], *, n_queries: int,
                  backend: str = "jax", max_results: int = 1 << 16,
                  max_group_size: int = MAX_GROUP_SIZE,
                  pool: "source.ResidentPool | None" = None,
                  stats: dict | None = None) -> PendingBatch:
    """Dispatch one device program per group chunk without materializing
    any result — the host returns as soon as everything is enqueued."""
    launched = []
    n_programs = 0
    for key, items in groups.items():
        step = _chunk_size(key, items, max_group_size)
        for lo in range(0, len(items), step):
            chunk = items[lo: lo + step]
            if key.kind == "bitmap":
                vals, counts = _launch_bitmap_group(key, chunk, pool, stats)
            else:
                vals, counts = _launch_svs_group(key, chunk, backend, pool,
                                                 stats)
            launched.append((key, chunk, vals, counts))
            n_programs += 1
    accumulate_launch_stats(stats, groups, n_programs)
    return PendingBatch(n_queries=n_queries, max_results=max_results,
                        launched=launched, stats=stats)


def accumulate_launch_stats(stats: dict | None, groups, n_programs: int):
    """Accumulate per-launch counters (like the decoded_ints/skip_folds
    counters) so one stats dict can span a chunked run of many batches —
    shared by the single-device and sharded launchers."""
    if stats is None:
        return
    for k, v in (("n_groups", len(groups)), ("n_programs", n_programs),
                 ("n_items", sum(len(v) for v in groups.values()))):
        stats[k] = stats.get(k, 0) + v


def collect_batch(pending: PendingBatch) -> list[QueryResult]:
    """Materialize a launched batch (blocks on the device) and re-assemble
    per-query results in part order — byte-identical to ``engine.query``."""
    per_query: list[list[tuple[int, np.ndarray]]] = \
        [[] for _ in range(pending.n_queries)]
    counts = [0] * pending.n_queries
    for key, chunk, vals_dev, counts_dev in pending.launched:
        vals = np.asarray(vals_dev)
        cnts = np.asarray(counts_dev)
        for b, it in enumerate(chunk):
            if it is None:          # padded slot (sharded shard-slice pad)
                continue
            cnt = int(cnts[b])
            counts[it.qi] += cnt
            if not cnt:
                continue
            if key.kind == "bitmap":
                docs = bm.extract_np(vals[b])
            else:
                docs = vals[b, : cnt]
            per_query[it.qi].append((it.pi, docs.astype(np.int64)
                                     + it.doc_lo))
    out = []
    for qi in range(pending.n_queries):
        chunks = [d for _, d in sorted(per_query[qi], key=lambda x: x[0])]
        docs = (np.concatenate(chunks) if chunks
                else np.zeros(0, np.int64))[: pending.max_results]
        out.append(QueryResult(count=counts[qi], docs=docs))
    return out


def execute_batch(index: HybridIndex, queries: list[list[int]], *,
                  backend: str = "jax", max_results: int = 1 << 16,
                  max_group_size: int = MAX_GROUP_SIZE, cache=None,
                  skip: bool = True, stats: dict | None = None,
                  pool: "source.ResidentPool | None" = None
                  ) -> list[QueryResult]:
    """Answer a batch of conjunctive queries; results are element-for-element
    identical to ``engine.query`` run per query.

    backend: 'jax' (searchsorted/tile-merge) or 'pallas' (galloping kernel).
    skip: False forces full decode of every fold list (the pre-skip
    behavior, kept for A/B benchmarking of the partial-decode win).
    pool: optional ResidentPool — operands are served from (and staged
    into) the device-resident index; group assembly becomes index-gathering
    over resident buffers instead of per-batch decode + padding + H2D.
    stats: optional dict, filled with scheduler counters (n_groups,
    n_programs, n_items, decoded_ints, skip_folds, resident_hits,
    layout_hits/misses) for introspection.
    """
    assert backend in ("jax", "pallas"), backend
    groups = schedule(index, queries, cache=cache, skip=skip, stats=stats,
                      pool=pool)
    pending = launch_groups(groups, n_queries=len(queries), backend=backend,
                            max_results=max_results,
                            max_group_size=max_group_size, pool=pool,
                            stats=stats)
    return collect_batch(pending)
