"""Batched query execution: shape-bucketed scheduling + device-side SvS.

The sequential engine (``repro.index.engine``) answers one query at a time
and bounces candidates to host between every SvS fold — exactly the dispatch
overhead the paper warns fast decoders drown in.  This module keeps whole
query *batches* inside the vectorized regime:

  1. **Schedule.** Every (query, index-part) work item is assigned a shape
     signature: (pow2 bucket of the shortest list M, pow2 bucket of the
     longest fold list N, bitmap word count, intersect algorithm, packed
     signature).  Fold terms resolve through the posting-source layer
     (``repro.index.source``): short lists decode (and cache), long
     skip-capable lists stay *packed* and carry a batch-uniform layout —
     words/widths/offsets/maxes buckets plus the host-precomputed candidate
     block ids — so compressed long lists are never fully decoded in the
     batch regime either.  Term counts are *not* part of the signature —
     queries of different arity merge into one program, padded to the
     group's max fold/probe count with masked no-op folds and all-ones
     bitmap rows (probe identities) — and the batch dimension is bucketed
     on a ×1.5 ladder, so the compile count stays O(log² n_docs · log B)
     overall.
  2. **Fuse (megagroups).** A realistic mixed batch spans dozens of shape
     signatures, and at ~60µs/arg of host jit-dispatch cost the *number of
     device programs per batch* becomes the serving bottleneck once operand
     assembly is arena-gathered (DESIGN.md §2.10).  ``fuse_groups``
     therefore coarsens compatible GroupKeys into signature **families** —
     same kind and packed block geometry; M/N/W/packed pads raised to the
     family ceiling; fold/probe arity ceilings pow2-bucketed — and
     concatenates their items along the batch-row axis, so one batch
     launches O(#families) ≈ O(1) fused programs instead of one per
     signature.  Fusion is sound because group programs are row-independent
     and padding is inert (module invariants below): a row assembled into a
     wider slot gathers sentinel/identity filler that never contributes to
     its result.  Fused programs force ``algo="gallop"``: the coarsened
     M/N make the tiled ratio rule meaningless, and the lock-step tile
     walk loses its data-dependent early exit entirely at family ceilings
     while galloping stays O(M log N) per row.  A sticky ``FusionPlan``
     keeps family ceilings monotone across batches so fused signatures
     converge, and ``warmup`` precompiles the family ladder ahead of the
     first batch (AOT signature warmup — steady-state serving never
     compiles).
  3. **Execute.** Each (fused) group runs as a *single* device program: the
     batch of shortest lists (B, M) is intersected with the stacked decoded
     fold lists (J, B, N) by a ``lax.scan`` of vmapped intersects, then
     with the stacked *packed* folds (tuple of (Jp, B, ...) layout arrays,
     each step a skip-aware partial decode of candidate blocks only), then
     the surviving candidates are probed against the stacked bitmap terms
     (J_b, B, W) — candidates never round-trip to host between terms.
     Every step ANDs its match mask into one running validity mask over the
     *original* sorted seed buffer instead of compacting between folds:
     compaction never shrank the (static) shapes, but its cumsum+scatter
     was the single most expensive op in the program, and mask-folding is
     what keeps the fused ceilings affordable.  Fold order is
     decoded-then-packed, which is safe because set intersection commutes
     and every mask is computed against the same sorted seed row.
     All-bitmap queries reduce to a batched AND + popcount.  Without a
     pool, stacking happens host-side in numpy (one device transfer per
     operand); with a ``source.ResidentPool`` the operands are
     device-resident and each one assembles as a single row-arena gather —
     no decode, no padding memcpy, no H2D transfer, and no per-row
     dispatch cost (DESIGN.md §2.8).
  4. **Aggregate.** Per-item results are re-assembled per query in index-part
     order, matching the sequential engine byte for byte.  Device results
     arrive masked-but-uncompacted; the host extracts the valid (still
     sorted) entries per row.

This module is DESIGN.md §2.7 (scheduler + group-key scheme); §2.8 covers
the resident/pipelined serving built on it, §2.9 the sharded fan-out, and
§2.10 megagroup fusion + warmup.  Invariants callers rely on:

  * **Group-signature stability** — ``GroupKey`` describes operand
    *shapes* only (pow2 buckets, block geometry, algorithm).  Residency,
    arenas, caches, and sharding change where a row lives or which device
    computes it, never its shape, so every serving mode compiles the same
    per-signature programs and the compile count stays bounded.  Fusion
    preserves this: a fused key is just a GroupKey at family-ceiling
    buckets, and the sticky ``FusionPlan`` makes those ceilings monotone
    so fused signatures converge to a fixed point.  The sharded executor
    additionally relies on group programs being row-independent (the only
    scanned axis is the fold axis), which is what lets it split the row
    axis across devices unchanged.
  * **Byte-identical aggregation** — per-query results concatenate in
    part order (items carry their part ordinal; ``collect_batch`` sorts
    by it), preserving global doc-id sortedness, so batched ==
    pipelined == sharded == sequential, element for element.
  * **Padding is inert** — padded batch rows, masked no-op folds,
    identity bitmap rows, and all-pad packed layouts never contribute to
    any active row's result.

Launch and collect are split (``launch_groups`` dispatches every group
program and returns a ``PendingBatch`` of un-materialized device results;
``collect_batch`` blocks and aggregates) so ``repro.index.pipeline`` can
overlap host scheduling of batch k+1 with device execution of batch k.
``execute_batch`` composes the two and is byte-identical to the sequential
engine.  On non-CPU backends the candidate buffer is donated to the device
program — it is freshly assembled per dispatch and never reused, so XLA can
reuse its pages for the output.

Algorithm choice: under ``vmap`` the tiled merge runs lock-step across the
batch — the slowest row sets the step count and its data-dependent early
exit is lost — so the batched dispatcher biases much harder toward galloping
than the sequential ratio rule (``BATCH_TILED_MAX_RATIO`` vs the paper's
50×; re-derived in ``benchmarks/bench_engine.py``), and fused megagroup
programs force galloping outright (see ``fuse_groups``).

Backends: ``backend="jax"`` uses the jnp searchsorted / tile-merge paths from
``core.intersect``; ``backend="pallas"`` routes every fold through the Pallas
galloping kernel (``kernels.ops.intersect_gallop_batch``).
"""

from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from functools import lru_cache, partial

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.core import bitmap as bm
from repro.core import codecs as codec_lib
from repro.core import intersect as its
from repro.index import source
from repro.index.builder import HybridIndex
from repro.index.engine import QueryResult

MAX_GROUP_SIZE = 128          # hard cap on items per device program
GROUP_INT_BUDGET = 1 << 25    # cap operand ints per program: B·(J·N+M+J_b·W)
BATCH_TILED_MAX_RATIO = 4.0   # vmapped tile-merge loses early exit; see above
PALLAS_MIN_OCCUPANCY = 0.5    # interpret-mode kernel guard; see below

# Interpret-mode Pallas executes every grid step on the host, so its cost
# scales with the PADDED grid (Bp·(1+J+Jp) fused-family ceiling slots), not
# the real payload — a sparsely occupied fused chunk can pay several times
# its useful work in dead steps (the PR-5 fused-ceiling regression).  When
# the kernels run in interpret mode and a chunk's occupancy (real rows +
# folds over padded grid slots) falls below PALLAS_MIN_OCCUPANCY, the
# launcher routes that one program through the jax backend instead —
# byte-identical results (the mask-fold contract is backend-independent),
# counted in stats["pallas_lowocc_fallbacks"].  Compiled mode skips the
# guard: dead TPU grid steps retire in microseconds and kernel residency
# is worth keeping (DESIGN.md §2.12).

# Donating the candidate buffer lets XLA alias its pages for the output; it
# is always freshly stacked per dispatch so nothing aliases it on the host.
# CPU has no donation support (XLA warns and ignores), so gate it.
_DONATE_CANDIDATES = (0,) if jax.default_backend() != "cpu" else ()


@dataclasses.dataclass(frozen=True)
class GroupKey:
    """Shape signature shared by all work items of one device program.
    Term counts are deliberately NOT part of the key: queries of different
    arity merge into one program, padded to the group's max fold/probe count
    with masked no-op folds and all-ones bitmap rows (probe identities).
    Packed folds replace the fold-length bucket with their block-layout
    buckets: (k_pad blocks, t_pad word rows, c_pad candidate blocks,
    e_pad exceptions, block_rows, delta mode).

    ``fused`` is set only on megagroup keys produced by ``fuse_groups``:
    the pow2-bucketed fold/probe arity ceilings — ('svs': (J, Jb, Jp),
    'bitmap': (J,)) — which a fused program pins so its signature does not
    drift with the arity mix of each batch.  Scheduled (unfused) keys
    leave it None and derive arities from their items, as before."""
    kind: str              # 'svs' (≥1 list term) | 'bitmap' (all-bitmap)
    m_bucket: int          # candidate buffer length M
    n_bucket: int          # decoded fold-list pad length N
    words: int             # bitmap word count W (0 when no bitmaps)
    algo: str              # 'tiled' | 'gallop' | '-'
    packed: tuple | None = None   # (k_pad, t_pad, c_pad, e_pad, rows, mode)
    fused: tuple | None = None    # megagroup arity ceilings (see above)


@dataclasses.dataclass
class _Item:
    qi: int                # query index within the submitted batch
    pi: int                # index-part ordinal (aggregation order)
    doc_lo: int
    r: object = None                      # (M,) seed: np (host) | jnp (pool)
    folds: list | None = None             # host: J × (N,) np
                                          # pool: J × DecodedSource
    psrc: list | None = None              # Jp × (layout, blk) — layout is
                                          # the self-padded np PackedLayout
                                          # (host) or the PackedSource
                                          # itself (pool; arena-assembled);
                                          # blk is the RAW candidate block
                                          # id list (padded at stack time to
                                          # the launching key's c_pad/k_pad,
                                          # which fusion may have raised)
    bm_words: np.ndarray | None = None    # host: (J_b, W) bitmap word rows
    bm_dev: list | None = None            # pool: J_b × (W,) resident rows
    bm_keys: list | None = None           # pool: J_b × pool keys (arenas)
    rsrc: object = None                   # pool: seed DecodedSource


# every jitted stacker ever created, so _compile_count can see their
# caches too (the arena-fallback path compiles stack programs mid-serving)
_STACKERS: list = []


@lru_cache(maxsize=None)
def _stacker(n: int):
    """Jitted n-ary row stack.  ``jnp.stack`` on a list dispatches one
    eager expand_dims per row — ~45µs each on a host backend, which made
    operand assembly the dominant serving cost; a jitted stacker is one
    dispatch for the whole stack (~8× cheaper at 128 rows).  Memoized per
    arity; jit itself re-specializes per row shape/dtype, and with inputs
    committed to one device the stack runs (and its result stays) there —
    which is what keeps per-shard slices on their own devices."""
    fn = jax.jit(lambda *xs: jnp.stack(xs))
    _STACKERS.append(fn)
    return fn


def _stack_rows(rows: list) -> jnp.ndarray:
    return _stacker(len(rows))(*rows)


def _bucket_rows(b: int) -> int:
    """Batch-dim bucket: ~×1.5 geometric ladder (1,2,3,4,6,9,13,19,28,…).
    Bounds the compile count per signature at O(log B) while wasting at
    most 1/3 of rows on padding (a pow2 ladder wastes up to 2×, which
    shows up directly as lost throughput on small groups)."""
    size = 1
    while size < b:
        size = size * 3 // 2 if size >= 2 else size + 1
    return size


def _extend_np(vals: np.ndarray, size: int) -> np.ndarray:
    return vals if vals.shape[0] == size else its.pad_to(vals, size)


def _extend_words(w: np.ndarray, size: int) -> np.ndarray:
    """Zero-extend a bitmap word row to a (possibly fused) W bucket.  Zeros
    are inert both ways: probes never index past the row's real doc span,
    and the all-bitmap AND meets a zero extension on every real term, so
    the popcount contribution is 0."""
    if w.shape[0] == size:
        return w
    out = np.zeros(size, np.uint32)
    out[: w.shape[0]] = w
    return out


def _extend_words_dev(row: jnp.ndarray, size: int) -> jnp.ndarray:
    if row.shape[0] == size:
        return row
    return jnp.concatenate(
        [row, jnp.zeros(size - row.shape[0], jnp.uint32)])


def schedule(index: HybridIndex, queries: list[list[int]], cache=None,
             skip: bool = True, stats: dict | None = None,
             pool: "source.ResidentPool | None" = None
             ) -> dict[GroupKey, list[_Item]]:
    """Bucket every (query, part) work item by shape signature.  Terms
    resolve through the posting-source layer here (host side, optionally
    cached): short lists decode, long skip-capable lists keep their packed
    layout plus host-searched candidate block ids.  With a ResidentPool the
    items carry *references to resident device buffers*; without one they
    carry host numpy arrays.  Everything downstream of this point is device
    programs over stacked operands."""
    codec = codec_lib.get_codec(index.codec_name)
    # sharded serving hands in one device-pinned pool per part (an object
    # with .for_part); plain serving hands in a single pool or None
    pool_of = (pool.for_part if hasattr(pool, "for_part")
               else (lambda pi: pool))
    groups: dict[GroupKey, list[_Item]] = defaultdict(list)
    for qi, term_ids in enumerate(queries):
        for pi, part in enumerate(index.parts):
            pool = pool_of(pi)
            tps = [part.terms[t] for t in term_ids]
            if any(tp.kind == "empty" for tp in tps):
                continue
            pairs = [(t, tp) for t, tp in zip(term_ids, tps)
                     if tp.kind == "list"]
            pairs.sort(key=lambda p: p[1].n)
            bm_pairs = [(t, tp) for t, tp in zip(term_ids, tps)
                        if tp.kind == "bitmap"]
            W = len(bm_pairs[0][1].payload) if bm_pairs else 0
            bm_words = bm_dev = bm_keys = None
            if bm_pairs:
                if pool is not None:
                    # (key, host row) pairs: the arena assembler must not
                    # depend on store residency (tiny pools evict between
                    # schedule and assembly)
                    bm_keys = [(("bm", part.uid, t), np.asarray(tp.payload))
                               for t, tp in bm_pairs]
                    bm_dev = [pool.stage_bitmap(k, w) for k, w in bm_keys]
                else:
                    bm_words = np.stack([tp.payload for _, tp in bm_pairs])
            if not pairs:
                key = GroupKey("bitmap", 0, 0, W, "-")
                groups[key].append(_Item(qi, pi, part.doc_lo,
                                         bm_words=bm_words, bm_dev=bm_dev,
                                         bm_keys=bm_keys))
                continue
            seed_t, seed_tp = pairs[0]
            seed = source.resolve(part, seed_t, seed_tp, codec, cache=cache,
                                  r_count=None, stats=stats, pool=pool)
            seed_np = (seed.vals_np if seed.vals_np is not None
                       else np.asarray(seed.vals))
            M = seed_np.shape[0]
            dec, packed = [], []
            for t, tp in pairs[1:]:
                src = source.resolve(part, t, tp, codec, cache=cache,
                                     r_count=seed_tp.n, skip=skip,
                                     stats=stats, pool=pool)
                if isinstance(src, source.PackedSource):
                    packed.append((t, tp, src))
                else:
                    dec.append(src)
            psig, psrc = None, None
            if packed:
                # stacking along the fold axis needs one block geometry:
                # keep the longest fold's (block_rows, mode), decode the
                # rare mismatch (adaptive block sizing on mid-length lists)
                ref = max(packed, key=lambda p: p[2].n)[2]
                rows, mode = ref.block_rows, ref.mode
                keep, demote = [], []
                for p in packed:
                    same = (p[2].block_rows == rows and p[2].mode == mode)
                    (keep if same else demote).append(p)
                for t, tp, _ in demote:
                    # cache=None / pool=None: a demoted long list must not
                    # evict the int-budgeted stores' hot short lists — and
                    # staging it resident would permanently win over
                    # want_skip, disabling its block-max skip path over a
                    # one-off grouping accident
                    src = source.resolve(part, t, tp, codec, cache=None,
                                         skip=False, stats=stats, pool=None)
                    dec.append(src)
                r_valid = seed_np[: seed.n]
                cand = [(s, s.candidate_block_ids(r_valid))
                        for _, _, s in keep]
                k_pad = max(s.self_pads()[0] for s, _ in cand)
                t_pad = max(s.self_pads()[1] for s, _ in cand)
                c_pad = max(its.pow2_bucket(len(b), floor=source.CAND_FLOOR)
                            for _, b in cand)
                e_max = max(s.num_exceptions for s, _ in cand)
                e_pad = its.pow2_bucket(e_max, floor=1) if e_max else 0
                psig = (k_pad, t_pad, c_pad, e_pad, rows, mode)
                if pool is not None:
                    # keep the PackedSource itself: the arena assembler
                    # materializes its group-padded layout rows on demand
                    # (memoized host-side, one device matrix per operand);
                    # block ids stay raw — the stacker pads them to the
                    # launching key's buckets (fusion may raise them)
                    psrc = [(s, b) for s, b in cand]
                else:
                    # memoized at the payload's own pads; the stacker
                    # zero-extends into the group slot (no per-group re-pad)
                    psrc = [(source.cached_layout_np(s, s.self_pads(), stats),
                             b) for s, b in cand]
                # decoded_ints for packed folds is accounted at LAUNCH
                # time (the program decodes c_pad blocks per row, and
                # fusion may raise c_pad past this group's bucket)
                source._bump(stats, "skip_folds", len(psrc))
            N = max((s.vals.shape[0] for s in dec), default=128)
            if pool is not None:
                r_op = seed.vals
                folds = dec                          # padded at stack time
            else:
                r_op = seed_np
                folds = [_extend_np(s.vals_np if s.vals_np is not None
                                    else np.asarray(s.vals), N) for s in dec]
            algo = ("tiled" if N / M <= BATCH_TILED_MAX_RATIO else "gallop")
            key = GroupKey("svs", M, N, W, algo, psig)
            groups[key].append(_Item(qi, pi, part.doc_lo, r=r_op,
                                     rsrc=seed if pool is not None else None,
                                     folds=folds, psrc=psrc,
                                     bm_words=bm_words, bm_dev=bm_dev,
                                     bm_keys=bm_keys))
    return groups


# --------------------------------------------------------------------------
# device programs (one dispatch per GroupKey chunk)
# --------------------------------------------------------------------------

def _mask_fold_scan(r, valid, folds, fold_active, intersect_fn):
    """Scan the stacked folds, ANDing each step's match mask into ``valid``.
    Every intersect runs against the *original* sorted seed buffer ``r``:
    compacting between folds never shrank the (static) operand shapes, but
    its cumsum+scatter was the single most expensive op in the program —
    mask-folding removes it, which is what keeps fused family-ceiling
    shapes affordable (DESIGN.md §2.10).  ``folds`` may be a plain
    (J, B, N) stack or any pytree of (J, ...)-leading operands (the packed
    layout tuple); inactive (j, b) slots leave their row's mask untouched."""
    def step(v, xs):
        f, act = xs
        hit = intersect_fn(r, f)
        return v & jnp.where(act[:, None], hit, True), None

    valid, _ = lax.scan(step, valid, (folds, fold_active))
    return valid


@partial(jax.jit, static_argnames=("algo", "backend", "mode", "block_rows"),
         donate_argnums=_DONATE_CANDIDATES)
def _svs_program(r, folds, fold_active, pk, pk_active, words, algo: str,
                 backend: str, mode: str, block_rows: int):
    """One device program per group chunk: decoded folds → packed folds →
    bitmap probes, candidates staying on device throughout.  Every stage
    computes a match mask over the original sorted seed buffer ``r`` and
    ANDs it into one running validity mask; the result is ``r`` with
    invalid slots set to SENTINEL — per-row sorted but NOT compacted (the
    host extracts the valid prefix-by-mask at collect).  ``pk`` is the
    tuple of stacked batch-uniform packed operands (or None); ``words`` the
    stacked bitmap rows (or None).  ``r`` is donated off-CPU (see module
    docstring)."""
    valid = r != its.SENTINEL
    if folds.shape[0]:
        if backend == "pallas":
            # fused megakernel: the whole J-fold stack in one launch
            # (grid (B, J), mask accumulated in the revisited out block)
            from repro.kernels import ops as kernel_ops
            valid = kernel_ops.intersect_fold_batch(r, valid, folds,
                                                    fold_active)
        else:
            if algo == "tiled":
                fold_fn = partial(its.intersect_tiled_batch,
                                  tile_r=min(128, r.shape[-1]),
                                  tile_f=min(1024, folds.shape[-1]))
            else:
                fold_fn = its.intersect_gallop_batch
            valid = _mask_fold_scan(r, valid, folds, fold_active, fold_fn)
    if pk is not None:
        if backend == "pallas":
            # fused decode+intersect megakernel: unpack candidate blocks in
            # kernel scratch, gallop, fold — one launch for the Jp stack,
            # no materialized decoded array (DESIGN.md §2.12)
            from repro.kernels import ops as kernel_ops
            valid = kernel_ops.intersect_packed_fold(
                r, valid, pk, pk_active, mode=mode, block_rows=block_rows)
        else:
            valid = _mask_fold_scan(
                r, valid, pk, pk_active,
                lambda rr, op: its.intersect_packed_batch(
                    rr, *op, mode=mode, block_rows=block_rows))
    if words is not None:
        def wstep(v, w):
            return jax.vmap(bm.probe)(w, r, v), None

        valid, _ = lax.scan(wstep, valid, words)
    return (jnp.where(valid, r, its.SENTINEL),
            jnp.sum(valid.astype(jnp.int32), axis=-1))


@jax.jit
def _bitmap_and_program(words):
    """All-bitmap queries: AND-reduce (B, J, W) word stacks + popcount."""
    out = words[:, 0]
    for j in range(1, words.shape[1]):
        out = out & words[:, j]
    counts = jnp.sum(lax.population_count(out).astype(jnp.int32), axis=-1)
    return out, counts


def _stack_packed(key: GroupKey, items: list[_Item], Bp: int,
                  jp: int | None = None):
    """Stack the per-item packed layouts into uniform (Jp, Bp, ...) numpy
    operands.  Layouts arrive self-padded (the memoized projection); each
    slot zero-extends into the key's buckets (which fusion may have raised
    past the scheduled group's) — pad blocks have width 0 and in-bounds
    offsets, and block ids beyond the real count never appear in the
    candidate list, so the extension is never decoded.  Raw candidate block
    ids pad with the key's out-of-range id ``k_pad`` (→ all-SENTINEL
    decode); inactive (j, b) slots keep all-pad block ids and are
    additionally masked by the active flags.  Returns (six host operand
    stacks, candidate block ids, active) — callers compose/upload."""
    k_pad, t_pad, c_pad, e_pad, rows, _ = key.packed
    Jp = (max((len(it.psrc) for it in items), default=0)
          if jp is None else jp)
    PW = np.zeros((Jp, Bp, t_pad, 128), np.uint32)
    PWid = np.zeros((Jp, Bp, k_pad), np.int32)
    POf = np.zeros((Jp, Bp, k_pad), np.int32)
    PMx = np.zeros((Jp, Bp, k_pad), np.uint32)
    PBk = np.full((Jp, Bp, c_pad), k_pad, np.int32)
    PEp = np.full((Jp, Bp, e_pad), -1, np.int32)
    PEa = np.zeros((Jp, Bp, e_pad), np.uint32)
    active = np.zeros((Jp, Bp), bool)
    for b, it in enumerate(items):
        for j, (lay, blk) in enumerate(it.psrc):
            K, T, E = (lay.widths.shape[0], lay.words.shape[0],
                       lay.exc_pos.shape[0])
            PW[j, b, :T] = lay.words
            PWid[j, b, :K] = lay.widths
            POf[j, b, :K] = lay.offsets
            PMx[j, b, :K] = lay.maxes
            PBk[j, b, : blk.shape[0]] = blk
            if e_pad and E:
                PEp[j, b, :E] = lay.exc_pos
                PEa[j, b, :E] = lay.exc_add
            active[j, b] = True
    return [PW, PWid, POf, PMx, PEp, PEa], PBk, active


# Arena gather: 2 arguments per assembled operand regardless of row count
# (the whole point of RowArena — see source.py); executes on the arena
# buffer's device, so per-shard slices stay on their shard's device.
_GATHER = jax.jit(lambda buf, idx: buf[idx])


def _stack_packed_arena(key: GroupKey, items: list[_Item], Bp: int,
                        pool: "source.ResidentPool",
                        jp: int | None = None):
    """Pool-mode packed stacking: gather each of the six layout operands
    from its RowArena with one (Jp·Bp,) index vector — slot 0 is the
    all-pad layout, so inactive grid positions decode to SENTINEL exactly
    like the host-stacked path.  Only the per-query candidate block ids
    cross to the device.  Returns (six device operand stacks, candidate
    block ids, active)."""
    k_pad, t_pad, c_pad, e_pad, rows, _ = key.packed
    pads = (k_pad, t_pad, e_pad)
    Jp = (max((len(it.psrc) for it in items), default=0)
          if jp is None else jp)
    arenas = [pool.layout_arena(pads, o) for o in range(6)]
    idx = np.zeros((Jp, Bp), np.int32)          # 0 = all-pad layout slot
    PBk = np.full((Jp, Bp, c_pad), k_pad, np.int32)
    active = np.zeros((Jp, Bp), bool)
    for b, it in enumerate(items):
        for j, (src, blk) in enumerate(it.psrc):
            slot = arenas[0].slots.get(src.key)
            if slot is None:
                lay = source.cached_layout_np(src, pads)
                ops = (lay.words, lay.widths, lay.offsets, lay.maxes,
                       lay.exc_pos, lay.exc_add)
                for a, row in zip(arenas, ops):
                    slot = a.slot(src.key, lambda r=row: np.asarray(r))
            idx[j, b] = slot
            PBk[j, b, : blk.shape[0]] = blk
            active[j, b] = True
    gidx = jnp.asarray(idx.reshape(-1))
    stacked = [_GATHER(a.buffer(), gidx).reshape(
                   (Jp, Bp) + a.rows_np[0].shape)
               for a in arenas]
    return stacked, PBk, active


def _compose_pk(stacked, PBk):
    """Order the packed program operand tuple from six stacked arrays +
    candidate block ids (device or host; the jit call uploads host parts)."""
    return (stacked[0], stacked[1], stacked[2], stacked[3],
            jnp.asarray(PBk), stacked[4], stacked[5])


def _n_bitmaps(it: _Item) -> int:
    return (it.bm_words.shape[0] if it.bm_words is not None
            else len(it.bm_dev) if it.bm_dev is not None else 0)


def _arena_ok(items: list[_Item]) -> bool:
    """Arena assembly needs a host copy + identity key for every value row;
    cache-hit sources carry neither (their numpy copy was dropped at cache
    fill), so groups containing them fall back to the row-stack path."""
    for it in items:
        if it.rsrc is None or it.rsrc.vals_np is None or not it.rsrc.key:
            return False
        for f in it.folds:
            if f.vals_np is None or not f.key:
                return False
    return True


def _assemble_svs(key: GroupKey, items: list[_Item],
                  pool: "source.ResidentPool | None", *,
                  bp: int | None = None, j: int | None = None,
                  jb: int | None = None, jp: int | None = None):
    """Build the operands of one svs group chunk.  Host mode stacks numpy
    and pays one H2D per operand; pool mode gathers resident rows (committed
    to the pool's device).  Rows narrower than the key's buckets (fused
    megagroup keys raise them past the scheduled shapes) extend with
    sentinel / zero-word filler — inert by the module's padding invariant.
    ``bp``/``j``/``jb``/``jp`` override the chunk-derived paddings so the
    sharded executor can assemble uniform per-shard slices
    (``repro.index.shard``); fused keys pin the arity ceilings via
    ``key.fused``; None derives them from the items — the single-device
    unfused path, unchanged."""
    B = len(items)
    kj, kjb, kjp = key.fused if key.fused else (None, None, None)
    Bp = _bucket_rows(B) if bp is None else bp
    if j is None:
        j = kj
    if jb is None:
        jb = kjb
    if jp is None:
        jp = kjp
    J = (max((len(it.folds) for it in items), default=0)
         if j is None else j)
    Jb = (max((_n_bitmaps(it) for it in items), default=0)
          if jb is None else jb)
    active = np.zeros((J, Bp), dtype=bool)
    if pool is not None and _arena_ok(items):
        # arena fast path: one gather per operand (DESIGN.md §2.8/§2.9)
        fa_m = pool.fold_arena(key.m_bucket)
        ridx = np.zeros(Bp, np.int32)               # 0 = sentinel row
        for b, it in enumerate(items):
            ridx[b] = fa_m.slot(
                it.rsrc.key,
                lambda s=it.rsrc: _extend_np(s.vals_np, key.m_bucket))
        R = _GATHER(fa_m.buffer(), jnp.asarray(ridx))
        if J:
            fa_n = pool.fold_arena(key.n_bucket)
            fidx = np.zeros((J, Bp), np.int32)
            for b, it in enumerate(items):
                for jj, f in enumerate(it.folds):
                    fidx[jj, b] = fa_n.slot(
                        f.key,
                        lambda s=f: _extend_np(s.vals_np, key.n_bucket))
                    active[jj, b] = True
            F = _GATHER(fa_n.buffer(),
                        jnp.asarray(fidx.reshape(-1))
                        ).reshape(J, Bp, key.n_bucket)
        else:
            F = jnp.zeros((0, Bp, key.n_bucket), jnp.int32)
        W = None
        if Jb:
            wa = pool.bitmap_arena(key.words)
            widx = np.zeros((Jb, Bp), np.int32)     # 0 = probe identity
            for b, it in enumerate(items):
                for jj, (bk, wnp) in enumerate(it.bm_keys or ()):
                    widx[jj, b] = wa.slot(
                        bk, lambda w=wnp: _extend_words(w, key.words))
            W = _GATHER(wa.buffer(),
                        jnp.asarray(widx.reshape(-1))
                        ).reshape(Jb, Bp, key.words)
    elif pool is not None:
        R = _stack_rows([pool.padded(it.rsrc, key.m_bucket) for it in items]
                        + [pool.sentinel_row(key.m_bucket)] * (Bp - B))
        rows = []
        for j in range(J):
            for b in range(Bp):
                it = items[b] if b < B else None
                if it is not None and j < len(it.folds):
                    rows.append(pool.padded(it.folds[j], key.n_bucket))
                    active[j, b] = True
                else:
                    rows.append(pool.sentinel_row(key.n_bucket))
        F = (_stack_rows(rows).reshape(J, Bp, key.n_bucket) if J
             else jnp.zeros((0, Bp, key.n_bucket), jnp.int32))
        W = None
        if Jb:
            wrows = []
            for j in range(Jb):
                for b in range(Bp):
                    it = items[b] if b < B else None
                    if it is not None and it.bm_dev and j < len(it.bm_dev):
                        wrows.append(_extend_words_dev(it.bm_dev[j],
                                                       key.words))
                    else:
                        # inactive slots are all-ones — the probe identity
                        wrows.append(pool.ones_row(key.words))
            W = _stack_rows(wrows).reshape(Jb, Bp, key.words)
    else:
        Rnp = np.full((Bp, key.m_bucket), its.SENTINEL, dtype=np.int32)
        for b, it in enumerate(items):
            Rnp[b, : it.r.shape[0]] = it.r
        R = jnp.asarray(Rnp)                                    # (Bp, M)
        F = np.full((J, Bp, key.n_bucket), its.SENTINEL, dtype=np.int32)
        for b, it in enumerate(items):
            for j, fold in enumerate(it.folds):
                F[j, b, : fold.shape[0]] = fold
                active[j, b] = True
        F = jnp.asarray(F)                                      # (J, Bp, N)
        W = None
        if Jb:
            # inactive slots are all-ones rows — the probe identity; the
            # zero extension past a real row's own W is never probed
            Wnp = np.full((Jb, Bp, key.words), 0xFFFFFFFF, dtype=np.uint32)
            for b, it in enumerate(items):
                if it.bm_words is not None:
                    for j in range(it.bm_words.shape[0]):
                        Wnp[j, b, : it.bm_words.shape[1]] = it.bm_words[j]
                        Wnp[j, b, it.bm_words.shape[1]:] = 0
            W = jnp.asarray(Wnp)
    pkparts = None
    if key.packed is not None:
        if pool is not None:
            pkparts = _stack_packed_arena(key, items, Bp, pool, jp=jp)
        else:
            pkparts = _stack_packed(key, items, Bp, jp=jp)
    return R, F, active, pkparts, W, Bp, J, Jb


def pallas_occupancy(key: GroupKey, items: list[_Item],
                     bp: int | None = None) -> float:
    """Fraction of the padded kernel grid that carries real work: (seed
    rows + decoded folds + packed folds) over Bp·(1 + J + Jp) family-
    ceiling slots.  This is exactly the ratio of useful to total grid
    steps the fused megakernels execute for the chunk.  ``bp`` overrides
    the batch bucket (the sharded launcher's grid is S·Bq rows)."""
    B = len(items)
    Bp = _bucket_rows(B) if bp is None else bp
    if key.fused:
        J, _, Jp = key.fused
        Jp = Jp or 0
    else:
        J = max((len(it.folds or ()) for it in items), default=0)
        Jp = max((len(it.psrc or ()) for it in items), default=0)
    real = (B + sum(len(it.folds or ()) for it in items)
            + sum(len(it.psrc or ()) for it in items))
    return real / max(Bp * (1 + J + Jp), 1)


def _effective_backend(key: GroupKey, items: list[_Item], backend: str,
                       stats: dict | None = None,
                       bp: int | None = None) -> str:
    """Occupancy guard (see PALLAS_MIN_OCCUPANCY above): demote a sparsely
    occupied chunk from interpret-mode pallas to the jax program.  Results
    are identical either way; only the execution engine changes."""
    if backend != "pallas":
        return backend
    from repro.kernels import ops as kernel_ops
    if not kernel_ops.INTERPRET:
        return backend
    if pallas_occupancy(key, items, bp) < PALLAS_MIN_OCCUPANCY:
        source._bump(stats, "pallas_lowocc_fallbacks")
        return "jax"
    return backend


def _launch_svs_group(key: GroupKey, items: list[_Item], backend: str,
                      pool, stats: dict | None, timings=None):
    """Dispatch one svs device program; returns un-materialized device
    results (vals, counts).  The batch dimension is bucketed (sentinel-
    padded rows, results masked back at collect time) so the compile count
    stays bounded by the signature space.  ``timings`` (a
    ``pipeline.StageTimings``) splits operand assembly from the async
    program enqueue."""
    backend = _effective_backend(key, items, backend, stats)
    t0 = time.perf_counter()
    R, F, active, pkparts, W, Bp, J, Jb = _assemble_svs(key, items, pool)
    pk = pk_active = None
    if pkparts is not None:
        stacked, PBk, pk_act = pkparts
        pk = _compose_pk(stacked, PBk)
        pk_active = jnp.asarray(pk_act)
    mode, rows = "d1", 32
    if key.packed is not None:
        rows, mode = key.packed[4], key.packed[5]
        # actual partial-decode volume: every active packed slot decodes
        # c_pad blocks at the LAUNCHING key's bucket (fused keys raise it
        # past the scheduled group's, and the stat must track the work the
        # program really does)
        source._bump(stats, "decoded_ints",
                     sum(len(it.psrc) for it in items)
                     * key.packed[2] * rows * 128)
    if stats is not None:
        stats.setdefault("signatures", set()).add(("svs", key, Bp, J, Jb))
    t1 = time.perf_counter()
    out = _svs_program(R, F, jnp.asarray(active), pk, pk_active, W,
                       key.algo, backend, mode, rows)
    if timings is not None:
        t2 = time.perf_counter()
        timings.assemble += t1 - t0
        timings.dispatch += t2 - t1
    return out


def _assemble_bitmap(key: GroupKey, items: list[_Item], pool, *,
                     bp: int | None = None, j: int | None = None):
    """Stacked (Bp, J, W) word rows of one all-bitmap group chunk (device
    array in pool mode, host numpy otherwise).  ``bp``/``j`` override the
    chunk-derived paddings for sharded per-shard slices; fused keys pin
    ``j`` via ``key.fused``.  Rows narrower than a fused W bucket
    zero-extend — every real row ANDs at least one zero extension, so the
    extension's popcount is 0."""
    B = len(items)
    Bp = _bucket_rows(B) if bp is None else bp
    if j is None and key.fused:
        j = key.fused[0]
    J = (max((_n_bitmaps(it) for it in items), default=1)
         if j is None else j)
    if pool is not None and all(it.bm_keys is not None for it in items):
        # arena fast path: missing terms of real rows gather the all-ones
        # AND identity (slot 0); padded batch rows gather all-zero (slot 1)
        wa = pool.bitmap_arena(key.words)
        widx = np.zeros((Bp, J), np.int32)
        widx[B:, :] = source.ResidentPool.BM_ZERO_SLOT
        for b, it in enumerate(items):
            for jj, (bk, wnp) in enumerate(it.bm_keys):
                widx[b, jj] = wa.slot(
                    bk, lambda w=wnp: _extend_words(w, key.words))
        words = _GATHER(wa.buffer(),
                        jnp.asarray(widx.reshape(-1))
                        ).reshape(Bp, J, key.words)
    elif pool is not None:
        rows = []
        for b in range(Bp):
            it = items[b] if b < B else None
            for j in range(J):
                if it is not None and j < len(it.bm_dev):
                    rows.append(_extend_words_dev(it.bm_dev[j], key.words))
                elif it is not None:
                    rows.append(pool.ones_row(key.words))   # AND identity
                else:
                    rows.append(pool.zeros_row(key.words))  # popcount 0
        words = _stack_rows(rows).reshape(Bp, J, key.words)
    else:
        # real rows pad missing terms with all-ones (AND identity); padded
        # batch rows — and every real row's words past its own W — stay
        # all-zero so their popcount contribution is 0
        wnp = np.zeros((Bp, J, key.words), dtype=np.uint32)
        for b, it in enumerate(items):
            wr = it.bm_words.shape[1]
            wnp[b, :, :wr] = 0xFFFFFFFF
            wnp[b, : it.bm_words.shape[0], :wr] = it.bm_words
        words = jnp.asarray(wnp)
    return words, Bp, J


def _launch_bitmap_group(key: GroupKey, items: list[_Item], pool,
                         stats: dict | None, timings=None):
    t0 = time.perf_counter()
    words, Bp, J = _assemble_bitmap(key, items, pool)
    if stats is not None:
        stats.setdefault("signatures", set()).add(("bm", key, Bp, J))
    t1 = time.perf_counter()
    out = _bitmap_and_program(words)
    if timings is not None:
        t2 = time.perf_counter()
        timings.assemble += t1 - t0
        timings.dispatch += t2 - t1
    return out


def _chunk_size(key: GroupKey, items: list[_Item],
                max_group_size: int) -> int:
    """Items per device program: flat cap ∧ operand-int budget (so huge
    J·N fold stacks shrink the batch instead of exploding device memory).
    Fused keys budget at their pinned arity ceilings."""
    if key.kind == "bitmap":
        J = (key.fused[0] if key.fused else
             max((it.bm_words.shape[0] if it.bm_words is not None
                  else len(it.bm_dev)) for it in items))
        per_item = J * key.words
    else:
        if key.fused:
            J, Jb, Jp = key.fused
        else:
            J = max(len(it.folds) for it in items)
            Jb = max((it.bm_words.shape[0] if it.bm_words is not None
                      else len(it.bm_dev) if it.bm_dev is not None else 0)
                     for it in items)
        per_item = J * key.n_bucket + key.m_bucket + Jb * key.words
        if key.packed is not None:
            k_pad, t_pad, c_pad, e_pad, rows, _ = key.packed
            if not key.fused:
                Jp = max(len(it.psrc) for it in items)
            # compressed words + per-block metadata + the partial decode
            # buffer the program materializes (c_pad blocks of rows×128)
            per_item += Jp * (t_pad * 128 + 3 * k_pad + c_pad
                              + 2 * e_pad + c_pad * rows * 128)
    return max(1, min(max_group_size, GROUP_INT_BUDGET // max(per_item, 1)))


# --------------------------------------------------------------------------
# megagroup fusion: collapse per-batch dispatch count (DESIGN.md §2.10)
# --------------------------------------------------------------------------

def _pow2_ceil(x: int) -> int:
    """Next power of two ≥ x (0 stays 0).  Fused arity ceilings are
    bucketed so the fused signature does not drift with each batch's exact
    arity mix."""
    return its.pow2_bucket(x, floor=1) if x > 0 else 0


class FusionPlan:
    """Sticky fused-dimension ceilings, one entry per signature family.

    Fused operand shapes are maxima over a batch's member groups; left
    alone they would drift batch to batch (a batch that happens to lack
    the longest list would compile a second, slightly smaller program).
    The plan makes ceilings *monotone*: every batch raises its family's
    sticky dims to at least everything previously seen, so fused
    signatures converge to a fixed point within the first few batches —
    which is what lets ``warmup`` reach that fixed point before serving
    starts.  Create one plan per serving session and pass it to every
    execute call (a fresh plan per call still fuses, it just re-derives
    ceilings per batch)."""

    def __init__(self):
        self.dims: dict[tuple, list[int]] = {}

    def raised(self, famid: tuple, dims: tuple) -> tuple:
        cur = self.dims.get(famid)
        if cur is None:
            self.dims[famid] = cur = list(dims)
        else:
            for i, d in enumerate(dims):
                if d > cur[i]:
                    cur[i] = d
        return tuple(cur)

    def covers(self, famid: tuple, dims: tuple) -> bool:
        """Read-only peek: would ``raised(famid, dims)`` change anything?
        True iff the family is known and every dim is within its sticky
        ceiling — i.e. fusing a batch with these family dims launches only
        already-established fused signatures."""
        cur = self.dims.get(famid)
        return cur is not None and all(d <= c for d, c in zip(dims, cur))


def _families(groups: dict[GroupKey, list[_Item]]) -> dict[tuple, list]:
    """Bucket scheduled groups into signature families — (kind, packed
    block geometry) — the identity ``fuse_groups`` coarsens within."""
    fams: dict[tuple, list] = {}
    for key, items in groups.items():
        geom = None if key.packed is None else (key.packed[4], key.packed[5])
        fams.setdefault((key.kind, geom), []).append((key, items))
    return fams


def _family_dims(kind: str, geom, members: list) -> tuple:
    """Ceiling dims of one family over its member (key, items) pairs — the
    shared derivation ``fuse_groups`` raises through the sticky plan and
    ``plan_covers`` peeks at.  Layout: bitmap -> (W, Jb); svs ->
    (M, N, W, J, Jb[, k, t, c, e, Jp])."""
    items = [it for _, mi in members for it in mi]
    if kind == "bitmap":
        return (max(k.words for k, _ in members),
                _pow2_ceil(max(_n_bitmaps(it) for it in items)))
    dims = [max(k.m_bucket for k, _ in members),
            max(k.n_bucket for k, _ in members),
            max(k.words for k, _ in members),
            _pow2_ceil(max(len(it.folds) for it in items)),
            _pow2_ceil(max(_n_bitmaps(it) for it in items))]
    if geom is not None:
        dims += [max(k.packed[i] for k, _ in members) for i in range(4)]
        dims.append(_pow2_ceil(max(len(it.psrc) for it in items)))
    return tuple(dims)


def plan_covers(groups: dict[GroupKey, list[_Item]],
                plan: FusionPlan | None) -> bool:
    """Family-signature admission predicate (DESIGN.md §2.11): True iff
    fusing ``groups`` under ``plan`` would not raise any sticky family
    ceiling — i.e. the batch launches only fused signatures the plan has
    already established (after ``warmup``, ones that are already
    compiled).  The continuous-batching server uses this to account for
    admission decisions that would stall a latency-bound batch on a
    compile; it never changes the plan (read-only peek, evaluate BEFORE
    ``fuse_groups`` makes the ceilings monotone over this batch)."""
    if plan is None:
        return False
    return all(plan.covers((kind, geom), _family_dims(kind, geom, members))
               for (kind, geom), members in _families(groups).items())


def fuse_groups(groups: dict[GroupKey, list[_Item]],
                plan: FusionPlan | None = None,
                stats: dict | None = None) -> dict[GroupKey, list[_Item]]:
    """Coarsen scheduled GroupKeys into signature *families* and merge each
    family's items along the batch-row axis, so a mixed batch launches
    O(#families) fused device programs instead of one per signature.

    A family is (kind, packed block geometry).  Every shape dimension that
    is NOT part of the family identity — the M/N/W buckets, the packed
    k/t/c/e pads, and the pow2-bucketed fold/probe arities — is raised to
    the family ceiling (max over member groups, further raised by the
    sticky ``plan``).  This is sound because group programs are
    row-independent and padding is inert (module invariants): a row
    assembled into a wider slot meets sentinel filler, masked no-op folds,
    all-pad packed layouts, and identity bitmap rows, none of which change
    its result.  ``tests/test_fusion.py`` pins fused == unfused ==
    sequential byte for byte across backends, corpora, and shard counts.

    Fused svs programs force ``algo='gallop'``: the tiled ratio rule was
    derived per scheduled group, family ceilings inflate M against it, and
    the vmapped tile walk loses its data-dependent early exit entirely at
    ceiling shapes, while galloping stays O(M log N) per row regardless of
    padding.  Groups without packed folds keep their own (svs, None)
    family rather than joining a packed one — inactive packed slots would
    still pay the partial decode for every row.

    The candidate-block bucket ``c_pad`` is the one ceiling that costs
    real decode work (each row partially decodes c_pad blocks whether it
    needs them or not), so it is batch-derived and only the plan's
    stickiness widens it: fused decode volume is bounded by the observed
    workload, never by the index size.
    """
    fused: dict[GroupKey, list[_Item]] = {}
    for (kind, geom), members in _families(groups).items():
        items = [it for _, mi in members for it in mi]
        dims = _family_dims(kind, geom, members)
        if plan is not None:
            dims = plan.raised((kind, geom), dims)
        if kind == "bitmap":
            w, jb = dims
            fkey = GroupKey("bitmap", 0, 0, w, "-", fused=(jb,))
        else:
            m, n, w, j, jb = dims[:5]
            packed = (tuple(dims[5:9]) + geom) if geom is not None else None
            jp = dims[9] if geom is not None else 0
            fkey = GroupKey("svs", m, n, w, "gallop", packed,
                            fused=(j, jb, jp))
        fused[fkey] = items
    if stats is not None:
        stats["n_sched_groups"] = (stats.get("n_sched_groups", 0)
                                   + len(groups))
        stats["n_fused_groups"] = (stats.get("n_fused_groups", 0)
                                   + len(fused))
    return fused


def _compile_count() -> int:
    """Total jit-cache entries behind the group programs, the arena
    gather, and every row stacker (the arena-fallback path compiles stack
    programs mid-serving, e.g. when a cache fill drops a row's host copy)
    — the compiles ``warmup`` is meant to front-load.  Uses jax's
    (private, guarded) ``_cache_size``; returns 0 when the running jax
    does not expose it, which only disables the ``n_compiles``
    *reporting*, never correctness."""
    n = 0
    for fn in (_svs_program, _bitmap_and_program, _GATHER, *_STACKERS):
        size = getattr(fn, "_cache_size", None)
        if size is not None:
            try:
                n += size()
            except Exception:
                pass
    return n


# --------------------------------------------------------------------------
# launch / collect (the pipeline split) and the public entry point
# --------------------------------------------------------------------------

@dataclasses.dataclass
class PendingBatch:
    """Dispatched-but-unmaterialized batch: device result handles per group
    chunk.  JAX async dispatch means the device is (or will be) executing
    these while the host moves on; ``collect_batch`` blocks on them."""
    n_queries: int
    max_results: int
    launched: list          # [(key, chunk_items, vals_dev, counts_dev)]
    stats: dict | None


def launch_groups(groups: dict[GroupKey, list[_Item]], *, n_queries: int,
                  backend: str = "jax", max_results: int = 1 << 16,
                  max_group_size: int = MAX_GROUP_SIZE,
                  pool: "source.ResidentPool | None" = None,
                  stats: dict | None = None, timings=None) -> PendingBatch:
    """Dispatch one device program per (possibly fused) group chunk without
    materializing any result — the host returns as soon as everything is
    enqueued.  ``timings`` (a ``pipeline.StageTimings``) attributes operand
    assembly vs program enqueue wall time."""
    launched = []
    n_dispatches = 0
    c0 = _compile_count() if stats is not None else 0
    for key, items in groups.items():
        step = _chunk_size(key, items, max_group_size)
        for lo in range(0, len(items), step):
            chunk = items[lo: lo + step]
            if key.kind == "bitmap":
                vals, counts = _launch_bitmap_group(key, chunk, pool, stats,
                                                    timings)
            else:
                vals, counts = _launch_svs_group(key, chunk, backend, pool,
                                                 stats, timings)
            launched.append((key, chunk, vals, counts))
            n_dispatches += 1
    accumulate_launch_stats(stats, groups, n_dispatches)
    if stats is not None:
        stats["n_compiles"] = (stats.get("n_compiles", 0)
                               + _compile_count() - c0)
    return PendingBatch(n_queries=n_queries, max_results=max_results,
                        launched=launched, stats=stats)


def accumulate_launch_stats(stats: dict | None, groups, n_dispatches: int):
    """Accumulate per-launch counters (like the decoded_ints/skip_folds
    counters) so one stats dict can span a chunked run of many batches —
    shared by the single-device and sharded launchers.  ``n_programs``
    stays an alias of ``n_dispatches`` (the historical name; both count
    device program launches — distinct *compiled* programs are
    ``len(stats['signatures'])``)."""
    if stats is None:
        return
    for k, v in (("n_groups", len(groups)), ("n_dispatches", n_dispatches),
                 ("n_programs", n_dispatches),
                 ("n_items", sum(len(v) for v in groups.values()))):
        stats[k] = stats.get(k, 0) + v


def collect_batch(pending: PendingBatch) -> list[QueryResult]:
    """Materialize a launched batch (blocks on the device) and re-assemble
    per-query results in part order — byte-identical to ``engine.query``.
    svs rows arrive masked-but-uncompacted (valid entries are the
    non-sentinel slots, still sorted); extraction happens here on host."""
    per_query: list[list[tuple[int, np.ndarray]]] = \
        [[] for _ in range(pending.n_queries)]
    counts = [0] * pending.n_queries
    for key, chunk, vals_dev, counts_dev in pending.launched:
        vals = np.asarray(vals_dev)
        cnts = np.asarray(counts_dev)
        for b, it in enumerate(chunk):
            if it is None:          # padded slot (sharded shard-slice pad)
                continue
            cnt = int(cnts[b])
            counts[it.qi] += cnt
            if not cnt:
                continue
            if key.kind == "bitmap":
                docs = bm.extract_np(vals[b])
            else:
                row = vals[b]
                docs = row[row != its.SENTINEL]
            per_query[it.qi].append((it.pi, docs.astype(np.int64)
                                     + it.doc_lo))
    out = []
    for qi in range(pending.n_queries):
        chunks = [d for _, d in sorted(per_query[qi], key=lambda x: x[0])]
        docs = (np.concatenate(chunks) if chunks
                else np.zeros(0, np.int64))[: pending.max_results]
        out.append(QueryResult(count=counts[qi], docs=docs))
    return out


def execute_batch(index: HybridIndex, queries: list[list[int]], *,
                  backend: str = "jax", max_results: int = 1 << 16,
                  max_group_size: int = MAX_GROUP_SIZE, cache=None,
                  skip: bool = True, stats: dict | None = None,
                  pool: "source.ResidentPool | None" = None,
                  fuse: bool = True, plan: FusionPlan | None = None
                  ) -> list[QueryResult]:
    """Answer a batch of conjunctive queries; results are element-for-element
    identical to ``engine.query`` run per query.

    backend: 'jax' (searchsorted/tile-merge) or 'pallas' (galloping kernel).
    skip: False forces full decode of every fold list (the pre-skip
    behavior, kept for A/B benchmarking of the partial-decode win).
    pool: optional ResidentPool — operands are served from (and staged
    into) the device-resident index; group assembly becomes index-gathering
    over resident buffers instead of per-batch decode + padding + H2D.
    fuse: coarsen the scheduled groups into megagroup families so the
    batch launches O(#families) device programs (DESIGN.md §2.10); False
    keeps one program per scheduled signature (the pre-fusion behavior,
    kept for A/B benchmarking — results are byte-identical either way).
    plan: optional FusionPlan carrying sticky family ceilings across calls
    (pass one per serving session so fused signatures converge; None
    re-derives ceilings per batch).
    stats: optional dict, filled with scheduler counters (n_groups,
    n_sched_groups/n_fused_groups, n_dispatches, n_compiles, n_items,
    decoded_ints, skip_folds, resident_hits, layout_hits/misses) for
    introspection.
    """
    assert backend in ("jax", "pallas"), backend
    groups = schedule(index, queries, cache=cache, skip=skip, stats=stats,
                      pool=pool)
    if fuse:
        groups = fuse_groups(groups, plan=plan, stats=stats)
    pending = launch_groups(groups, n_queries=len(queries), backend=backend,
                            max_results=max_results,
                            max_group_size=max_group_size, pool=pool,
                            stats=stats)
    return collect_batch(pending)


# --------------------------------------------------------------------------
# AOT signature warmup (DESIGN.md §2.10)
# --------------------------------------------------------------------------

def synth_warmup_queries(index: HybridIndex, n: int, seed: int = 0,
                         arities=(2, 3, 4, 5)) -> list[list[int]]:
    """Synthesize a warmup query sample from the index's own term stats —
    the fallback when no representative slice of the real stream is at
    hand.  Seeds draw from the shortest tercile of list terms (the seed of
    a real conjunctive query is its *shortest* list, so sampling seeds
    uniformly would sticky the plan's M ceiling to the longest list and
    permanently oversize every fused program); the remaining positions
    draw uniformly so fold/bitmap/packed families all get exercised."""
    rng = np.random.default_rng(seed)
    lens: dict[int, int] = {}
    for part in index.parts:            # aggregate over ALL parts: a term
        for tid, tp in part.terms.items():   # may be empty in part 0 only
            if tp.kind != "empty":
                lens[tid] = lens.get(tid, 0) + tp.n
    terms = sorted(lens.items(), key=lambda t: t[1])
    if not terms:
        return []
    ids = [t for t, _ in terms]
    short = ids[: max(len(ids) // 3, 1)]
    queries = []
    for i in range(n):
        a = arities[i % len(arities)]
        q = {int(rng.choice(short))}
        while len(q) < min(a, len(ids)):
            q.add(int(rng.choice(ids)))
        queries.append(sorted(q))
    return queries


def warm_to_fixed_point(run_fn, max_passes: int = 4
                        ) -> tuple[int, int, bool]:
    """Repeat ``run_fn(stats)`` until a pass adds no new program signature
    (cache fills, pool staging, and sticky plan ceilings all change how
    batches compile between passes).  Returns (n_signatures, passes,
    converged) — the one convergence rule shared by ``warmup`` and
    serve.py's warm loops.  ``converged`` is False when the loop ran out
    of ``max_passes`` while the last pass was still adding signatures: a
    timed loop after a non-converged warm pays hidden compiles that
    ``n_compiles == 0`` assertions on *later* batches silently miss, so
    callers must surface it (serve.py / ``warmup`` warn)."""
    stats: dict = {}
    seen = -1
    passes = 0
    converged = False
    for _ in range(max_passes):
        run_fn(stats)
        passes += 1
        n_sigs = len(stats.get("signatures", ()))
        if n_sigs == seen:
            converged = True
            break
        seen = n_sigs
    return len(stats.get("signatures", ())), passes, converged


def warmup(index: HybridIndex, queries: list[list[int]] | None = None, *,
           plan: FusionPlan, batch_size: int = 32, backend: str = "jax",
           pool: "source.ResidentPool | None" = None, cache=None,
           skip: bool = True, max_group_size: int = MAX_GROUP_SIZE,
           max_passes: int = 4, seed: int = 0) -> dict:
    """AOT signature warmup: precompile the fused family ladder before the
    first real batch, so steady-state serving never compiles.

    Runs the fused pipeline over ``queries`` — a representative sample of
    the expected workload; pass a slice of the real stream when one is at
    hand, else ``synth_warmup_queries`` fabricates one from the index term
    stats — repeating until no new program signature appears.  Repetition
    matters twice over: pool staging and cache fills change how terms
    resolve between passes (decoded vs packed), and the sticky ``plan``
    ceilings only reach their fixed point once a pass stops raising them.
    Every compile this triggers is one the first serving batches would
    otherwise have stalled on (a realistic mixed batch used to pay the
    whole signature ladder; fused it pays O(#families) compiles, all of
    them front-loaded here).

    Returns ``{"n_compiles", "n_signatures", "passes", "converged",
    "time_s"}`` — the compile count is measured from jax's jit caches, and
    a steady-state serve loop after warmup should report ``n_compiles ==
    0``.  ``converged`` is False when the signature ladder was still
    growing at ``max_passes`` (see ``warm_to_fixed_point``) — the
    zero-compile steady-state claim does not hold then, and callers
    should warn."""
    t0 = time.perf_counter()
    c0 = _compile_count()
    if queries is None:
        queries = synth_warmup_queries(index, 2 * batch_size, seed=seed)

    def one_pass(stats):
        for lo in range(0, len(queries), batch_size):
            execute_batch(index, queries[lo: lo + batch_size],
                          backend=backend, cache=cache, skip=skip,
                          pool=pool, fuse=True, plan=plan,
                          max_group_size=max_group_size, stats=stats)

    n_signatures, passes, converged = warm_to_fixed_point(one_pass,
                                                          max_passes)
    return {"n_compiles": _compile_count() - c0,
            "n_signatures": n_signatures,
            "passes": passes,
            "converged": converged,
            "time_s": time.perf_counter() - t0}
