"""Double-buffered pipelined serving (DESIGN.md §2.8).

``batch.execute_batch`` is host→device serialized: the host schedules and
stacks a batch, dispatches its device programs, then *blocks* materializing
the results before it even looks at the next batch — so the device idles
while the host schedules, and the host idles while the device executes.
This module overlaps the two, the same way the paper overlaps decoding with
intersection: JAX dispatch is asynchronous, so once batch k's programs are
enqueued the host can immediately schedule and dispatch batch k+1 (and
k+2, … up to ``depth``) while the device chews through k.  ``depth`` bounds
the number of un-collected batches in flight — each one pins its operand
and result buffers, so depth is a memory knob, not just a latency knob:

    depth 1   launch → collect, strictly serial (== execute_batch)
    depth 2   classic double buffering: stage k+1 while k executes
    depth d   d-1 batches of slack for jittery schedule times

The pipeline composes with the device-resident index: with a
``source.ResidentPool`` the host stage is pure bookkeeping (bucketing +
skip-index searches + gathers of resident rows), which is exactly what lets
it hide under device execution.  Mutating shared state (pool staging, cache
fills, layout memo) happens in schedule order, so results are byte-identical
to ``execute_batch`` run batch by batch — the differential guarantee
``tests/test_pipeline.py`` locks in across depths, backends, and corpora.

Per-stage wall time is accounted into ``StageTimings``:

    stage     host scheduling: resolve/bucketing + candidate-block search
              (+ megagroup fusion, which is pure bookkeeping)
    assemble  operand assembly (arena gathers / host stacking + upload)
    dispatch  async program enqueue
    block     time spent blocked on device results at collect

``serve.py --pipeline N`` and ``bench_engine.py --profile`` report the
breakdown; ``block`` collapsing toward zero at depth ≥ 2 is the visible
signature of a hidden device.  The assemble/dispatch split is attributed
inside the launcher (``batch.launch_groups`` /
``shard.launch_groups_sharded`` accept the timings object); a custom
``launch_fn`` that ignores it simply leaves those two fields zero.

This module is DESIGN.md §2.8 (the pipelined half); the sharded executor
(DESIGN.md §2.9, ``repro.index.shard``) reuses this exact loop through the
``schedule_fn``/``launch_fn`` hooks, fanning each launch across the shard
devices while in-flight tracking, depth bounding, and stage accounting
stay shared.  Invariants callers rely on:

  * **Byte-identical to the unpipelined path** — mutations of shared
    state (pool staging, cache fills, layout memo, arena growth,
    fusion-plan ceilings) happen in schedule order, so results equal
    ``execute_batch`` run chunk by chunk, and therefore ``engine.query``
    per query, at every depth.
  * **Depth bounds memory** — at most ``depth`` un-collected batches pin
    operand/result buffers; depth 1 is strictly serial.
  * **Collect order is submission order** — results return in query
    order regardless of which device finished first.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

from repro.index import batch as batch_lib
from repro.index.builder import HybridIndex
from repro.index.engine import QueryResult


@dataclasses.dataclass
class StageTimings:
    """Cumulative per-stage wall time across a pipelined run."""
    stage: float = 0.0          # host scheduling (resolve + bucket + fuse)
    assemble: float = 0.0       # operand assembly (gathers / stack + H2D)
    dispatch: float = 0.0       # async program enqueue
    block: float = 0.0          # blocked on device results
    batches: int = 0

    def as_dict(self) -> dict:
        return {"stage_s": self.stage, "assemble_s": self.assemble,
                "dispatch_s": self.dispatch, "block_s": self.block,
                "batches": self.batches}


def execute_pipelined(index: HybridIndex, queries: list[list[int]], *,
                      batch_size: int, depth: int = 2,
                      backend: str = "jax", max_results: int = 1 << 16,
                      max_group_size: int = batch_lib.MAX_GROUP_SIZE,
                      cache=None, skip: bool = True, pool=None,
                      fuse: bool = True,
                      plan: "batch_lib.FusionPlan | None" = None,
                      stats: dict | None = None,
                      timings: StageTimings | None = None,
                      schedule_fn=None, launch_fn=None
                      ) -> list[QueryResult]:
    """Answer ``queries`` in ``batch_size`` chunks with up to ``depth``
    batches in flight; results are byte-identical to ``execute_batch`` run
    chunk by chunk (and therefore to ``engine.query`` per query).

    ``fuse``/``plan`` mirror ``execute_batch``: each chunk's scheduled
    groups coarsen into megagroup families before launch (DESIGN.md
    §2.10).  A single sticky plan is created for the whole run when none
    is passed, so fused signatures converge across chunks.

    ``schedule_fn(chunk, stats) -> groups`` and ``launch_fn(groups,
    n_queries, stats) -> PendingBatch`` override the two pipeline stages —
    the sharded executor (``repro.index.shard``, DESIGN.md §2.9) plugs in
    per-shard group assembly and fan-out dispatch here while reusing this
    loop's in-flight tracking and stage accounting unchanged.  Defaults are
    the single-device ``batch`` scheduler/launcher."""
    assert depth >= 1, depth
    assert batch_size >= 1, batch_size
    if fuse and plan is None:
        plan = batch_lib.FusionPlan()
    if schedule_fn is None:
        def schedule_fn(chunk, stats):
            groups = batch_lib.schedule(index, chunk, cache=cache,
                                        skip=skip, stats=stats, pool=pool)
            if fuse:
                groups = batch_lib.fuse_groups(groups, plan=plan,
                                               stats=stats)
            return groups
    if launch_fn is None:
        def launch_fn(groups, n_queries, stats):
            return batch_lib.launch_groups(
                groups, n_queries=n_queries, backend=backend,
                max_results=max_results, max_group_size=max_group_size,
                pool=pool, stats=stats, timings=timings)
    inflight: deque[batch_lib.PendingBatch] = deque()
    out: list[QueryResult] = []

    def drain_one():
        t0 = time.perf_counter()
        out.extend(batch_lib.collect_batch(inflight.popleft()))
        if timings is not None:
            timings.block += time.perf_counter() - t0

    for lo in range(0, len(queries), batch_size):
        chunk = queries[lo: lo + batch_size]
        t0 = time.perf_counter()
        groups = schedule_fn(chunk, stats)
        t1 = time.perf_counter()
        pending = launch_fn(groups, len(chunk), stats)
        if timings is not None:
            timings.stage += t1 - t0
            timings.batches += 1
        inflight.append(pending)
        while len(inflight) >= depth:
            drain_one()
    while inflight:
        drain_one()
    return out
