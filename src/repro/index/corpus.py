"""Synthetic corpus + query log matched to the paper's Table 2 marginals.

No TREC data is available offline, so we generate: (a) per-term posting lists
whose documents follow the ClusterData process (sorted-run structure like the
URL-sorted GOV2), and (b) a query log whose term-count distribution and
per-position posting-list lengths are fitted to the paper's Table 2 statistics
(scaled to the synthetic corpus size).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.clusterdata import clusterdata

# Table 2(a), ClueWeb09: {terms: (query %, [avg hits per term, thousands])}
TABLE2_CLUEWEB = {
    2: (19.8, [380, 2600]),
    3: (32.5, [400, 1500, 5100]),
    4: (26.3, [480, 1400, 3200, 8100]),
    5: (13.2, [420, 1200, 2600, 4800, 10000]),
    6: (4.9, [350, 1000, 2100, 3700, 6500, 13000]),
    7: (1.7, [390, 1100, 2100, 3400, 5200, 7300, 13000]),
}
TABLE2_DOCS = 50_000_000    # ClueWeb09 corpus size the marginals refer to


@dataclasses.dataclass
class Corpus:
    n_docs: int
    postings: list[np.ndarray]        # term id → sorted doc ids
    queries: list[list[int]]          # query → term ids (sorted by length)

    @property
    def n_terms(self) -> int:
        return len(self.postings)


def synthesize(n_docs: int = 1 << 20, n_queries: int = 200,
               seed: int = 0, table=TABLE2_CLUEWEB,
               shared_vocab: bool = False, zipf_s: float = 1.1,
               vocab_per_bucket: int = 6) -> Corpus:
    """Build posting lists + queries scaled from the paper's Table 2.

    shared_vocab=False keeps the original behavior: every query position
    mints a fresh term id, so a DecodeCache only helps across exact query
    repeats.  shared_vocab=True draws term ids from a shared vocabulary
    instead: per length bucket (~pow2 of the target posting count) at most
    ``vocab_per_bucket`` terms exist, and repeat picks follow a Zipf(s)
    rank distribution over the bucket — the head-heavy term reuse real
    query logs show, which is what gives the DecodeCache a realistic hit
    rate (ROADMAP: cross-query decode reuse).
    """
    rng = np.random.default_rng(seed)
    scale = n_docs / TABLE2_DOCS
    universe_bits = int(np.ceil(np.log2(n_docs)))

    # desired per-position lengths (thousands → docs, scaled)
    term_sizes: list[int] = []
    queries: list[list[int]] = []
    probs = np.array([p for _, (p, _) in table.items()])
    probs = probs / probs.sum()
    n_terms_options = list(table.keys())
    vocab: dict[int, list[int]] = {}        # length bucket → term ids
    for _ in range(n_queries):
        k = int(rng.choice(n_terms_options, p=probs))
        lens = table[k][1]
        tids: list[int] = []
        for ln in lens:
            target = max(int(ln * 1000 * scale *
                             float(np.exp(rng.normal(0, 0.35)))), 4)
            target = min(target, n_docs - 1)
            if not shared_vocab:
                tids.append(len(term_sizes))
                term_sizes.append(target)
                continue
            bucket = vocab.setdefault(int(np.log2(target)), [])
            pool = [t for t in bucket if t not in tids]
            if len(bucket) < vocab_per_bucket or not pool:
                tid = len(term_sizes)
                term_sizes.append(target)
                bucket.append(tid)
            else:
                # Zipf over creation rank: early terms are the hot head
                w = np.array([1.0 / (i + 1) ** zipf_s
                              for i, t in enumerate(bucket) if t in pool])
                tid = pool[int(rng.choice(len(pool), p=w / w.sum()))]
            tids.append(tid)
        queries.append(tids)

    postings = [clusterdata(rng, sz, universe_bits) for sz in term_sizes]
    postings = [p[p < n_docs] for p in postings]
    return Corpus(n_docs=n_docs, postings=postings, queries=queries)
