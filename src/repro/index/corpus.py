"""Synthetic corpus + query log matched to the paper's Table 2 marginals.

No TREC data is available offline, so we generate: (a) per-term posting lists
whose documents follow the ClusterData process (sorted-run structure like the
URL-sorted GOV2), and (b) a query log whose term-count distribution and
per-position posting-list lengths are fitted to the paper's Table 2 statistics
(scaled to the synthetic corpus size).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.clusterdata import clusterdata

# Table 2(a), ClueWeb09: {terms: (query %, [avg hits per term, thousands])}
TABLE2_CLUEWEB = {
    2: (19.8, [380, 2600]),
    3: (32.5, [400, 1500, 5100]),
    4: (26.3, [480, 1400, 3200, 8100]),
    5: (13.2, [420, 1200, 2600, 4800, 10000]),
    6: (4.9, [350, 1000, 2100, 3700, 6500, 13000]),
    7: (1.7, [390, 1100, 2100, 3400, 5200, 7300, 13000]),
}
TABLE2_DOCS = 50_000_000    # ClueWeb09 corpus size the marginals refer to


@dataclasses.dataclass
class Corpus:
    n_docs: int
    postings: list[np.ndarray]        # term id → sorted doc ids
    queries: list[list[int]]          # query → term ids (sorted by length)

    @property
    def n_terms(self) -> int:
        return len(self.postings)


def synthesize(n_docs: int = 1 << 20, n_queries: int = 200,
               seed: int = 0, table=TABLE2_CLUEWEB) -> Corpus:
    """Build posting lists + queries scaled from the paper's Table 2."""
    rng = np.random.default_rng(seed)
    scale = n_docs / TABLE2_DOCS
    universe_bits = int(np.ceil(np.log2(n_docs)))

    # desired per-position lengths (thousands → docs, scaled)
    counts = np.array([c for _, (_, lens) in table.items() for c in lens])
    term_sizes: list[int] = []
    queries: list[list[int]] = []
    probs = np.array([p for _, (p, _) in table.items()])
    probs = probs / probs.sum()
    n_terms_options = list(table.keys())
    for _ in range(n_queries):
        k = int(rng.choice(n_terms_options, p=probs))
        lens = table[k][1]
        tids = []
        for ln in lens:
            target = max(int(ln * 1000 * scale *
                             float(np.exp(rng.normal(0, 0.35)))), 4)
            target = min(target, n_docs - 1)
            tids.append(len(term_sizes))
            term_sizes.append(target)
        queries.append(tids)

    postings = [clusterdata(rng, sz, universe_bits) for sz in term_sizes]
    postings = [p[p < n_docs] for p in postings]
    return Corpus(n_docs=n_docs, postings=postings, queries=queries)
