"""Conjunctive query engine: SvS over compressed lists + bitmap probes
(paper §5–§6.7).

Pipeline per query, per index part — exactly the paper's:
  1. order terms by posting length (SvS),
  2. decode the two shortest compressed lists, intersect with the
     ratio-dispatched SIMD algorithm (V1-tile / galloping / packed-gallop),
  3. fold in remaining compressed lists (against the shrinking candidate set),
  4. probe candidate doc ids against each bitmap term,
  5. (all-bitmap queries) AND the bitmaps directly.

This module is the *sequential* path: one query at a time, with a host
round-trip between folds.  ``repro.index.batch`` is the batched path — a
shape-bucketed scheduler that groups queries into single device programs
(vmapped intersects, ``lax.scan``-fused SvS folds, batched bitmap probes)
and is the one to use under load; this module remains the reference the
batched path is differentially tested against.

Backend switch: set ``USE_KERNELS = True`` (or pass ``backend="pallas"`` to
the batch scheduler) to route large-ratio intersections through the Pallas
galloping kernel (``repro.kernels.ops.intersect_gallop``) instead of the
jnp searchsorted path.

JAX serving constraint: shapes are static, so decoded/padded lengths are
bucketed to powers of two (recompile count is O(log n_docs) per algorithm) —
the standard shape-bucketing pattern of real JAX serving systems.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.core import bitmap as bm
from repro.core import bitpack
from repro.core import codecs as codec_lib
from repro.core import intersect as its
from repro.index.builder import HybridIndex, IndexPart

USE_KERNELS = False     # route big-ratio intersects through the Pallas kernel


class DecodeCache:
    """LRU cache of decoded (padded) posting lists — the paper's Table 4
    regime: SvS over *uncompressed* lists.  Real engines decode hot lists
    once, not per query; capacity bounds working-set memory like the paper's
    L3-sized partitions bound theirs."""

    def __init__(self, capacity_ints: int = 1 << 24):
        self.capacity = capacity_ints
        self._store: dict[int, tuple] = {}
        self._size = 0
        self._tick = 0

    def get(self, key):
        hit = self._store.get(key)
        if hit is None:
            return None
        self._tick += 1
        self._store[key] = (hit[0], hit[1], self._tick)
        return hit[0], hit[1]

    def put(self, key, vals, n):
        old = self._store.get(key)
        if old is not None:
            self._size -= int(old[0].shape[0])
        self._size += int(vals.shape[0])
        self._tick += 1
        self._store[key] = (vals, n, self._tick)
        while self._size > self.capacity and len(self._store) > 1:
            oldest = min(self._store, key=lambda k: self._store[k][2])
            self._size -= int(self._store[oldest][0].shape[0])
            del self._store[oldest]


@dataclasses.dataclass
class QueryResult:
    count: int
    docs: np.ndarray        # global doc ids (may be truncated to cap)


def _decode_padded(codec, tp) -> tuple[jnp.ndarray, int]:
    from repro.core import varint as varint_lib
    if isinstance(tp.payload, bitpack.PackedList):
        vals = np.asarray(bitpack.decode_bucketed(tp.payload))[: tp.n]
        vals = vals.astype(np.int32)
    elif isinstance(tp.payload, varint_lib.VarintList):
        vals = varint_lib.decode(tp.payload).astype(np.int32)   # tail codec
    else:
        vals = np.asarray(codec.decode(tp.payload))[: tp.n].astype(np.int32)
    size = its.pow2_bucket(tp.n)
    return jnp.asarray(its.pad_to(vals, size)), tp.n


def decode_term(part: IndexPart, tid: int, tp, codec, cache=None):
    """Decode one term's posting list to (padded int32 vals, count), going
    through the DecodeCache when one is supplied.  Shared by the sequential
    path below and the batched scheduler in ``repro.index.batch``."""
    if cache is not None:
        hit = cache.get((part.uid, tid))
        if hit is not None:
            return hit
    out = _decode_padded(codec, tp)
    if cache is not None:
        cache.put((part.uid, tid), out[0], out[1])
    return out


def _intersect_part(part: IndexPart, term_ids: list[int], codec,
                    use_packed_gallop: bool = True, cache=None):
    """Returns (padded candidate vals, count) or ('bitmap', words)."""
    def decode(tid, tp):
        return decode_term(part, tid, tp, codec, cache=cache)

    tps = [part.terms[t] for t in term_ids]
    if any(tp.kind == "empty" for tp in tps):
        return None, 0
    lists = sorted((tp for tp in tps if tp.kind == "list"), key=lambda t: t.n)
    bitmaps = [tp for tp in tps if tp.kind == "bitmap"]

    if not lists:
        words = bitmaps[0].payload
        for tp in bitmaps[1:]:
            words = np.asarray(bm.bitmap_and(jnp.asarray(words),
                                             jnp.asarray(tp.payload)))
        return ("bitmap", words), int(bm.popcount(jnp.asarray(words)))

    id_of = {id(tp): t for t, tp in zip(term_ids, tps)}
    r, r_count = decode(id_of[id(lists[0])], lists[0])
    for tp in lists[1:]:
        if r_count == 0:
            break
        ratio = tp.n / max(r_count, 1)
        if (cache is None and use_packed_gallop
                and isinstance(tp.payload, bitpack.PackedList)
                and ratio > its.TILED_MAX_RATIO):
            # paper's galloping+skip: search the block-max index, decode only
            # candidate blocks — the long list is never fully decoded.
            mask = its.intersect_packed(r, tp.payload)
        elif USE_KERNELS and ratio > its.TILED_MAX_RATIO:
            from repro.kernels import ops as kernel_ops
            f, _ = decode(id_of[id(tp)], tp)
            mask = kernel_ops.intersect_gallop(r, f)
        else:
            f, _ = decode(id_of[id(tp)], tp)
            mask = its.intersect_auto(r, f, r_count, tp.n)
        r, cnt = its.compact(r, mask)
        r_count = int(cnt)
    for tp in bitmaps:
        if r_count == 0:
            break
        mask = bm.probe(jnp.asarray(tp.payload), r, r != its.SENTINEL)
        r, cnt = its.compact(r, mask)
        r_count = int(cnt)
    return ("list", r), r_count


def query(index: HybridIndex, term_ids: list[int],
          max_results: int = 1 << 16, cache: "DecodeCache | None" = None
          ) -> QueryResult:
    """cache: optional DecodeCache → the paper's Table 4 regime (SvS over
    already-decoded lists); None → Table 5 regime (decode per query)."""
    codec = codec_lib.get_codec(index.codec_name)
    total = 0
    out_docs = []
    for part in index.parts:
        res, cnt = _intersect_part(part, term_ids, codec, cache=cache)
        total += cnt
        if cnt and res is not None:
            kind, payload = res
            if kind == "list":
                docs = np.asarray(payload)[:cnt]
            else:
                docs = bm.extract_np(payload)
            out_docs.append(docs.astype(np.int64) + part.doc_lo)
    docs = (np.concatenate(out_docs) if out_docs
            else np.zeros(0, np.int64))[:max_results]
    return QueryResult(count=total, docs=docs)


def brute_force(postings: list[np.ndarray], term_ids: list[int]) -> np.ndarray:
    """Oracle: numpy set intersection over the raw posting lists."""
    res = postings[term_ids[0]]
    for t in term_ids[1:]:
        res = np.intersect1d(res, postings[t])
    return res
