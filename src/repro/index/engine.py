"""Conjunctive query engine: SvS over compressed lists + bitmap probes
(paper §5–§6.7).

Pipeline per query, per index part — exactly the paper's:
  1. order terms by posting length (SvS),
  2. decode the two shortest compressed lists, intersect with the
     ratio-dispatched SIMD algorithm (V1-tile / galloping / packed-gallop),
  3. fold in remaining compressed lists (against the shrinking candidate set),
  4. probe candidate doc ids against each bitmap term,
  5. (all-bitmap queries) AND the bitmaps directly.

This module is the *sequential* path: one query at a time, with a host
round-trip between folds.  ``repro.index.batch`` is the batched path — a
shape-bucketed scheduler that groups queries into single device programs
(vmapped intersects, ``lax.scan``-fused SvS folds, batched bitmap probes)
and is the one to use under load; this module remains the reference the
batched path is differentially tested against.

Backend switch: set ``USE_KERNELS = True`` (or pass ``backend="pallas"`` to
the batch scheduler) to route large-ratio intersections through the Pallas
galloping kernel (``repro.kernels.ops.intersect_gallop``) instead of the
jnp searchsorted path.

JAX serving constraint: shapes are static, so decoded/padded lengths are
bucketed to powers of two (recompile count is O(log n_docs) per algorithm) —
the standard shape-bucketing pattern of real JAX serving systems.
"""

from __future__ import annotations

import dataclasses
import os
from collections import OrderedDict

import numpy as np
import jax.numpy as jnp

from repro.core import bitmap as bm
from repro.core import codecs as codec_lib
from repro.core import intersect as its
from repro.index import source
from repro.index.builder import HybridIndex, IndexPart

# route big-ratio intersects through the Pallas kernels; the
# REPRO_USE_KERNELS=1 env form is what CI's kernel-backend job flips so the
# whole sequential engine suite runs through the Pallas paths
USE_KERNELS = os.environ.get("REPRO_USE_KERNELS", "0") == "1"


class DecodeCache:
    """LRU cache of decoded (padded) posting lists — the paper's Table 4
    regime: SvS over *uncompressed* lists.  Real engines decode hot lists
    once, not per query; capacity bounds working-set memory like the paper's
    L3-sized partitions bound theirs.

    The store is an OrderedDict in recency order — get/put are O(1)
    ``move_to_end`` and eviction pops from the cold end (the old
    implementation re-scanned every key with ``min()`` per eviction, O(n²)
    across an eviction burst).  ``hits``/``misses`` drive the hit-rate
    figure serve.py reports."""

    def __init__(self, capacity_ints: int = 1 << 24):
        self.capacity = capacity_ints
        self._store: OrderedDict = OrderedDict()
        self._size = 0
        self.hits = 0
        self.misses = 0

    def __contains__(self, key) -> bool:
        return key in self._store        # residency peek: no counter, no LRU

    def get(self, key):
        hit = self._store.get(key)
        if hit is None:
            self.misses += 1
            return None
        self.hits += 1
        self._store.move_to_end(key)
        return hit

    def put(self, key, vals, n):
        old = self._store.pop(key, None)
        if old is not None:
            self._size -= int(old[0].shape[0])
        self._size += int(vals.shape[0])
        self._store[key] = (vals, n)
        while self._size > self.capacity and len(self._store) > 1:
            _, (old_vals, _) = self._store.popitem(last=False)
            self._size -= int(old_vals.shape[0])

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.hits + self.misses, 1)


@dataclasses.dataclass
class QueryResult:
    count: int
    docs: np.ndarray        # global doc ids (may be truncated to cap)


def _packed_probe(r, r_count: int, src: source.PackedSource,
                  stats: dict | None = None):
    """Skip-probe the current candidates against a PackedSource: host-side
    block-max search picks the candidate blocks, the device decodes only
    those (``intersect_packed_candidates`` or the fused Pallas kernel).
    The padded device operands are memoized per (part, term) — only the
    per-query candidate block ids move host→device here."""
    blk = src.candidate_block_ids(np.asarray(r)[:r_count])
    k_pad, t_pad, e_pad = src.self_pads()
    c_pad = its.pow2_bucket(len(blk), floor=source.CAND_FLOOR)
    words, widths, offsets, maxes, exc_pos, exc_add = \
        source.cached_layout_dev(src, (k_pad, t_pad, e_pad), stats)
    blk_p = jnp.asarray(source.pad_block_ids(blk, c_pad, k_pad))
    source._bump(stats, "decoded_ints", c_pad * src.block_rows * 128)
    source._bump(stats, "skip_folds")
    args = (words, widths, offsets, maxes, blk_p, exc_pos, exc_add)
    if USE_KERNELS:
        from repro.kernels import ops as kernel_ops
        return kernel_ops.intersect_packed_batch(
            r[None], *(a[None] for a in args),
            mode=src.mode, block_rows=src.block_rows)[0]
    return its.intersect_packed_candidates(
        r, *args, mode=src.mode, block_rows=src.block_rows)


def _intersect_part(part: IndexPart, term_ids: list[int], codec,
                    skip: bool = True, cache=None,
                    stats: dict | None = None, pool=None):
    """Returns (padded candidate vals, count) or ('bitmap', words)."""
    tps = [part.terms[t] for t in term_ids]
    if any(tp.kind == "empty" for tp in tps):
        return None, 0
    lists = sorted((tp for tp in tps if tp.kind == "list"), key=lambda t: t.n)
    bitmaps = [tp for tp in tps if tp.kind == "bitmap"]

    if not lists:
        words = bitmaps[0].payload
        for tp in bitmaps[1:]:
            words = np.asarray(bm.bitmap_and(jnp.asarray(words),
                                             jnp.asarray(tp.payload)))
        return ("bitmap", words), int(bm.popcount(jnp.asarray(words)))

    id_of = {id(tp): t for t, tp in zip(term_ids, tps)}
    # the shortest list seeds the candidate buffer — always decoded
    seed = source.resolve(part, id_of[id(lists[0])], lists[0], codec,
                          cache=cache, r_count=None, stats=stats, pool=pool)
    r, r_count = seed.vals, seed.n
    for tp in lists[1:]:
        if r_count == 0:
            break
        src = source.resolve(part, id_of[id(tp)], tp, codec, cache=cache,
                             r_count=r_count, skip=skip, stats=stats,
                             pool=pool)
        if isinstance(src, source.PackedSource):
            # paper's galloping+skip: search the block-max index, decode only
            # candidate blocks — the long list is never fully decoded.
            mask = _packed_probe(r, r_count, src, stats=stats)
        elif USE_KERNELS and tp.n / max(r_count, 1) > its.TILED_MAX_RATIO:
            from repro.kernels import ops as kernel_ops
            mask = kernel_ops.intersect_gallop(r, src.vals)
        else:
            mask = its.intersect_auto(r, src.vals, r_count, tp.n)
        r, cnt = its.compact(r, mask)
        r_count = int(cnt)
    for tp in bitmaps:
        if r_count == 0:
            break
        mask = bm.probe(jnp.asarray(tp.payload), r, r != its.SENTINEL)
        r, cnt = its.compact(r, mask)
        r_count = int(cnt)
    return ("list", r), r_count


def query(index: HybridIndex, term_ids: list[int],
          max_results: int = 1 << 16, cache: "DecodeCache | None" = None,
          skip: bool = True, stats: dict | None = None,
          pool: "source.ResidentPool | None" = None) -> QueryResult:
    """cache: optional DecodeCache → the paper's Table 4 regime (SvS over
    already-decoded lists); None → Table 5 regime (decode per query).
    Either way long skip-capable lists go through the packed skip path
    (``skip=False`` forces full decode everywhere, for A/B benchmarks).
    stats: optional dict accumulating decoded_ints / skip_folds counters.
    pool: optional ResidentPool — decoded operands are served from (and
    staged into) the device-resident index (DESIGN.md §2.8)."""
    codec = codec_lib.get_codec(index.codec_name)
    total = 0
    out_docs = []
    for part in index.parts:
        res, cnt = _intersect_part(part, term_ids, codec, skip=skip,
                                   cache=cache, stats=stats, pool=pool)
        total += cnt
        if cnt and res is not None:
            kind, payload = res
            if kind == "list":
                docs = np.asarray(payload)[:cnt]
            else:
                docs = bm.extract_np(payload)
            out_docs.append(docs.astype(np.int64) + part.doc_lo)
    docs = (np.concatenate(out_docs) if out_docs
            else np.zeros(0, np.int64))[:max_results]
    return QueryResult(count=total, docs=docs)


def brute_force(postings: list[np.ndarray], term_ids: list[int]) -> np.ndarray:
    """Oracle: numpy set intersection over the raw posting lists."""
    res = postings[term_ids[0]]
    for t in term_ids[1:]:
        res = np.intersect1d(res, postings[t])
    return res
