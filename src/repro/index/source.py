"""Posting-source layer: one decode/skip policy for both engines
(DESIGN.md §2.6).

A query term resolves to one of two *sources*:

  DecodedSource — the padded int32 value array (today's behavior): short
                  lists, cache-resident lists, and codecs without a skip
                  index all land here.
  PackedSource  — the compressed list stays packed; intersection gallops
                  over the block-max skip index and decodes only candidate
                  blocks (paper §6.5).  Long skip-capable lists land here.

The choice is made by ``resolve`` from three inputs — the candidate/list
cardinality ratio, the codec family (``bitpack.skip_capable``), and cache
residency — replacing the two divergent inline heuristics the sequential
and batched engines used to carry.  Crucially the skip path *composes* with
the DecodeCache instead of being mutually exclusive with it: short lists
are decoded once and cached, long lists are skip-probed and never pollute
the cache (their decode cost is exactly what the skip index avoids).

``resolve`` also keeps the decoded-ints accounting (``stats`` dict) that
serve.py and bench_engine.py report: every integer materialized from a
compressed payload is counted, so the partial-decode win is visible as a
number, not a belief.

Device residency (DESIGN.md §2.8): ``ResidentPool`` keeps resolved operands
— decoded value rows, bitmap word rows, and (via the layout memo) packed
layout operands — staged on device with explicit ``jax.device_put`` and
LRU eviction accounting, so steady-state batch assembly is pure
index-gathering over resident buffers instead of per-batch decode + pow2
padding + H2D transfer.

Invariants callers rely on:

  * **Residency wins in resolve** — a list already staged in the pool (or
    the DecodeCache) is served decoded even when the ratio policy would
    skip-probe it; fresh decodes are staged so the next batch gathers
    instead of decoding.  Residency never changes *values*, only where a
    row lives, so engines stay byte-identical with and without a pool.
  * **Device pinning (DESIGN.md §2.5/§2.9)** — a pool constructed with
    ``device=`` commits every buffer it stages to that device, and the
    layout memo keeps per-device copies of packed layout operands.  This is
    what places each index shard's working set on its own device in the
    sharded executor (``repro.index.shard``); ``device=None`` keeps today's
    single-device behavior byte for byte.
  * **Host copies are kept** — schedulers read seed values and block-max
    indexes on host; pool entries always carry the numpy copy so no D2H
    sync lands on the query path.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bitpack
from repro.core import codecs as codec_lib
from repro.core import intersect as its
from repro.core import varint as varint_lib

# Ratio above which a skip-capable list is probed packed instead of decoded
# (the paper's galloping threshold, re-derived on TPU tile geometry — same
# constant the decoded-path dispatcher uses).
SKIP_MIN_RATIO = its.TILED_MAX_RATIO
# Below this many blocks the skip index cannot prune anything worth the
# extra program: decode instead.
SKIP_MIN_BLOCKS = 4

# Bucket floor for the candidate-block-id buffer (pow2-bucketed like every
# other device shape).
CAND_FLOOR = 8


@dataclasses.dataclass
class DecodedSource:
    """Fully decoded posting list: padded int32 values + valid count.

    ``vals`` may live on host (numpy) or device (pool-resident / cached);
    ``vals_np`` is the host copy when one exists for free (fresh decodes,
    pool entries) so schedulers can read values without a D2H sync.
    ``key`` is the (part.uid, tid) identity for pool lookups."""
    vals: jnp.ndarray
    n: int
    vals_np: np.ndarray | None = None
    key: tuple = ()


@dataclasses.dataclass
class PackedSource:
    """Compressed posting list kept packed for skip-aware partial decode."""
    payload: object            # PackedList | PatchedList
    n: int
    maxes_np: np.ndarray       # host copy of the block-max skip index
    key: tuple = ()            # (part.uid, tid) — layout memoization key

    @property
    def mode(self) -> str:
        return self.payload.mode

    @property
    def block_rows(self) -> int:
        return self.payload.block_rows

    @property
    def num_blocks(self) -> int:
        return int(self.payload.widths.shape[0])

    @property
    def num_rows(self) -> int:
        return int(self.payload.flat_words.shape[0])

    @property
    def num_exceptions(self) -> int:
        return int(getattr(self.payload, "exc_pos",
                           np.zeros(0)).shape[0])

    def candidate_block_ids(self, values: np.ndarray) -> np.ndarray:
        """Unique block ids possibly containing any candidate value."""
        return bitpack.candidate_block_ids(self.maxes_np, values)

    def layout(self, k_pad: int, t_pad: int, e_pad: int) -> bitpack.PackedLayout:
        return bitpack.layout_np(self.payload, k_pad, t_pad, e_pad)

    def self_pads(self) -> tuple[int, int, int]:
        """The payload's own pow2 buckets — the canonical memoization pads
        (group buckets are maxima of member self-pads, so a group-sized
        stack slot zero-extends a self-padded layout; see batch.py)."""
        return bitpack.self_pads(self.payload)


def pad_block_ids(blk: np.ndarray, c_pad: int, k_pad: int) -> np.ndarray:
    """Pad a candidate block-id list to the group bucket; pad entries use the
    out-of-range id ``k_pad`` which the device decodes to all-SENTINEL."""
    out = np.full(c_pad, k_pad, np.int32)
    out[: blk.shape[0]] = blk
    return out


# Memoized padded layouts: building a PackedLayout copies the compressed
# words off device and re-pads them, and the sequential probe re-uploads
# the result — per query, per fold, for lists that by definition recur
# (they are the long head terms).  Keyed by ((part.uid, tid), pads) so
# index rebuilds can't serve stale entries; LRU-bounded by total layout
# ints (like the DecodeCache), since each entry pins a whole compressed
# list.  Under megagroup fusion (DESIGN.md §2.10) the arena assembler
# requests layouts at *family-level* pads — the fused key's k/t/e
# ceilings — and the sticky FusionPlan keeps those ceilings monotone, so
# the family key space (and this memo) converges instead of fragmenting
# per batch.
_LAYOUT_CACHE: OrderedDict = OrderedDict()
_LAYOUT_CACHE_BUDGET = 1 << 26      # total ints across cached layouts
_layout_cache_size = 0


def _layout_ints(pads: tuple) -> int:
    k_pad, t_pad, e_pad = pads
    return t_pad * bitpack.LANES + 3 * k_pad + 2 * e_pad


def _layout_entry(src: PackedSource, pads: tuple, stats: dict | None = None):
    global _layout_cache_size
    key = (src.key, pads)
    entry = _LAYOUT_CACHE.get(key)
    if entry is None:
        _bump(stats, "layout_misses")
        entry = {"np": src.layout(*pads), "dev": {}}
        _LAYOUT_CACHE[key] = entry
        _layout_cache_size += _layout_ints(pads)
        while (_layout_cache_size > _LAYOUT_CACHE_BUDGET
               and len(_LAYOUT_CACHE) > 1):
            (_, old_pads), _ = _LAYOUT_CACHE.popitem(last=False)
            _layout_cache_size -= _layout_ints(old_pads)
    else:
        _bump(stats, "layout_hits")
        _LAYOUT_CACHE.move_to_end(key)
    return entry


def cached_layout_np(src: PackedSource, pads: tuple,
                     stats: dict | None = None) -> bitpack.PackedLayout:
    """Memoized host-side padded layout (batch scheduler stacking)."""
    return _layout_entry(src, pads, stats)["np"]


def cached_layout_dev(src: PackedSource, pads: tuple,
                      stats: dict | None = None, device=None) -> tuple:
    """Memoized device-resident layout operands (sequential probe and the
    pool-resident batch stacks): (words, widths, offsets, maxes, exc_pos,
    exc_add) jnp arrays.  ``device`` pins the copy to one device (sharded
    serving keeps one copy per owning shard; None = default placement)."""
    entry = _layout_entry(src, pads, stats)
    dev = entry["dev"].get(device)
    if dev is None:
        lay = entry["np"]
        dev = tuple(jax.device_put(x, device) for x in (
            lay.words, lay.widths, lay.offsets, lay.maxes,
            lay.exc_pos, lay.exc_add))
        entry["dev"][device] = dev
    return dev


def precompute_layouts(parts, stats: dict | None = None) -> int:
    """Build-time projection of every skip-capable list payload onto its
    self-padded PackedLayout, warming the layout memo so serving never
    re-pads on the host (ISSUE 3).  Returns the number of layouts staged."""
    n = 0
    for part in parts:
        for tid, tp in part.terms.items():
            if (tp.kind == "list" and bitpack.skip_capable(tp.payload)
                    and getattr(tp, "skip_ok", True)
                    and int(tp.payload.widths.shape[0]) >= SKIP_MIN_BLOCKS):
                src = PackedSource(tp.payload, tp.n,
                                   maxes_np=np.asarray(tp.payload.maxes),
                                   key=(part.uid, tid))
                cached_layout_np(src, src.self_pads(), stats)
                n += 1
    return n


def decoded_ints_of(payload) -> int:
    """Integers materialized by a full decode of this payload."""
    if isinstance(payload, varint_lib.VarintList):
        return payload.n
    if bitpack.skip_capable(payload):
        return int(payload.widths.shape[0]) * payload.block_rows * bitpack.LANES
    return int(getattr(payload, "padded_n", payload.n))


def decode_padded_np(codec, tp) -> tuple[np.ndarray, int]:
    """Decode one term posting to (pow2-padded int32 numpy vals, count).

    Dispatch is by payload type through the codec registry
    (``codecs.codec_for``) so mixed-codec indexes — the autotuner's output —
    decode without an index-level codec name; the passed ``codec`` is only
    the fallback for payload types the registry does not know."""
    if isinstance(tp.payload, bitpack.PackedList):
        vals = np.asarray(bitpack.decode_bucketed(tp.payload))[: tp.n]
        vals = vals.astype(np.int32)
    elif isinstance(tp.payload, varint_lib.VarintList):
        vals = varint_lib.decode(tp.payload).astype(np.int32)   # tail codec
    else:
        c = codec_lib.codec_for(tp.payload) or codec
        vals = np.asarray(c.decode(tp.payload))[: tp.n].astype(np.int32)
    size = its.pow2_bucket(tp.n)
    return its.pad_to(vals, size), tp.n


def decode_padded(codec, tp) -> tuple[jnp.ndarray, int]:
    """Decode one term posting to (pow2-padded int32 device vals, count)."""
    vals, n = decode_padded_np(codec, tp)
    return jnp.asarray(vals), n


def _bump(stats, key, by=1):
    if stats is not None:
        stats[key] = stats.get(key, 0) + by


# --------------------------------------------------------------------------
# device-resident operand pool (DESIGN.md §2.8)
# --------------------------------------------------------------------------

class RowArena:
    """Same-shape resident rows packed into ONE device matrix, so a group's
    operand assembly is a single ``buffer[idx]`` gather instead of an n-ary
    stack.  Why: jit dispatch costs ~60µs *per argument* on the host
    backend, so stacking hundreds of row references per batch was the
    dominant serving cost — a gather is 2 arguments regardless of row count
    (DESIGN.md §2.8/§2.9).

    Identity rows (sentinel / all-ones / all-zero / pad-layout) occupy the
    first slots so padded and inactive grid positions gather them by
    construction.  The buffer is rebuilt lazily (host ``np.stack`` + one
    ``device_put``) when new rows joined since the last build — steady
    state rebuilds nothing, and the warm-up rebuild cost is absorbed by
    the warm passes every serving/bench loop already runs.  The buffer's
    row count is padded to a pow2 capacity (filler = the identity row, a
    slot id no index ever takes) so its *shape* changes only O(log rows)
    times — the gather program recompiles per buffer shape, not per added
    row.  Rows are keyed by the same (part.uid, tid) identity the pool
    uses.

    Eviction (ISSUE 6): the pool's LRU eviction calls ``evict(key)`` so a
    churned row's slot reverts to the identity row and lands on a free
    list for the next ``slot()`` miss to reuse — without it every arena
    held a device copy of every row *ever* staged, so real device memory
    grew monotonically while the pool's ``resident_ints`` claimed the
    budget held.  ``ints`` reports the *allocated* footprint (the
    high-water row count — a freed slot's memory is only reclaimed when
    the buffer next rebuilds), which is what the pool counts against its
    capacity."""

    def __init__(self, identities: list, device=None):
        self.rows_np: list = list(identities)
        self.slots: dict = {}
        self.device = device
        self.evictions = 0
        self._free: list[int] = []
        self._buf = None

    def slot(self, key, make_np) -> int:
        s = self.slots.get(key)
        if s is None:
            if self._free:
                s = self._free.pop()
                self.rows_np[s] = make_np()
            else:
                s = len(self.rows_np)
                self.rows_np.append(make_np())
            self.slots[key] = s
            self._buf = None
        return s

    def evict(self, key) -> int:
        """Drop one row: its slot reverts to the identity row and is
        reused by the next ``slot()`` miss, so churn stops growing the
        buffer.  Dispatched gathers are unaffected — mutation rebuilds a
        *new* device buffer; in-flight programs keep the old one.
        Returns the ints the slot will stop pinning once reused."""
        s = self.slots.pop(key, None)
        if s is None:
            return 0
        self.rows_np[s] = self.rows_np[0]
        self._free.append(s)
        self.evictions += 1
        return int(np.prod(self.rows_np[0].shape))

    @property
    def ints(self) -> int:
        return len(self.rows_np) * int(np.prod(self.rows_np[0].shape))

    def buffer(self):
        if self._buf is None:
            cap = 1
            while cap < len(self.rows_np):
                cap <<= 1
            rows = self.rows_np + [self.rows_np[0]] * (cap - len(self.rows_np))
            self._buf = jax.device_put(np.stack(rows), self.device)
        return self._buf

class ResidentPool:
    """Device-resident index operands: decoded value rows and bitmap word
    rows staged once with explicit ``jax.device_put`` and reused by every
    subsequent batch (packed layouts stay resident through the layout memo
    above — same lifecycle, different key space).

    Entries are LRU-evicted against an int budget with explicit accounting
    (``staged_*`` / ``evicted_*`` / ``resident_ints``), because residency is
    a *capacity decision*: a decoded pool the size of the corpus is just an
    uncompressed index.  ``warm`` stages the decode-policy lists up front
    (build-time staging); anything else lands in the pool the first time a
    batch decodes it, so steady state converges to zero host decode either
    way.

    Residency accounting (ISSUE 6 bugfix): everything the pool puts on
    device is counted against ``capacity_ints`` — store entries *and*
    their per-size pad memos (``pad_ints``, dropped and subtracted when
    the entry evicts) *and* the arena / identity-row overhead
    (``overhead_ints``), which the old accounting ignored entirely: a
    churned pool's arenas kept a device copy of every row ever staged, so
    real device memory could exceed the budget without bound while
    ``stats()`` claimed otherwise.  Evicting a store entry now also
    evicts its rows from every arena (slots go to a free list and are
    reused, see ``RowArena.evict``).  ``stats()['device_ints']`` is the
    full device-side footprint; ``resident_ints`` stays the store-entry
    total (``staged_ints - evicted_ints == resident_ints`` remains an
    invariant).

    Each entry keeps the host numpy copy alongside the device buffer: the
    scheduler's block-max skip search reads seed *values* on host, and a
    D2H sync per seed would serialize the very pipeline the pool feeds.

    ``device`` pins every staged buffer to one device — the sharded
    executor (DESIGN.md §2.5/§2.9) gives each index shard a pool pinned to
    its own device so the shard's whole working set lives where its slice
    of the batch executes.  ``device=None`` is the default placement
    (single-device serving, unchanged).
    """

    def __init__(self, capacity_ints: int = 1 << 26, device=None, tag=None):
        self.capacity = capacity_ints
        self.device = device
        self.tag = tag                 # generation tag (DESIGN.md §2.14)
        self._store: OrderedDict = OrderedDict()
        self._pad_rows: dict[tuple, jnp.ndarray] = {}
        self._arenas: dict[tuple, RowArena] = {}
        self.hits = 0
        self.misses = 0
        self.staged_lists = 0
        self.staged_ints = 0
        self.evicted_lists = 0
        self.evicted_ints = 0
        self.resident_ints = 0
        self.pad_ints = 0              # current pad-memo ints (⊂ resident)

    # -- staging -----------------------------------------------------------

    def overhead_ints(self) -> int:
        """Device ints the pool holds *outside* the LRU store: identity
        rows and the row arenas (allocated footprint — see RowArena)."""
        return (sum(int(r.size) for r in self._pad_rows.values())
                + sum(a.ints for a in self._arenas.values()))

    def device_ints(self) -> int:
        """The pool's full device-side footprint — what ``capacity_ints``
        actually bounds (the store alone under-counts by the arena copies
        of every resident row)."""
        return self.resident_ints + self.overhead_ints()

    def _evict(self):
        while (self.device_ints() > self.capacity
               and len(self._store) > 1):
            key, old = self._store.popitem(last=False)
            freed = old["ints"] + old["pad_ints"]
            self.evicted_lists += 1
            self.evicted_ints += freed
            self.resident_ints -= freed
            self.pad_ints -= old["pad_ints"]
            old["pads"].clear()            # drop the pad memos with the entry
            for arena in self._arenas.values():
                arena.evict(key)           # free the arena copies for reuse

    def stage(self, key, vals_np: np.ndarray, n: int,
              dev: jnp.ndarray | None = None):
        """Stage one padded decoded list; ``dev`` reuses an already-staged
        device buffer instead of a second H2D transfer (re-pinned if this
        pool is bound to a device and the buffer lives elsewhere)."""
        if key in self._store:
            self._store.move_to_end(key)
            return self._store[key]
        if dev is None:
            dev = jax.device_put(vals_np, self.device)
        elif self.device is not None and self.device not in dev.devices():
            dev = jax.device_put(dev, self.device)
        entry = {"dev": dev, "np": vals_np, "n": n,
                 "pads": {}, "ints": int(vals_np.shape[0]), "pad_ints": 0}
        self._store[key] = entry
        self.staged_lists += 1
        self.staged_ints += entry["ints"]
        self.resident_ints += entry["ints"]
        self._evict()
        return entry

    def stage_bitmap(self, key, words_np: np.ndarray,
                     dev: jnp.ndarray | None = None) -> jnp.ndarray:
        """Stage one bitmap term's word row (key should carry a 'bm' tag to
        keep it disjoint from decoded-list keys).  ``dev`` reuses an
        already-staged device buffer (same contract as ``stage``)."""
        entry = self._store.get(key)
        if entry is None:
            if dev is None:
                dev = jax.device_put(words_np, self.device)
            elif self.device is not None and self.device not in dev.devices():
                dev = jax.device_put(dev, self.device)
            entry = {"dev": dev,
                     "np": words_np,
                     "n": int(words_np.shape[0]), "pads": {},
                     "ints": int(words_np.shape[0]), "pad_ints": 0}
            self._store[key] = entry
            self.staged_lists += 1
            self.staged_ints += entry["ints"]
            self.resident_ints += entry["ints"]
            self._evict()
        else:
            self._store.move_to_end(key)
        return entry["dev"]

    # -- lookup ------------------------------------------------------------

    def get(self, key):
        """(device vals, host vals, n) or None — counts hit/miss."""
        entry = self._store.get(key)
        if entry is None:
            self.misses += 1
            return None
        self.hits += 1
        self._store.move_to_end(key)
        return entry["dev"], entry["np"], entry["n"]

    def __contains__(self, key) -> bool:
        return key in self._store        # residency peek: no counters

    def padded(self, src: DecodedSource, size: int) -> jnp.ndarray:
        """Device row of ``src`` SENTINEL-padded to ``size`` (a group's fold
        bucket).  Memoized per (entry, size); survives eviction races by
        falling back to an eager device pad of the source's own buffer."""
        base = src.vals
        if base.shape[0] == size:
            return base
        entry = self._store.get(src.key) if src.key else None
        if entry is not None and entry["dev"] is base:
            dev = entry["pads"].get(size)
            if dev is None:
                dev = jax.device_put(its.pad_to(entry["np"], size),
                                     self.device)
                entry["pads"][size] = dev
                entry["pad_ints"] += size
                self.staged_ints += size
                self.resident_ints += size
                self.pad_ints += size
                self._evict()
            return dev
        return jnp.concatenate(
            [base, jnp.full((size - base.shape[0],), its.SENTINEL,
                            jnp.int32)])

    def sentinel_row(self, size: int) -> jnp.ndarray:
        """All-SENTINEL device row (inactive fold / padded batch slots)."""
        row = self._pad_rows.get(("sent", size))
        if row is None:
            row = jax.device_put(np.full(size, its.SENTINEL, np.int32),
                                 self.device)
            self._pad_rows[("sent", size)] = row
        return row

    def ones_row(self, words: int) -> jnp.ndarray:
        """All-ones bitmap row — the probe/AND identity."""
        row = self._pad_rows.get(("ones", words))
        if row is None:
            row = jax.device_put(np.full(words, 0xFFFFFFFF, np.uint32),
                                 self.device)
            self._pad_rows[("ones", words)] = row
        return row

    def zeros_row(self, words: int) -> jnp.ndarray:
        """All-zero bitmap row — padded batch slots (popcount 0)."""
        row = self._pad_rows.get(("zero", words))
        if row is None:
            row = jax.device_put(np.zeros(words, np.uint32), self.device)
            self._pad_rows[("zero", words)] = row
        return row

    # -- arenas (gather-based group assembly; DESIGN.md §2.8/§2.9) ---------

    # identity-slot layout shared with the batch assembler:
    #   fold arenas:   slot 0 = all-SENTINEL row
    #   bitmap arenas: slot 0 = all-ones (probe/AND identity),
    #                  slot 1 = all-zero (padded batch rows, popcount 0)
    FOLD_PAD_SLOT = 0
    BM_ONES_SLOT = 0
    BM_ZERO_SLOT = 1

    def fold_arena(self, size: int) -> RowArena:
        """Arena of SENTINEL-padded int32 value rows of length ``size``."""
        a = self._arenas.get(("fold", size))
        if a is None:
            a = RowArena([np.full(size, its.SENTINEL, np.int32)],
                         device=self.device)
            self._arenas[("fold", size)] = a
        return a

    def bitmap_arena(self, words: int) -> RowArena:
        a = self._arenas.get(("bm", words))
        if a is None:
            a = RowArena([np.full(words, 0xFFFFFFFF, np.uint32),
                          np.zeros(words, np.uint32)], device=self.device)
            self._arenas[("bm", words)] = a
        return a

    def layout_arena(self, pads: tuple, op: int) -> RowArena:
        """Arena of packed-layout operand ``op`` (word rows, widths,
        offsets, maxes, exc_pos, exc_add — the _compose_pk order minus the
        candidate block ids) at group pads — family-level ceilings when
        the fused scheduler is driving (one arena set per family, not per
        scheduled signature); slot 0 is the all-pad layout whose blocks
        are never candidates."""
        a = self._arenas.get(("lay", pads, op))
        if a is None:
            k_pad, t_pad, e_pad = pads
            idn = (np.zeros((t_pad, bitpack.LANES), np.uint32),
                   np.zeros(k_pad, np.int32),
                   np.zeros(k_pad, np.int32),
                   np.zeros(k_pad, np.uint32),
                   np.full(e_pad, -1, np.int32),
                   np.zeros(e_pad, np.uint32))[op]
            a = RowArena([idn], device=self.device)
            self._arenas[("lay", pads, op)] = a
        return a

    def arena_stats(self) -> dict:
        return {"arenas": len(self._arenas),
                "arena_ints": sum(a.ints for a in self._arenas.values()),
                "arena_rows": sum(len(a.slots)
                                  for a in self._arenas.values()),
                "arena_evictions": sum(a.evictions
                                       for a in self._arenas.values())}

    # -- lifecycle ---------------------------------------------------------

    def carry_from(self, other: "ResidentPool",
                   part_uids: set | None = None) -> int:
        """Generation-swap residency reuse (DESIGN.md §2.14): adopt another
        pool's staged entries — decoded rows and bitmap rows — around
        their existing device buffers, so surviving segments pay neither a
        re-decode nor a second H2D transfer when a new generation's pool
        is built next to the old one.  ``part_uids`` restricts the carry
        to entries whose part survived the swap (None = carry all).
        Returns the number of entries carried; LRU accounting counts them
        as freshly staged in this pool."""
        carried = 0
        for key, e in list(other._store.items()):
            if part_uids is not None:
                uid = key[1] if (key and key[0] == "bm") else key[0]
                if uid not in part_uids:
                    continue
            if key in self._store:
                continue
            if key and key[0] == "bm":
                self.stage_bitmap(key, e["np"], dev=e["dev"])
            else:
                self.stage(key, e["np"], e["n"], dev=e["dev"])
            carried += 1
        return carried

    def warm(self, index, stats: dict | None = None) -> dict:
        """Stage the whole index per the resolve policy: bitmaps and
        decode-policy lists go resident decoded; skip-capable long lists
        stay compressed (their memory story *is* the skip index) and only
        warm their self-padded layout projection.  Already-resident
        entries (e.g. carried across a generation swap) skip the decode
        entirely."""
        codec = codec_lib.get_codec(index.codec_name)
        for part in index.parts:
            for tid, tp in part.terms.items():
                if tp.kind == "bitmap":
                    self.stage_bitmap(("bm", part.uid, tid),
                                      np.asarray(tp.payload))
                elif tp.kind == "list":
                    if (part.uid, tid) in self._store:
                        self._store.move_to_end((part.uid, tid))
                        continue
                    if (bitpack.skip_capable(tp.payload) and
                            getattr(tp, "skip_ok", True) and
                            int(tp.payload.widths.shape[0])
                            >= SKIP_MIN_BLOCKS):
                        continue                 # serves packed: stay compressed
                    vals, n = decode_padded_np(codec, tp)
                    _bump(stats, "decoded_ints",
                          decoded_ints_of(tp.payload))
                    self.stage((part.uid, tid), vals, n)
        precompute_layouts(index.parts, stats)
        return self.stats()

    def stats(self) -> dict:
        return {"tag": self.tag,
                "resident_lists": len(self._store),
                "resident_ints": self.resident_ints,
                "staged_lists": self.staged_lists,
                "staged_ints": self.staged_ints,
                "evicted_lists": self.evicted_lists,
                "evicted_ints": self.evicted_ints,
                "pad_ints": self.pad_ints,
                "overhead_ints": self.overhead_ints(),
                "device_ints": self.device_ints(),
                "hits": self.hits, "misses": self.misses,
                **self.arena_stats()}


def resolve(part, tid: int, tp, codec, cache=None, r_count: int | None = None,
            skip: bool = True, stats: dict | None = None,
            pool: ResidentPool | None = None):
    """Resolve one term posting to a DecodedSource or a PackedSource.

    r_count: current (or scheduled) candidate cardinality — None means this
    term *is* the candidate seed and must decode.  skip=False forces the
    decoded path everywhere (the pre-skip engine behavior, kept for A/B
    benchmarking).  pool: optional ResidentPool — residency wins like cache
    residency does (an already-staged list is free to reuse), and fresh
    decodes are staged so the next batch gathers instead of decoding.
    """
    key = (part.uid, tid)
    want_skip = (skip and r_count is not None
                 and bitpack.skip_capable(tp.payload)
                 and getattr(tp, "skip_ok", True)
                 and tp.n / max(r_count, 1) > SKIP_MIN_RATIO
                 and int(tp.payload.widths.shape[0]) >= SKIP_MIN_BLOCKS)
    if want_skip:
        # residency wins: an already-decoded list is free to reuse
        if cache is not None and key in cache:
            vals, n = cache.get(key)
            return DecodedSource(vals, n, key=key)
        if pool is not None and key in pool:
            dev, vals_np, n = pool.get(key)
            _bump(stats, "resident_hits")
            return DecodedSource(dev, n, vals_np=vals_np, key=key)
        return PackedSource(tp.payload, tp.n,
                            maxes_np=np.asarray(tp.payload.maxes), key=key)
    if pool is not None:
        hit = pool.get(key)
        if hit is not None:
            _bump(stats, "resident_hits")
            return DecodedSource(hit[0], hit[2], vals_np=hit[1], key=key)
    if cache is not None:
        hit = cache.get(key)
        if hit is not None:
            if pool is not None:          # promote: next batch gathers
                pool.stage(key, np.asarray(hit[0]), hit[1], dev=hit[0])
            return DecodedSource(hit[0], hit[1], key=key)
    vals_np, n = decode_padded_np(codec, tp)
    _bump(stats, "decoded_ints", decoded_ints_of(tp.payload))
    _bump(stats, "decoded_lists")
    if pool is not None:
        # stage first so the buffer lands on the pool's device (sharded
        # pools are device-pinned) and the source serves the staged copy
        vals = pool.stage(key, vals_np, n)["dev"]
    else:
        vals = jnp.asarray(vals_np)
    if cache is not None:
        cache.put(key, vals, n)
    return DecodedSource(vals, n, vals_np=vals_np, key=key)
