"""Posting-source layer: one decode/skip policy for both engines
(DESIGN.md §2.6).

A query term resolves to one of two *sources*:

  DecodedSource — the padded int32 value array (today's behavior): short
                  lists, cache-resident lists, and codecs without a skip
                  index all land here.
  PackedSource  — the compressed list stays packed; intersection gallops
                  over the block-max skip index and decodes only candidate
                  blocks (paper §6.5).  Long skip-capable lists land here.

The choice is made by ``resolve`` from three inputs — the candidate/list
cardinality ratio, the codec family (``bitpack.skip_capable``), and cache
residency — replacing the two divergent inline heuristics the sequential
and batched engines used to carry.  Crucially the skip path *composes* with
the DecodeCache instead of being mutually exclusive with it: short lists
are decoded once and cached, long lists are skip-probed and never pollute
the cache (their decode cost is exactly what the skip index avoids).

``resolve`` also keeps the decoded-ints accounting (``stats`` dict) that
serve.py and bench_engine.py report: every integer materialized from a
compressed payload is counted, so the partial-decode win is visible as a
number, not a belief.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np
import jax.numpy as jnp

from repro.core import bitpack
from repro.core import intersect as its
from repro.core import varint as varint_lib

# Ratio above which a skip-capable list is probed packed instead of decoded
# (the paper's galloping threshold, re-derived on TPU tile geometry — same
# constant the decoded-path dispatcher uses).
SKIP_MIN_RATIO = its.TILED_MAX_RATIO
# Below this many blocks the skip index cannot prune anything worth the
# extra program: decode instead.
SKIP_MIN_BLOCKS = 4

# Bucket floor for the candidate-block-id buffer (pow2-bucketed like every
# other device shape).
CAND_FLOOR = 8


@dataclasses.dataclass
class DecodedSource:
    """Fully decoded posting list: padded int32 values + valid count."""
    vals: jnp.ndarray
    n: int


@dataclasses.dataclass
class PackedSource:
    """Compressed posting list kept packed for skip-aware partial decode."""
    payload: object            # PackedList | PatchedList
    n: int
    maxes_np: np.ndarray       # host copy of the block-max skip index
    key: tuple = ()            # (part.uid, tid) — layout memoization key

    @property
    def mode(self) -> str:
        return self.payload.mode

    @property
    def block_rows(self) -> int:
        return self.payload.block_rows

    @property
    def num_blocks(self) -> int:
        return int(self.payload.widths.shape[0])

    @property
    def num_rows(self) -> int:
        return int(self.payload.flat_words.shape[0])

    @property
    def num_exceptions(self) -> int:
        return int(getattr(self.payload, "exc_pos",
                           np.zeros(0)).shape[0])

    def candidate_block_ids(self, values: np.ndarray) -> np.ndarray:
        """Unique block ids possibly containing any candidate value."""
        return bitpack.candidate_block_ids(self.maxes_np, values)

    def layout(self, k_pad: int, t_pad: int, e_pad: int) -> bitpack.PackedLayout:
        return bitpack.layout_np(self.payload, k_pad, t_pad, e_pad)


def pad_block_ids(blk: np.ndarray, c_pad: int, k_pad: int) -> np.ndarray:
    """Pad a candidate block-id list to the group bucket; pad entries use the
    out-of-range id ``k_pad`` which the device decodes to all-SENTINEL."""
    out = np.full(c_pad, k_pad, np.int32)
    out[: blk.shape[0]] = blk
    return out


# Memoized padded layouts: building a PackedLayout copies the compressed
# words off device and re-pads them, and the sequential probe re-uploads
# the result — per query, per fold, for lists that by definition recur
# (they are the long head terms).  Keyed by ((part.uid, tid), pads) so
# index rebuilds can't serve stale entries; LRU-bounded by total layout
# ints (like the DecodeCache), since each entry pins a whole compressed
# list.
_LAYOUT_CACHE: OrderedDict = OrderedDict()
_LAYOUT_CACHE_BUDGET = 1 << 26      # total ints across cached layouts
_layout_cache_size = 0


def _layout_ints(pads: tuple) -> int:
    k_pad, t_pad, e_pad = pads
    return t_pad * bitpack.LANES + 3 * k_pad + 2 * e_pad


def _layout_entry(src: PackedSource, pads: tuple):
    global _layout_cache_size
    key = (src.key, pads)
    entry = _LAYOUT_CACHE.get(key)
    if entry is None:
        entry = {"np": src.layout(*pads), "dev": None}
        _LAYOUT_CACHE[key] = entry
        _layout_cache_size += _layout_ints(pads)
        while (_layout_cache_size > _LAYOUT_CACHE_BUDGET
               and len(_LAYOUT_CACHE) > 1):
            (_, old_pads), _ = _LAYOUT_CACHE.popitem(last=False)
            _layout_cache_size -= _layout_ints(old_pads)
    else:
        _LAYOUT_CACHE.move_to_end(key)
    return entry


def cached_layout_np(src: PackedSource, pads: tuple) -> bitpack.PackedLayout:
    """Memoized host-side padded layout (batch scheduler stacking)."""
    return _layout_entry(src, pads)["np"]


def cached_layout_dev(src: PackedSource, pads: tuple) -> tuple:
    """Memoized device-resident layout operands (sequential probe):
    (words, widths, offsets, maxes, exc_pos, exc_add) jnp arrays."""
    entry = _layout_entry(src, pads)
    if entry["dev"] is None:
        lay = entry["np"]
        entry["dev"] = (jnp.asarray(lay.words), jnp.asarray(lay.widths),
                        jnp.asarray(lay.offsets), jnp.asarray(lay.maxes),
                        jnp.asarray(lay.exc_pos), jnp.asarray(lay.exc_add))
    return entry["dev"]


def decoded_ints_of(payload) -> int:
    """Integers materialized by a full decode of this payload."""
    if isinstance(payload, varint_lib.VarintList):
        return payload.n
    if bitpack.skip_capable(payload):
        return int(payload.widths.shape[0]) * payload.block_rows * bitpack.LANES
    return payload.n


def decode_padded(codec, tp) -> tuple[jnp.ndarray, int]:
    """Decode one term posting to (pow2-padded int32 vals, count)."""
    if isinstance(tp.payload, bitpack.PackedList):
        vals = np.asarray(bitpack.decode_bucketed(tp.payload))[: tp.n]
        vals = vals.astype(np.int32)
    elif isinstance(tp.payload, varint_lib.VarintList):
        vals = varint_lib.decode(tp.payload).astype(np.int32)   # tail codec
    else:
        vals = np.asarray(codec.decode(tp.payload))[: tp.n].astype(np.int32)
    size = its.pow2_bucket(tp.n)
    return jnp.asarray(its.pad_to(vals, size)), tp.n


def _bump(stats, key, by=1):
    if stats is not None:
        stats[key] = stats.get(key, 0) + by


def resolve(part, tid: int, tp, codec, cache=None, r_count: int | None = None,
            skip: bool = True, stats: dict | None = None):
    """Resolve one term posting to a DecodedSource or a PackedSource.

    r_count: current (or scheduled) candidate cardinality — None means this
    term *is* the candidate seed and must decode.  skip=False forces the
    decoded path everywhere (the pre-skip engine behavior, kept for A/B
    benchmarking).
    """
    key = (part.uid, tid)
    want_skip = (skip and r_count is not None
                 and bitpack.skip_capable(tp.payload)
                 and tp.n / max(r_count, 1) > SKIP_MIN_RATIO
                 and int(tp.payload.widths.shape[0]) >= SKIP_MIN_BLOCKS)
    if want_skip:
        # cache residency wins: an already-decoded list is free to reuse
        if cache is not None and key in cache:
            vals, n = cache.get(key)
            return DecodedSource(vals, n)
        return PackedSource(tp.payload, tp.n,
                            maxes_np=np.asarray(tp.payload.maxes), key=key)
    if cache is not None:
        hit = cache.get(key)
        if hit is not None:
            return DecodedSource(hit[0], hit[1])
    vals, n = decode_padded(codec, tp)
    _bump(stats, "decoded_ints", decoded_ints_of(tp.payload))
    _bump(stats, "decoded_lists")
    if cache is not None:
        cache.put(key, vals, n)
    return DecodedSource(vals, n)
