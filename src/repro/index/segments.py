"""Mutable segmented index: adds, deletes, background merge (DESIGN.md §2.14).

The engine below this layer is frozen at build time — every structure the
batched/fused/sharded serving stack touches (``IndexPart`` payloads, packed
layouts, ``ResidentPool`` entries, group signatures) assumes an immutable
posting store.  No real service runs on a read-only corpus, so this module
adds a tantivy-style segment lifecycle *on top of* that frozen machinery
instead of mutating it:

  mutable segment   new documents accumulate in a small append-only segment
                    (per-term python lists of ascending local doc ids) and
                    are served via the decoded path: a host-side sorted
                    intersection merged into results at collect time.  No
                    device program ever sees the mutable segment, so adds
                    can never change a group/fusion signature.
  sealed segments   ``seal()`` freezes the mutable segment into a normal
                    ``builder.build`` index (bitpacked + skip-indexed, same
                    codecs/autotuner as any build) covering a contiguous
                    global doc-id range.  A generation's serving view is the
                    concatenation of its sealed segments' parts, doc-range
                    shifted — ``batch.schedule`` / ``engine.query`` / the
                    sharded fan-out run on it unchanged.
  tombstones        deletes set one bit in a global doc-id-indexed bitmap
                    and are filtered at collect (``finalize``), after the
                    device programs ran: results stay byte-identical to a
                    rebuild-from-scratch (the filtered set is exactly the
                    rebuilt set, and both sides stay doc-id sorted), while
                    the launched programs — and therefore their signatures
                    — never see a delete at all.
  generations       the serving state is one atomically-swapped reference
                    ``_state = (Generation, MutableSegment)``.  Each
                    ``Generation`` owns its composed view plus its own
                    generation-tagged ``ResidentPool`` (or per-shard pools
                    via ``ShardedIndex``); queries grab the reference once
                    per batch and keep serving the old generation while a
                    new one is staged off to the side.  ``carry_from``
                    moves surviving segments' device buffers into the new
                    pool without re-decode or re-transfer, and part ``uid``s
                    are preserved across generations so the global layout
                    memo keeps hitting.
  background merge  ``merge()`` decodes the snapshot segments' live
                    postings (tombstoned docs drop out here — this is when
                    deletes are physically reclaimed), rebuilds them as one
                    segment, stages + optionally plan-warms the candidate
                    generation entirely off-lock, then swaps under the
                    mutation lock.  Serving never pauses: queries are
                    lock-free, and the only locked step is the reference
                    swap.  A ``hook(stage)`` seam is called at every merge
                    phase boundary so fault-injection tests can crash the
                    merge mid-flight and assert the old generation is still
                    serving, byte-identical.

Doc ids are append-only and never recycled: segments partition
``[0, next_doc_id)`` in base order and the mutable segment is always the
highest range, so per-part results concatenated in part order (what
``collect_batch`` already does) followed by the mutable hits are globally
sorted — the byte-identity invariant needs no re-sort anywhere.

Why signatures stay stable across a generation swap: ``GroupKey`` describes
operand *shapes* only (M/N/W buckets, algo, packed geometry), never pool or
part identity.  A swap changes which pool serves the gathers and which part
uids key the layout memo, but a warmed sticky ``FusionPlan`` covers the new
generation's groups whenever their family dims fit the existing monotone
ceilings — so steady-state serving stays at 0 compiles through seals and
merges that don't grow any family past its ceiling (and a merge can pre-warm
the candidate generation through the same plan before publishing it).
"""

from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

from repro.core import bitmap as bm
from repro.core import codecs as codec_lib
from repro.index import batch as batch_lib
from repro.index import builder
from repro.index import source
from repro.index.builder import HybridIndex, IndexPart, TermPosting
from repro.index.engine import QueryResult


_EMPTY = TermPosting("empty", None, 0)


class TermMap(dict):
    """Per-part term dict that answers *any* term id.

    The vocabulary grows as documents are added, but a sealed segment was
    built against the vocabulary of its own era — a query touching a newer
    term must see an empty posting in the old segment, not a KeyError.
    """

    def __missing__(self, tid):
        return _EMPTY


def _wrap_terms(index: HybridIndex) -> HybridIndex:
    for part in index.parts:
        if not isinstance(part.terms, TermMap):
            part.terms = TermMap(part.terms)
    return index


# --------------------------------------------------------------------------
# segments
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Segment:
    """One sealed, immutable doc-id range ``[doc_base, doc_hi)`` backed by a
    normal ``builder.build`` index over its local id space.  ``file`` names
    the segment's persisted raw-postings file in a ``DurableLog`` segment
    store (None while the index runs without a WAL)."""
    doc_base: int
    doc_hi: int
    index: HybridIndex
    file: "str | None" = None

    @property
    def span(self) -> int:
        return self.doc_hi - self.doc_base


class MutableSegment:
    """The append-only write buffer: per-term ascending local doc ids.

    Appends publish ``n_docs`` *last*, so any reader that slices postings
    by a ``cutoff`` it read from ``n_docs`` sees only complete documents —
    that is the whole consistency protocol of the decoded serving path.
    """

    def __init__(self, doc_base: int):
        self.doc_base = doc_base
        self.postings: dict[int, list[int]] = {}
        self.n_docs = 0

    def add(self, terms) -> int:
        lid = self.n_docs
        for t in terms:
            self.postings.setdefault(int(t), []).append(lid)
        self.n_docs = lid + 1          # publish after postings are complete
        return self.doc_base + lid

    def intersect(self, term_ids, cutoff: int) -> np.ndarray:
        """Sorted global doc ids matching the conjunction, restricted to
        the first ``cutoff`` docs (a snapshot's consistent prefix)."""
        empty = np.zeros(0, np.int64)
        if cutoff <= 0 or not term_ids:
            return empty
        arrs = []
        for t in term_ids:
            lst = self.postings.get(int(t))
            if not lst:
                return empty
            a = np.asarray(lst, dtype=np.int64)
            a = a[: int(np.searchsorted(a, cutoff))]    # ids are ascending
            if a.size == 0:
                return empty
            arrs.append(a)
        arrs.sort(key=len)
        r = arrs[0]
        for a in arrs[1:]:
            r = np.intersect1d(r, a, assume_unique=True)
            if r.size == 0:
                break
        return r + self.doc_base


@dataclasses.dataclass
class Generation:
    """One immutable serving epoch: the composed view over sealed segments
    plus the generation-tagged residency that serves it (a ``ResidentPool``
    single-device, a ``ShardedIndex`` with per-shard pools under fan-out)."""
    gid: int
    segments: list[Segment]
    view: HybridIndex
    pool: "source.ResidentPool | None"
    sharded: object = None          # shard.ShardedIndex | None

    def residency_stats(self) -> dict:
        if self.sharded is not None:
            return self.sharded.stats()
        return self.pool.stats() if self.pool is not None else {}


@dataclasses.dataclass
class Snapshot:
    """What one batch serves against: a generation reference plus a
    consistent prefix of the mutable segment.  Grabbing it is lock-free
    (one tuple read), and everything it points at is append-only or
    immutable, so a background swap can never corrupt an in-flight batch."""
    gen: Generation
    mseg: MutableSegment
    cutoff: int


class MergeAborted(RuntimeError):
    """A merge hook interrupted the merge; nothing was published."""


# --------------------------------------------------------------------------
# the mutable index
# --------------------------------------------------------------------------

class MutableIndex:
    """Segmented mutable index serving through the frozen batched engine.

    ``add``/``delete``/``seal``/``merge`` mutate under one re-entrant lock;
    queries never take it — they snapshot ``_state`` (one atomic tuple
    read) and run entirely against immutable or append-only structures.

    n_parts:  doc-range parts per sealed/merged segment (the L3/shard
              partitioning knob of ``builder.build``).
    n_shards: 0 = single-device generations with one ``ResidentPool``
              each; N = every generation is a ``ShardedIndex`` fan-out.
    """

    def __init__(self, *, codec_name: str = "bp-d1", B: int = 16,
                 n_parts: int = 1, n_shards: int = 0,
                 capacity_ints: int = 1 << 26,
                 varint_tail_below: int = 1024,
                 plan: "batch_lib.FusionPlan | None" = None,
                 wal=None):
        self.codec_name = codec_name
        self.B = B
        self.n_parts = max(n_parts, 1)
        self.n_shards = n_shards
        self.capacity_ints = capacity_ints
        self.varint_tail_below = varint_tail_below
        self.plan = plan if plan is not None else batch_lib.FusionPlan()
        self._lock = threading.RLock()
        self._next_id = 0
        self._vocab = 0
        self._dead = np.zeros(1024, dtype=bool)
        self._n_dead = 0
        self._gen_counter = 0
        self._merging = False
        self.n_seals = 0
        self.n_merges = 0
        self._last_merge_error: str | None = None
        self._merge_failures = 0
        # durability (DESIGN.md §2.15): when a DurableLog is attached,
        # every mutation is WAL-appended *before* it is applied, and
        # seal/merge/bootstrap commit atomic snapshots.  _wal_replaying
        # suppresses appends while recovery drives mutations back through
        # these same paths.
        self._wal = wal
        self._wal_replaying = False
        gen = self._new_generation([], carry=None)
        self._state: tuple[Generation, MutableSegment] = \
            (gen, MutableSegment(0))
        if wal is not None:
            wal.start_fresh()
            self._wal_checkpoint()

    # -- construction ------------------------------------------------------

    @classmethod
    def from_postings(cls, postings: list[np.ndarray], n_docs: int,
                      **kw) -> "MutableIndex":
        """Bootstrap from a frozen corpus: one initial sealed segment over
        ``[0, n_docs)`` built exactly as ``builder.build`` would."""
        mi = cls(**kw)
        with mi._lock:
            mi._vocab = len(postings)
            mi._next_id = n_docs
            mi._ensure_dead(n_docs)
            seg = mi._build_segment(0, n_docs, list(postings))
            if mi._wal is not None:
                mi._wal.persist_segment(seg, list(postings))
            gen = mi._new_generation([seg], carry=mi._state[0])
            mi._state = (gen, MutableSegment(n_docs))
            mi._wal_checkpoint()
        return mi

    @classmethod
    def recover(cls, directory: str, **kw) -> "MutableIndex":
        """Rebuild from a ``DurableLog`` directory: newest readable
        snapshot + WAL-tail replay, byte-identical to the pre-crash index
        (DESIGN.md §2.15)."""
        from repro.index import durability
        return durability.recover(directory, **kw)

    # -- mutation ----------------------------------------------------------

    def _ensure_dead(self, n: int):
        if n > self._dead.shape[0]:
            grown = np.zeros(max(2 * self._dead.shape[0], n + 1024),
                             dtype=bool)
            grown[: self._dead.shape[0]] = self._dead
            self._dead = grown

    def add(self, terms) -> int:
        """Add one document; returns its (permanent) global doc id."""
        terms = [int(t) for t in terms]
        if not terms:
            raise ValueError("a document needs at least one term")
        with self._lock:
            self._wal_append("add", {"terms": terms})
            self._vocab = max(self._vocab, max(terms) + 1)
            # grow the tombstone bitmap here (adds already hold the lock)
            # so delete() can always set its bit in place — an in-place
            # store is immediately visible to lock-free readers, a grown
            # copy would not be
            self._ensure_dead(self._next_id + 1)
            gid = self._state[1].add(terms)
            self._next_id = gid + 1
            return gid

    def delete(self, doc_id: int) -> bool:
        """Tombstone one document (idempotent).  Takes effect immediately:
        collect-time filtering reads the shared bitmap, no rebuild, no
        generation swap, no signature change."""
        with self._lock:
            if not (0 <= doc_id < self._next_id):
                raise KeyError(f"doc id {doc_id} was never assigned")
            if self._dead[doc_id]:
                return False
            self._wal_append("delete", {"doc": int(doc_id)})
            self._dead[doc_id] = True
            self._n_dead += 1
            return True

    def seal(self) -> "Segment | None":
        """Freeze the mutable segment into a sealed one and publish a new
        generation.  Concurrent queries keep serving the old state until
        the single reference swap; concurrent adds briefly wait here.

        Crash protocol (DESIGN.md §2.15): the ``seal`` WAL record lands
        first, then the in-memory apply, then the snapshot checkpoint.  A
        crash before the append loses nothing; after the append, replaying
        the old snapshot + WAL re-derives the identical sealed segment
        (the builder is deterministic); after the checkpoint, the new
        manifest is authoritative and the record is never replayed."""
        with self._lock:
            gen, mseg = self._state
            if mseg.n_docs == 0:
                return None
            self._wal_append("seal", {})
            seg = self._apply_seal()
            self._wal_checkpoint()
            return seg

    def _apply_seal(self) -> "Segment":
        """The in-memory seal (lock held, mutable segment non-empty)."""
        gen, mseg = self._state
        postings = [
            np.asarray(mseg.postings.get(t, []), dtype=np.int64)
            for t in range(self._vocab)]
        seg = self._build_segment(mseg.doc_base, mseg.n_docs, postings)
        if self._wal is not None:
            self._wal.persist_segment(seg, postings)
        new_gen = self._new_generation(gen.segments + [seg], carry=gen)
        self._state = (new_gen, MutableSegment(self._next_id))
        self.n_seals += 1
        return seg

    # -- durability hooks (DESIGN.md §2.15) --------------------------------

    def _wal_append(self, rtype: str, payload: dict) -> None:
        if self._wal is not None and not self._wal_replaying:
            self._wal.append(rtype, payload)

    def _wal_config(self) -> dict:
        return {"codec_name": self.codec_name, "B": self.B,
                "n_parts": self.n_parts, "n_shards": self.n_shards,
                "capacity_ints": self.capacity_ints,
                "varint_tail_below": self.varint_tail_below}

    def _wal_checkpoint(self) -> None:
        """Commit the full serving state as an atomic snapshot and rotate
        the WAL.  The mutable segment is part of the snapshot, so rotation
        never strands an un-sealed add in a discarded epoch."""
        if self._wal is None or self._wal_replaying:
            return
        from repro.index import durability
        with self._lock:
            gen, mseg = self._state
            entries = []
            for s in sorted(gen.segments, key=lambda s: s.doc_base):
                if s.file is None:
                    raise durability.WalError(
                        f"segment [{s.doc_base},{s.doc_hi}) was never "
                        f"persisted — cannot checkpoint")
                entries.append({"base": int(s.doc_base),
                                "hi": int(s.doc_hi), "file": s.file})
            self._wal.checkpoint({
                "config": self._wal_config(),
                "segments": entries,
                "mseg_base": mseg.doc_base,
                "mseg_n_docs": mseg.n_docs,
                "mseg_postings": mseg.postings,
                "dead_ids": np.flatnonzero(self._dead[: self._next_id]),
                "next_doc_id": self._next_id,
                "vocab": self._vocab,
                "counters": {"n_seals": self.n_seals,
                             "n_merges": self.n_merges,
                             "gen_counter": self._gen_counter},
            })

    # -- segment building / generations ------------------------------------

    def _build_segment(self, base: int, span: int,
                       postings: list[np.ndarray]) -> Segment:
        idx = builder.build(postings, span, codec_name=self.codec_name,
                            B=self.B, n_parts=min(self.n_parts, max(span, 1)),
                            varint_tail_below=self.varint_tail_below)
        return Segment(base, base + span, _wrap_terms(idx))

    def _compose_view(self, segments: list[Segment]) -> HybridIndex:
        """The serving view: every segment's parts doc-range-shifted into
        global id space, in base order.  Part ``uid``s are preserved so
        layout memos and carried pool entries keep their keys across
        generations."""
        parts = []
        for seg in sorted(segments, key=lambda s: s.doc_base):
            for p in seg.index.parts:
                parts.append(IndexPart(doc_lo=seg.doc_base + p.doc_lo,
                                       doc_hi=seg.doc_base + p.doc_hi,
                                       terms=p.terms, uid=p.uid))
        return HybridIndex(n_docs=max(self._next_id, 1), B=self.B,
                           codec_name=self.codec_name, parts=parts)

    def _new_generation(self, segments: list[Segment], *,
                        carry: Generation | None,
                        pool: "source.ResidentPool | None" = None
                        ) -> Generation:
        view = self._compose_view(segments)
        with self._lock:
            gid = self._gen_counter
            self._gen_counter += 1
        if self.n_shards:
            from repro.index import shard as shard_lib
            sharded = shard_lib.shard_index(
                view, self.n_shards, capacity_ints=self.capacity_ints,
                warm=True)
            return Generation(gid, segments, view, None, sharded)
        if pool is None:
            pool = source.ResidentPool(capacity_ints=self.capacity_ints,
                                       tag=gid)
            if carry is not None and carry.pool is not None:
                pool.carry_from(carry.pool)
        pool.tag = gid
        pool.warm(view)
        return Generation(gid, segments, view, pool, None)

    # -- background merge --------------------------------------------------

    def merge(self, *, hook=None, warm_queries=None,
              backend: str = "jax") -> bool:
        """Compact all sealed segments of the current generation into one,
        dropping tombstoned docs, and swap the new generation in.

        Designed to run on a background thread: every heavy phase (decode,
        build, pool staging, plan warm) happens before the lock is taken,
        and the locked step is the reference swap.  ``hook(stage)`` is
        called at each phase boundary (stages: ``snapshot``, ``decode``,
        ``build``, ``stage``, ``warm``, ``swap``) — an exception raised
        there aborts the merge with the old generation untouched, and a
        retry converges because nothing was published.  ``warm_queries``
        pre-warms the candidate generation's fused signatures through the
        shared sticky plan so the swap does not invalidate warmed steady
        state."""
        with self._lock:
            if self._merging:
                return False
            self._merging = True
        try:
            hook = hook or (lambda stage: None)
            with self._lock:
                gen, _ = self._state
                segs = list(gen.segments)
                vocab = self._vocab
            lo = min((s.doc_base for s in segs), default=0)
            hi = max((s.doc_hi for s in segs), default=0)
            in_range = int(self._dead[lo:hi].sum()) if hi > lo else 0
            if len(segs) < 2 and in_range == 0:
                return False                   # nothing to compact
            hook("snapshot")

            postings = self._decode_live(segs, vocab, lo)
            hook("decode")
            merged = self._build_segment(lo, hi - lo, postings)
            if self._wal is not None:
                # persist while the postings are in hand; unreferenced
                # until the swap checkpoint, pinned against pruning, and
                # a harmless orphan if the merge aborts before it
                self._wal.persist_segment(merged, postings)
            hook("build")

            # stage the candidate generation completely off-lock: carried
            # entries reuse the old generation's device buffers, merged
            # lists pay their one decode+transfer here, not on the query
            # path after the swap
            cand_segs = sorted([merged] + [s for s in segs
                                           if s.doc_hi > hi or s.doc_base < lo],
                               key=lambda s: s.doc_base)
            pool = None
            if not self.n_shards:
                pool = source.ResidentPool(capacity_ints=self.capacity_ints)
                if gen.pool is not None:
                    pool.carry_from(gen.pool)
            cand = self._new_generation(cand_segs, carry=gen, pool=pool)
            hook("stage")
            if warm_queries:
                self._warm_generation(cand, warm_queries, backend=backend)
            hook("warm")

            hook("swap")
            with self._lock:
                cur, mseg = self._state
                snap_set = set(map(id, segs))
                late = [s for s in cur.segments if id(s) not in snap_set]
                if late:
                    # a seal published between snapshot and swap: rebuild
                    # the generation with the late segments included
                    # (carried from the candidate, so only the late ones
                    # pay staging inside the lock — they are small)
                    cand = self._new_generation(
                        sorted(cand_segs + late, key=lambda s: s.doc_base),
                        carry=cand, pool=cand.pool)
                self._state = (cand, mseg)
                self.n_merges += 1
                self._wal_checkpoint()
            return True
        finally:
            with self._lock:
                self._merging = False

    def merge_async(self, *, retries: int = 2,
                    retry_backoff_s: float = 0.05,
                    max_backoff_s: float = 2.0, **kw) -> threading.Thread:
        """Run ``merge`` on a daemon thread (serving continues lock-free
        while it compacts); join the returned thread to wait for it.

        A failed merge never dies silently: the exception is recorded as
        ``counters()['last_merge_error']`` (cleared on the next success),
        ``merge_failures`` is bumped, and the merge is retried up to
        ``retries`` times with capped exponential backoff.  The old
        generation keeps serving throughout — merge aborts publish
        nothing, as the stage-crash tests guarantee."""
        def run():
            delay = retry_backoff_s
            for attempt in range(retries + 1):
                try:
                    self.merge(**kw)
                except Exception as e:       # noqa: BLE001 — surfaced below
                    with self._lock:
                        self._last_merge_error = f"{type(e).__name__}: {e}"
                        self._merge_failures += 1
                    if attempt == retries:
                        return
                    time.sleep(delay)
                    delay = min(delay * 2, max_backoff_s)
                else:
                    with self._lock:
                        self._last_merge_error = None
                    return

        t = threading.Thread(target=run, daemon=True)
        t.start()
        return t

    def _decode_live(self, segs: list[Segment], vocab: int,
                     base: int) -> list[np.ndarray]:
        """Decode every segment's postings back to global doc ids (this is
        the only place sealed payloads are ever decompressed outside
        serving), drop tombstoned docs, and re-base to the merged span.
        Segments and parts iterate in doc order, so concatenation keeps
        every list sorted."""
        acc: list[list[np.ndarray]] = [[] for _ in range(vocab)]
        dead = self._dead
        for seg in sorted(segs, key=lambda s: s.doc_base):
            codec = codec_lib.get_codec(seg.index.codec_name)
            for part in seg.index.parts:
                off = seg.doc_base + part.doc_lo
                for tid, tp in part.terms.items():
                    if tp.kind == "empty" or tid >= vocab:
                        continue
                    if tp.kind == "bitmap":
                        loc = bm.extract_np(np.asarray(tp.payload))
                    else:
                        vals, n = source.decode_padded_np(codec, tp)
                        loc = vals[:n]
                    g = loc.astype(np.int64) + off
                    g = g[~dead[g]]
                    if g.size:
                        acc[tid].append(g - base)
        return [np.concatenate(a) if a else np.zeros(0, np.int64)
                for a in acc]

    def _warm_generation(self, gen: Generation, queries, *,
                         backend: str = "jax"):
        """Drive the candidate generation through the shared sticky plan to
        the signature fixed point before it is published.  Walks the same
        ×1.5 batch-row ladder ``warm_server`` uses: live flushes are
        variable sized, and every row bucket against the *merged* geometry
        is a distinct program — a single full-batch pass would leave the
        small buckets cold and the first post-swap deadline flush would
        compile."""
        snap = Snapshot(gen, MutableSegment(self._next_id), 0)
        sizes, b = [], 1
        while b < len(queries):
            sizes.append(b)
            b = b * 3 // 2 if b >= 2 else b + 1
        sizes.append(len(queries))

        def one_pass(stats):
            for size in sizes:
                for lo in range(0, len(queries), size):
                    chunk = queries[lo: lo + size]
                    groups = self.schedule(snap, chunk, stats=stats)
                    groups = batch_lib.fuse_groups(groups, plan=self.plan,
                                                   stats=stats)
                    batch_lib.collect_batch(self.launch(
                        snap, groups, len(chunk), backend=backend,
                        stats=stats))

        batch_lib.warm_to_fixed_point(one_pass)

    # -- serving -----------------------------------------------------------

    def snapshot(self) -> Snapshot:
        gen, mseg = self._state
        return Snapshot(gen, mseg, mseg.n_docs)

    def schedule(self, snap: Snapshot, queries, *, stats=None, cache=None):
        """``batch.schedule`` over the snapshot generation (raw groups —
        the caller applies fusion so admission accounting like the
        server's ``plan_covers`` check stays possible)."""
        gen = snap.gen
        pool = (gen.sharded.pool_map if gen.sharded is not None
                else gen.pool)
        return batch_lib.schedule(gen.view, queries, cache=cache,
                                  stats=stats, pool=pool)

    def launch(self, snap: Snapshot, groups, n_queries: int, *,
               backend: str = "jax", max_results: int = 1 << 16,
               max_group_size: int = batch_lib.MAX_GROUP_SIZE,
               stats=None) -> "batch_lib.PendingBatch":
        gen = snap.gen
        if gen.sharded is not None:
            from repro.index import shard as shard_lib
            return shard_lib.launch_groups_sharded(
                gen.sharded, groups, n_queries=n_queries, backend=backend,
                max_results=max_results, max_group_size=max_group_size,
                stats=stats)
        return batch_lib.launch_groups(
            groups, n_queries=n_queries, backend=backend,
            max_results=max_results, max_group_size=max_group_size,
            pool=gen.pool, stats=stats)

    def finalize(self, snap: Snapshot, queries, results,
                 max_results: int = 1 << 16) -> list[QueryResult]:
        """Collect-time completion: filter tombstones out of the sealed
        hits, append the mutable segment's decoded-path hits (its doc ids
        are the highest range, so plain concatenation stays sorted), and
        recount."""
        dead = self._dead
        out = []
        for q, r in zip(queries, results):
            docs = r.docs
            if docs.size:
                docs = docs[~dead[docs]]
            mdocs = snap.mseg.intersect(q, snap.cutoff)
            if mdocs.size:
                mdocs = mdocs[~dead[mdocs]]
                docs = np.concatenate([docs, mdocs]) if docs.size else mdocs
            out.append(QueryResult(count=int(docs.size),
                                   docs=docs[:max_results]))
        return out

    def execute_batch(self, queries, *, backend: str = "jax",
                      fuse: bool = True, stats=None, cache=None,
                      max_results: int = 1 << 16) -> list[QueryResult]:
        """One-call serving path, byte-identical to rebuilding the live
        corpus from scratch and running ``batch.execute_batch`` on it."""
        snap = self.snapshot()
        groups = self.schedule(snap, queries, stats=stats, cache=cache)
        if fuse:
            groups = batch_lib.fuse_groups(groups, plan=self.plan,
                                           stats=stats)
        pending = self.launch(snap, groups, len(queries), backend=backend,
                              stats=stats)
        results = batch_lib.collect_batch(pending)
        return self.finalize(snap, queries, results, max_results)

    def warm(self, queries, *, backend: str = "jax", fuse: bool = True
             ) -> dict:
        """Warm the current generation's signatures (and pools) to the
        fixed point through the same path serving uses."""
        t0 = time.perf_counter()
        c0 = batch_lib._compile_count()
        n_sigs, passes, converged = batch_lib.warm_to_fixed_point(
            lambda s: self.execute_batch(queries, backend=backend,
                                         fuse=fuse, stats=s))
        return {"n_compiles": batch_lib._compile_count() - c0,
                "n_signatures": n_sigs, "passes": passes,
                "converged": converged,
                "time_s": time.perf_counter() - t0}

    # -- introspection -----------------------------------------------------

    @property
    def next_doc_id(self) -> int:
        return self._next_id

    @property
    def generation(self) -> int:
        return self._state[0].gid

    def live_postings(self) -> list[np.ndarray]:
        """The rebuild-from-scratch oracle's input: per-term sorted global
        doc ids of every live (non-tombstoned) document.  Decodes sealed
        payloads — test/diagnostic use, not a serving path."""
        with self._lock:
            gen, mseg = self._state
            vocab = self._vocab
            cutoff = mseg.n_docs
        sealed = self._decode_live(gen.segments, vocab, 0)
        dead = self._dead
        out = []
        for t in range(vocab):
            parts = [sealed[t]] if sealed[t].size else []
            lst = mseg.postings.get(t)
            if lst:
                a = np.asarray(lst, dtype=np.int64)
                a = a[: int(np.searchsorted(a, cutoff))] + mseg.doc_base
                a = a[~dead[a]]
                if a.size:
                    parts.append(a)
            out.append(np.concatenate(parts) if parts
                       else np.zeros(0, np.int64))
        return out

    def counters(self) -> dict:
        """The build-banner counters: segment/tombstone/generation state."""
        gen, mseg = self._state
        return {"generation": gen.gid,
                "n_segments": len(gen.segments),
                "mutable_docs": mseg.n_docs,
                "tombstones": self._n_dead,
                "next_doc_id": self._next_id,
                "vocab": self._vocab,
                "n_seals": self.n_seals,
                "n_merges": self.n_merges,
                "last_merge_error": self._last_merge_error,
                "merge_failures": self._merge_failures}

    def stats(self) -> dict:
        gen, _ = self._state
        return {**self.counters(),
                "residency": gen.residency_stats(),
                "index": gen.view.stats() if gen.view.parts else {}}
