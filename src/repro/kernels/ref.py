"""Pure-jnp oracles for every Pallas kernel in this package.

These wrap the vectorized library implementations in ``repro.core`` (which are
themselves validated against numpy scalar oracles in tests/test_core_*), so
the chain is: Pallas kernel ≡ jnp library ≡ numpy scalar reference.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import bitpack as core_bitpack
from repro.core import deltas as core_deltas
from repro.core import intersect as core_intersect


def unpack_blocks_ref(padded_words, widths, seeds, mode: str = "d1"):
    """(K, 32, 128) packed words → (K, 32, 128) values (integrated decode)."""
    K = padded_words.shape[0]
    rows = padded_words.shape[1]
    flat = padded_words.reshape(K * rows, core_bitpack.LANES)
    offsets = jnp.arange(K, dtype=jnp.int32) * rows
    d = core_bitpack.unpack_deltas(flat, widths.astype(jnp.int32), offsets,
                                   block_rows=rows)
    return core_deltas.prefix_sum(d, seeds, mode)


def pack_blocks_ref(deltas, widths):
    """(K, 32, 128) deltas → (K, 32, 128) block-padded packed words (jnp).

    Width-generic vector packing: word w of a lane collects contributions of
    every row r whose bit-range [r·b, r·b+b) overlaps [32w, 32w+32).
    """
    K, R, L = deltas.shape
    d = deltas.astype(jnp.uint32)
    b = widths.astype(jnp.uint32)[:, None, None]          # (K,1,1)
    r = jnp.arange(R, dtype=jnp.uint32)[None, :, None]    # (1,R,1)
    w = jnp.arange(R, dtype=jnp.uint32)[None, None, :]    # (1,1,R) word index
    start = r * b
    # contribution of row r to word w, lane-wise
    lo_sel = (start >> 5) == w
    sh = (start & 31)
    hi_sel = ((start >> 5) + 1 == w) & ((sh + b) > 32)
    lo = jnp.where(lo_sel[..., None], d[:, :, None, :] << sh[..., None], 0)
    hi = jnp.where(hi_sel[..., None],
                   d[:, :, None, :] >> (((jnp.uint32(32) - sh) & 31)[..., None]),
                   0)
    out = (lo | hi)
    # OR-reduce over rows → use bitwise accumulate via sum of disjoint bits?
    # contributions can share a word but never share bits → OR == sum is NOT
    # safe in general; emulate OR-reduce with a fori-free reduce:
    acc = out[:, 0]
    for rr in range(1, R):
        acc = acc | out[:, rr]
    return acc


def intersect_gallop_ref(r, f):
    """mask over sentinel-padded r (vectorized searchsorted)."""
    return core_intersect.intersect_gallop(r, f)
