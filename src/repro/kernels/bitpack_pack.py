"""Pallas TPU kernel: block bit packing (encode side of paper §3).

One grid step packs one (32, 128) delta tile into a (32, 128) word tile whose
first ``b`` rows are the packed words (the rest zero) — the block-padded
mirror of the unpack kernel.  Delta computation happens in the jnp wrapper
(``ops.pack_blocks``): 'computing deltas during compression is an inexpensive
operation' (paper §4); the kernel is the bit-shuffle hot loop.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

ROWS = 32
LANES = 128


def pack_kernel(widths_ref, deltas_ref, out_ref):
    k = pl.program_id(0)
    b = widths_ref[k].astype(jnp.uint32)
    d = deltas_ref[0]                              # (32, 128) uint32
    out = jnp.zeros((ROWS, LANES), dtype=jnp.uint32)
    for r in range(ROWS):                          # static unroll
        start = jnp.uint32(r) * b
        w = (start >> 5).astype(jnp.int32)
        sh = start & 31
        val = d[r]
        lo_word = lax.dynamic_index_in_dim(out, w, axis=0, keepdims=False)
        lo_word = lo_word | (val << sh)
        out = lax.dynamic_update_index_in_dim(out, lo_word, w, axis=0)
        spill = (sh + b) > 32
        w1 = jnp.minimum(w + 1, ROWS - 1)
        hi_word = lax.dynamic_index_in_dim(out, w1, axis=0, keepdims=False)
        hi_add = jnp.where(spill, val >> ((jnp.uint32(32) - sh) & 31),
                           jnp.uint32(0))
        out = lax.dynamic_update_index_in_dim(out, hi_word | hi_add, w1, axis=0)
    out_ref[0] = out


@partial(jax.jit, static_argnames=("interpret",))
def pack_blocks_padded(deltas, widths, interpret: bool = True):
    """deltas: (K, 32, 128) uint32 (< 2**width per block); widths: (K,).
    Returns (K, 32, 128) uint32 block-padded packed words."""
    from jax.experimental.pallas import tpu as pltpu

    K = deltas.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(K,),
        in_specs=[pl.BlockSpec((1, ROWS, LANES), lambda k, *_: (k, 0, 0))],
        out_specs=pl.BlockSpec((1, ROWS, LANES), lambda k, *_: (k, 0, 0)),
    )
    return pl.pallas_call(
        pack_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((K, ROWS, LANES), jnp.uint32),
        interpret=interpret,
    )(widths.astype(jnp.int32), deltas.astype(jnp.uint32))
