"""jit'd public wrappers around the Pallas kernels.

Kernel execution mode (DESIGN.md §2.12): the kernels target TPU v5e, so
at import we *probe* the runtime backend — compiled Mosaic lowering when
``jax.default_backend() == "tpu"``, Pallas interpret mode everywhere else
(this container is CPU-only).  The REPRO_PALLAS_INTERPRET env var is an
explicit override in either direction (``0`` forces compiled, anything
else forces interpret); ``set_kernel_mode("compiled"|"interpret"|"auto")``
re-resolves at runtime (``serve.py --kernel-mode``).  ``INTERPRET`` stays
the module-level switch every wrapper reads at call time, so existing
``ops.INTERPRET = ...`` assignments keep working.  Benchmarks must record
``kernel_mode()`` next to any Pallas number — interpret-mode timings are
not comparable to compiled ones and the bench compare gate refuses to
ratio across modes.
"""

from __future__ import annotations

import os
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.core import bitpack as core_bitpack
from repro.core import deltas as core_deltas
from repro.core.intersect import SENTINEL, pad_to, pow2_bucket  # noqa: F401
from repro.kernels import bitunpack as _bitunpack
from repro.kernels import bitpack_pack as _bitpack_pack
from repro.kernels import intersect_gallop as _intersect_gallop
from repro.kernels import megakernel as _megakernel


def probe_kernel_mode() -> str:
    """Capability probe: can the runtime backend execute our Mosaic/TPU
    kernels natively?  Compiled only on TPU — the kernels use TPU grid
    semantics (sequential revisited output blocks, PrefetchScalarGridSpec),
    so GPU/CPU fall back to interpret."""
    return "compiled" if jax.default_backend() == "tpu" else "interpret"


def resolve_kernel_mode(mode: str = "auto") -> str:
    """Resolve a requested mode to 'compiled' | 'interpret'.  'auto' honors
    the REPRO_PALLAS_INTERPRET env override when set, else the probe."""
    if mode == "auto":
        env = os.environ.get("REPRO_PALLAS_INTERPRET")
        if env is not None:
            return "interpret" if env != "0" else "compiled"
        return probe_kernel_mode()
    if mode not in ("compiled", "interpret"):
        raise ValueError(f"unknown kernel mode {mode!r}")
    return mode


INTERPRET = resolve_kernel_mode() == "interpret"


def kernel_mode() -> str:
    """The effective execution mode of every kernel wrapper in this module."""
    return "interpret" if INTERPRET else "compiled"


def set_kernel_mode(mode: str = "auto") -> str:
    """Set the module-wide kernel mode; returns the resolved mode."""
    global INTERPRET
    INTERPRET = resolve_kernel_mode(mode) == "interpret"
    return kernel_mode()


ROWS = _bitunpack.ROWS
LANES = _bitunpack.LANES
GALLOP_VMEM_CAP = 1 << 20          # max f ints resident in VMEM (4 MiB)


# --------------------------------------------------------------------------
# padding helpers (flat packed words ↔ block-padded kernel layout)
# --------------------------------------------------------------------------

@jax.jit
def pad_packed(flat_words, offsets):
    """Gather flat (T,128) packed words into (K, 32, 128) block-padded form.
    T == 0 must short-circuit: ``clip(..., 0, T-1)`` would clamp to index
    -1 and ``jnp.take`` silently wraps negative indices, so an empty
    payload would gather garbage instead of zero blocks."""
    T = flat_words.shape[0]
    if T == 0:
        return jnp.zeros((offsets.shape[0], ROWS, LANES), flat_words.dtype)
    idx = jnp.clip(offsets[:, None] + jnp.arange(ROWS, dtype=jnp.int32)[None],
                   0, T - 1)
    return jnp.take(flat_words, idx, axis=0)


# --------------------------------------------------------------------------
# decode / encode
# --------------------------------------------------------------------------

def unpack_blocks(padded_words, widths, seeds, mode: str = "d1"):
    return _bitunpack.unpack_blocks(padded_words, widths, seeds, mode=mode,
                                    interpret=INTERPRET)


def decode_packed(plist: core_bitpack.PackedList) -> jnp.ndarray:
    """Kernel-path decode of a PackedList → flat padded values."""
    assert plist.block_rows == ROWS, \
        "Pallas kernels are specialized to 32-row (4096-int) blocks"
    padded = pad_packed(plist.flat_words, plist.offsets)
    seeds = core_bitpack.seeds_of(plist)
    vals = unpack_blocks(padded, plist.widths, seeds, mode=plist.mode)
    return vals.reshape(-1)


def decode_packed_ni(plist: core_bitpack.PackedList) -> jnp.ndarray:
    """Two-pass (-NI) kernel decode: unpack (mode='none') then a separate
    prefix-sum pass — the paper's Fig. 1a comparison point."""
    padded = pad_packed(plist.flat_words, plist.offsets)
    seeds = core_bitpack.seeds_of(plist)
    zero_seeds = jnp.zeros_like(seeds)
    d = unpack_blocks(padded, plist.widths, zero_seeds, mode="none")
    jax.block_until_ready(d)
    return core_deltas.prefix_sum(d, seeds, plist.mode).reshape(-1)


def pack_blocks(values, seeds, widths, mode: str = "d1"):
    """values: (K, 32, 128) uint32 sorted; returns (K, 32, 128) padded words."""
    d = core_deltas.encode_deltas_jnp(values, seeds, mode)
    return _bitpack_pack.pack_blocks_padded(d, widths, interpret=INTERPRET)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal: bool = True, kv_len=None,
                    bq: int = 512, bk: int = 512):
    """Flash attention fwd (GQA-aware); see kernels/flash_attention.py."""
    from repro.kernels.flash_attention import flash_attention as _fa
    return _fa(q, k, v, causal=causal, kv_len=kv_len, bq=bq, bk=bk,
               interpret=INTERPRET)


# --------------------------------------------------------------------------
# intersection
# --------------------------------------------------------------------------

def intersect_gallop(r, f):
    """Kernel-path galloping intersection; falls back to two-level block-skip
    probing when f exceeds the VMEM cap (DESIGN.md §2.4)."""
    M = r.shape[0]
    m_pad = (-M) % _intersect_gallop.TILE_R
    if m_pad:
        r = jnp.concatenate(
            [r, jnp.full((m_pad,), SENTINEL, dtype=jnp.int32)])
    N = f.shape[0]
    n_pow = pow2_bucket(N, floor=_intersect_gallop.TILE_R)
    if n_pow > GALLOP_VMEM_CAP:
        from repro.core import intersect as core_intersect
        mask = core_intersect.intersect_gallop(r, f)
        return mask[:M]
    if n_pow != N:
        f = jnp.concatenate(
            [f, jnp.full((n_pow - N,), SENTINEL, dtype=jnp.int32)])
    mask = _intersect_gallop.gallop_tiles(r, f, interpret=INTERPRET)
    return mask[:M]


def intersect_gallop_batch(r, f):
    """Kernel-path batched galloping: r (B, M), f (B, N) → (B, M) mask.
    Inputs must already be sentinel-padded to M % 128 == 0 and N a power of
    two (index/batch.py buckets guarantee this); falls back to the vmapped
    jnp path when a query's long list exceeds the VMEM cap."""
    if f.shape[-1] > GALLOP_VMEM_CAP:
        from repro.core import intersect as core_intersect
        return core_intersect.intersect_gallop_batch(r, f)
    return _intersect_gallop.gallop_tiles_batched(r, f, interpret=INTERPRET)


def intersect_packed_batch(r, words, widths, offsets, maxes, blk_ids,
                           exc_pos, exc_add, mode: str, block_rows: int):
    """Kernel-path batched packed gallop: decode only the candidate blocks of
    each row's compressed list in VMEM, then binary-search the candidates
    against the partially decoded buffer (one fused kernel; DESIGN.md §2.6).
    Falls back to the jnp path when the decoded candidate buffer plus the
    VMEM-resident compressed words would not fit the VMEM budget."""
    per = block_rows * LANES
    resident = blk_ids.shape[-1] * per + words.shape[-2] * LANES
    if resident > GALLOP_VMEM_CAP:
        from repro.core import intersect as core_intersect
        return core_intersect.intersect_packed_batch(
            r, words, widths, offsets, maxes, blk_ids, exc_pos, exc_add,
            mode=mode, block_rows=block_rows)
    return _intersect_gallop.packed_gallop_batched(
        r, words, widths, offsets, maxes, blk_ids, exc_pos, exc_add,
        mode=mode, block_rows=block_rows, interpret=INTERPRET)


# --------------------------------------------------------------------------
# fused fold megakernels (DESIGN.md §2.12)
# --------------------------------------------------------------------------

def _fold_scan(r, valid, stack, active, intersect_fn):
    """VMEM-overflow fallback: per-fold kernel launches under a lax.scan,
    same mask-fold semantics as the megakernels (and as
    ``batch._mask_fold_scan``, which this mirrors to avoid a circular
    import of the scheduler from the kernel layer)."""
    def step(v, xs):
        op, act = xs
        hit = intersect_fn(r, op)
        return v & jnp.where(act[:, None], hit, True), None

    valid, _ = lax.scan(step, valid, (stack, active))
    return valid


def intersect_fold_batch(r, valid, folds, fold_active):
    """Fused decoded SvS fold: ONE kernel launch ANDs the match masks of the
    whole (J, B, N) fold stack into ``valid`` (grid (B, J), the output mask
    block revisited across j).  Falls back to a scan of per-fold gallop
    launches when a fold row exceeds the VMEM cap."""
    if folds.shape[0] == 0:
        return valid
    if folds.shape[-1] > GALLOP_VMEM_CAP:
        return _fold_scan(r, valid, folds, fold_active,
                          intersect_gallop_batch)
    return _megakernel.decoded_fold_batched(r, valid, folds, fold_active,
                                            interpret=INTERPRET)


def intersect_packed_fold(r, valid, pk, pk_active, mode: str,
                          block_rows: int):
    """Fused packed SvS fold: ONE kernel launch decodes each (j, b) slot's
    candidate blocks in VMEM scratch and ANDs the gallop match masks of the
    whole (Jp, B, ...) packed stack into ``valid`` — no materialized
    decoded array (DESIGN.md §2.12).  ``pk`` is the stacked operand tuple
    in ``batch._compose_pk`` order.  Falls back to a scan of per-fold
    packed-gallop launches when one slot's scratch + resident compressed
    words exceed the VMEM budget."""
    words, widths, offsets, maxes, blk_ids, exc_pos, exc_add = pk
    if words.shape[0] == 0:
        return valid
    per = block_rows * LANES
    resident = blk_ids.shape[-1] * per + words.shape[-2] * LANES
    if resident > GALLOP_VMEM_CAP:
        return _fold_scan(
            r, valid, pk, pk_active,
            lambda rr, op: intersect_packed_batch(
                rr, *op, mode=mode, block_rows=block_rows))
    return _megakernel.packed_fold_batched(
        r, valid, words, widths, offsets, maxes, blk_ids, exc_pos, exc_add,
        pk_active, mode=mode, block_rows=block_rows, interpret=INTERPRET)
