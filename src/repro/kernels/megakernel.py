"""Pallas TPU megakernels: the whole SvS fold chain in ONE launch.

The per-fold kernels in ``intersect_gallop.py`` run one intersect stage per
``pallas_call``; the group program then scans J such launches, and each
packed stage re-enters the kernel with a freshly staged operand set.  The
megakernels here collapse the scan into the kernel grid (DESIGN.md §2.12):

* ``decoded_fold_batched`` — grid (B, J).  Step (b, j) gallop-probes seed
  row b against decoded fold j and ANDs the match mask into the row's
  running validity mask.
* ``packed_fold_batched`` — grid (B, Jp).  Step (b, j) gathers the
  candidate blocks of row b's j-th *compressed* list, bit-unpacks them
  into kernel scratch (``bitunpack.decode_candidates`` — the same
  shift/mask machinery as the Algorithm-1 unpack kernel), patches
  FastPFOR exceptions, prefix-sums deltas in-register, and gallop-probes
  the seed row against the scratch window.  No decoded array is ever
  materialized in HBM: decode volume per step is C·block ints of VMEM
  scratch, freed when the step retires.

Both kernels accumulate into the same output block: the out BlockSpec maps
every j to row b's (1, M) mask block, the innermost grid axis revisits it
J times, and ``pl.when(j == 0)`` seeds it from the incoming validity mask.
TPU grids execute sequentially with the last axis innermost, so the
revisited block stays resident in VMEM across the J steps and is flushed
once per row — this is the mask-fold contract of DESIGN.md §2.10 moved
inside the kernel, which is why ``collect_batch`` needs no changes.

Inactive (j, b) slots (fused family arity ceilings pad J/Jp past each
row's real fold count) AND ``True`` — their mask contribution is the
identity, exactly like the host-side ``_mask_fold_scan``.
"""

from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import bitpack as core_bitpack
from repro.kernels import bitunpack as _bitunpack
from repro.kernels.intersect_gallop import _gallop_body

LANES = core_bitpack.LANES


# --------------------------------------------------------------------------
# decoded folds: unpacked short lists, one gallop per (row, fold)
# --------------------------------------------------------------------------

def make_decoded_fold_kernel(log2n: int):
    def kernel(r_ref, v_ref, f_ref, act_ref, out_ref):
        j = pl.program_id(1)

        @pl.when(j == 0)
        def _seed_mask():
            out_ref[0] = v_ref[0]

        hit = _gallop_body(r_ref[0], f_ref[0, 0], log2n)
        act = act_ref[0, 0] != 0
        out_ref[0] = out_ref[0] & jnp.where(act, hit, True)
    return kernel


@partial(jax.jit, static_argnames=("interpret",))
def decoded_fold_batched(r, valid, folds, fold_active, interpret: bool = True):
    """Fused decoded SvS fold: r (B, M) sentinel-padded int32, valid (B, M)
    bool, folds (J, B, N) sentinel-padded with N a power of two,
    fold_active (J, B).  Returns the (B, M) validity mask after ANDing all
    J match masks — one kernel launch for the whole stack."""
    J, B, N = folds.shape
    M = r.shape[-1]
    log2n = int(np.log2(N))
    assert (1 << log2n) == N, "folds must be padded to a power of two"
    row = lambda b, j: (b, 0)
    grid_spec = pl.GridSpec(
        grid=(B, J),                                 # j innermost: the out
        in_specs=[                                   # block is revisited
            pl.BlockSpec((1, M), row),
            pl.BlockSpec((1, M), row),
            pl.BlockSpec((1, 1, N), lambda b, j: (j, b, 0)),
            pl.BlockSpec((1, 1), lambda b, j: (j, b)),
        ],
        out_specs=pl.BlockSpec((1, M), row),
    )
    return pl.pallas_call(
        make_decoded_fold_kernel(log2n),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, M), jnp.bool_),
        interpret=interpret,
    )(r.astype(jnp.int32), valid, folds.astype(jnp.int32),
      fold_active.astype(jnp.int32))


# --------------------------------------------------------------------------
# packed folds: decode + intersect fused, no materialized decoded array
# --------------------------------------------------------------------------

def make_packed_fold_kernel(mode: str, block_rows: int, n_exc: int):
    per = block_rows * LANES

    def kernel(r_ref, v_ref, w_ref, wid_ref, off_ref, max_ref, blk_ref,
               ep_ref, ea_ref, act_ref, out_ref):
        j = pl.program_id(1)

        @pl.when(j == 0)
        def _seed_mask():
            out_ref[0] = v_ref[0]

        C = blk_ref.shape[-1]
        flat = _bitunpack.decode_candidates(          # (C·per,) sorted int32
            w_ref[0, 0], wid_ref[0, 0], off_ref[0, 0], max_ref[0, 0],
            blk_ref[0, 0],
            ep_ref[0, 0] if n_exc else None,
            ea_ref[0, 0] if n_exc else None,
            mode=mode, block_rows=block_rows)
        hit = _gallop_body(r_ref[0], flat, int(np.log2(C * per)))
        act = act_ref[0, 0] != 0
        out_ref[0] = out_ref[0] & jnp.where(act, hit, True)
    return kernel


@partial(jax.jit, static_argnames=("mode", "block_rows", "interpret"))
def packed_fold_batched(r, valid, words, widths, offsets, maxes, blk_ids,
                        exc_pos, exc_add, active, mode: str, block_rows: int,
                        interpret: bool = True):
    """Fused packed SvS fold.  r (B, M) sentinel-padded int32; valid (B, M)
    bool; words (Jp, B, Tp, 128) uint32; widths/offsets/maxes (Jp, B, Kp);
    blk_ids (Jp, B, C) with C·block_rows·128 a power of two; exc_pos /
    exc_add (Jp, B, E) FastPFOR patches (-1-padded); active (Jp, B).
    Returns the (B, M) validity mask after folding all Jp packed lists —
    one kernel launch, decode scratch only, no decoded array in HBM."""
    Jp, B, Tp, _ = words.shape
    M = r.shape[-1]
    C = blk_ids.shape[-1]
    E = exc_pos.shape[-1]
    per = block_rows * LANES
    assert (C * per) & (C * per - 1) == 0, "C·per must be a power of two"
    Kp = widths.shape[-1]
    row = lambda b, j: (b, 0)
    jb2 = lambda b, j: (j, b, 0)
    grid_spec = pl.GridSpec(
        grid=(B, Jp),                                # j innermost: the out
        in_specs=[                                   # block is revisited
            pl.BlockSpec((1, M), row),
            pl.BlockSpec((1, M), row),
            pl.BlockSpec((1, 1, Tp, LANES), lambda b, j: (j, b, 0, 0)),
            pl.BlockSpec((1, 1, Kp), jb2),
            pl.BlockSpec((1, 1, Kp), jb2),
            pl.BlockSpec((1, 1, Kp), jb2),
            pl.BlockSpec((1, 1, C), jb2),
            pl.BlockSpec((1, 1, max(E, 1)), jb2),
            pl.BlockSpec((1, 1, max(E, 1)), jb2),
            pl.BlockSpec((1, 1), lambda b, j: (j, b)),
        ],
        out_specs=pl.BlockSpec((1, M), row),
    )
    ep = exc_pos if E else jnp.full((Jp, B, 1), -1, jnp.int32)
    ea = exc_add if E else jnp.zeros((Jp, B, 1), jnp.uint32)
    return pl.pallas_call(
        make_packed_fold_kernel(mode, block_rows, E),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, M), jnp.bool_),
        interpret=interpret,
    )(r.astype(jnp.int32), valid, words, widths.astype(jnp.int32),
      offsets.astype(jnp.int32), maxes, blk_ids.astype(jnp.int32),
      ep, ea, active.astype(jnp.int32))
