"""Stream VByte batched device decode: pure-jnp path + Pallas TPU kernel.

Both implement the same lane-parallel reconstruction (arXiv 1709.08990 /
DESIGN.md §2.13): control words → per-lane 2-bit codes → byte widths →
prefix-summed byte offsets → gather the two uint32 data words straddling
each value's byte offset → shift/mask out the 1–4 value bytes → delta
prefix sum with the per-block scalar seed.  This mirrors the candidate
decode of ``bitunpack.decode_candidates``: every shape is static, the body
is vectorized jnp, and pad blocks decode harmlessly (code 0 → width-1
lanes reading clamped in-bounds bytes) with callers trimming to the valid
count.

The Pallas kernel follows the ``bitunpack.unpack_blocks`` idiom: one grid
step per block, per-block data byte offset + seed in scalar prefetch
(SMEM), control words blocked per step, and the full data-word stream
resident in VMEM across steps (its BlockSpec index map is constant) since
byte offsets cross block boundaries.  Validated against the host reference
decode in interpret mode across all delta modes (tests/test_codecs_roundtrip).
"""

from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import deltas as core_deltas
from repro.core import streamvbyte as svb_lib

LANES = 128


def _reconstruct(codes, offs, data, DW: int):
    """codes: (..., per) int32 2-bit byte-length codes; offs: (..., per)
    absolute byte offsets into the data stream; data: (DW,) uint32 words.
    Returns (..., per) uint32 values."""
    lens = codes + 1
    word = offs >> 2
    sh = ((offs & 3) << 3).astype(jnp.uint32)
    lo = jnp.take(data, jnp.clip(word, 0, DW - 1))
    hi = jnp.take(data, jnp.clip(word + 1, 0, DW - 1))
    val = (lo >> sh) | jnp.where(sh > 0, hi << ((jnp.uint32(32) - sh) & 31),
                                 jnp.uint32(0))
    nbits = (lens << 3).astype(jnp.uint32)
    mask = jnp.where(lens >= 4, jnp.uint32(0xFFFFFFFF),
                     (jnp.uint32(1) << jnp.minimum(nbits, 31)) - 1)
    return val & mask


def _codes_of(ctrl_flat, base: int, per: int):
    """Extract ``per`` 2-bit codes starting at value index ``base`` from a
    flat control-word vector (16 codes per word, LE byte order)."""
    i = base + jax.lax.broadcasted_iota(jnp.int32, (per, 1), 0).squeeze(-1)
    return ((jnp.take(ctrl_flat, i >> 4) >> ((i & 15) << 1)) & 3
            ).astype(jnp.int32)


@partial(jax.jit, static_argnames=("mode", "block_rows"))
def decode_svb(ctrl, data, doffs, seeds, mode: str, block_rows: int):
    """Batched jnp decode: ctrl (K, CW) uint32, data (DW,) uint32,
    doffs/seeds (K,).  Returns (K, block_rows, 128) uint32 values."""
    K = ctrl.shape[0]
    per = block_rows * LANES
    DW = data.shape[0]
    codes = _codes_of(ctrl.reshape(-1), 0, K * per).reshape(K, per)
    lens = codes + 1
    offs = doffs[:, None] + jnp.cumsum(lens, axis=1) - lens
    d = _reconstruct(codes, offs, data, DW).reshape(K, block_rows, LANES)
    return core_deltas.prefix_sum(d, seeds, mode)


def make_svb_kernel(mode: str, block_rows: int, DW: int):
    """One grid step decodes one block (decode_candidates-style body)."""
    per = block_rows * LANES

    def kernel(doffs_ref, seeds_ref, ctrl_ref, data_ref, out_ref):
        k = pl.program_id(0)
        base = doffs_ref[k]
        seed = seeds_ref[k]
        ctrl = ctrl_ref[0]                         # (CW,) this block's codes
        data = data_ref[...]                       # (DW,) full stream
        codes = _codes_of(ctrl, 0, per)
        lens = codes + 1
        offs = base + jnp.cumsum(lens) - lens
        d = _reconstruct(codes, offs, data, DW).reshape(1, block_rows, LANES)
        out = core_deltas.prefix_sum(d, seed[None], mode)
        out_ref[0] = out[0]

    return kernel


@partial(jax.jit, static_argnames=("mode", "block_rows", "interpret"))
def unpack_svb_blocks(ctrl, data, doffs, seeds, mode: str = "d1",
                      block_rows: int = svb_lib.DEFAULT_ROWS,
                      interpret: bool = True):
    """Pallas decode: same operands/result as ``decode_svb``."""
    from jax.experimental.pallas import tpu as pltpu

    K, CW = ctrl.shape
    DW = int(data.shape[0])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                     # doffs, seeds → SMEM
        grid=(K,),
        in_specs=[pl.BlockSpec((1, CW), lambda k, *_: (k, 0)),
                  pl.BlockSpec((DW,), lambda k, *_: (0,))],
        out_specs=pl.BlockSpec((1, block_rows, LANES),
                               lambda k, *_: (k, 0, 0)),
    )
    return pl.pallas_call(
        make_svb_kernel(mode, block_rows, DW),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((K, block_rows, LANES), jnp.uint32),
        interpret=interpret,
    )(doffs.astype(jnp.int32), seeds.astype(jnp.uint32),
      ctrl.astype(jnp.uint32), data.astype(jnp.uint32))


def _pow2(n: int) -> int:
    size = 1
    while size < n:
        size *= 2
    return size


def decode_bucketed(sl) -> jnp.ndarray:
    """Decode an SVBList with (K, DW) padded to powers of two — bounds jit
    specializations exactly like ``bitpack.decode_bucketed``.  Pad blocks
    carry code 0 / offset 0 and decode to garbage the caller trims; pad
    data words are zero and only reachable through clamped gathers."""
    K = sl.num_blocks
    DW = int(sl.data.shape[0])
    Kp, DWp = _pow2(K), _pow2(DW)
    ctrl = np.zeros((Kp, sl.ctrl.shape[1]), np.uint32)
    ctrl[:K] = sl.ctrl
    data = np.zeros(DWp, np.uint32)
    data[:DW] = sl.data
    doffs = np.zeros(Kp, np.int32)
    doffs[:K] = sl.doffs
    maxes = np.zeros(Kp, np.uint32)
    maxes[:K] = sl.maxes
    maxes[K:] = sl.maxes[-1] if K else 0
    seeds = np.concatenate([[0], maxes[:-1]]).astype(np.uint32)
    vals = decode_svb(jnp.asarray(ctrl), jnp.asarray(data),
                      jnp.asarray(doffs), jnp.asarray(seeds),
                      sl.mode, sl.block_rows)
    return vals.reshape(-1)
