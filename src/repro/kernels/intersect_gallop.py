"""Pallas TPU kernel: SIMD Galloping intersection (paper §5, Algorithm 4).

TPU adaptation (DESIGN.md §2.4): the paper gallops serially per element of the
short list; here one grid step takes a 128-lane tile of the short list ``r``
and runs **128 binary searches in parallel** against the long list ``f`` held
in VMEM — log2(N) rounds of branchless lower-bound probing (vector gathers),
then one gather + compare for the membership test.  Same O(m/τ · log n)
complexity as Algorithm 4 at τ = 128 with the doubling phase replaced by
full binary search (depth-optimal on vectors; sequential doubling has no TPU
advantage).

VMEM budget: f must fit in VMEM (N ≤ 2**20 → 4 MiB).  Longer lists go through
``ops.intersect_gallop`` which first searches the block-max skip index (this
kernel again) and then probes candidate blocks.
"""

from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_R = 128
SENTINEL = np.int32(2**31 - 1)


def _gallop_body(r, f, log2n: int):
    """Branchless lower_bound of each lane of r into f + membership test."""
    lo = jnp.full(r.shape, -1, dtype=jnp.int32)
    for k in range(log2n - 1, -1, -1):               # branchless lower_bound
        probe = lo + (1 << k)
        vals = jnp.take(f, probe)                    # vector gather from VMEM
        lo = jnp.where(vals < r, probe, lo)
    pos = jnp.minimum(lo + 1, (1 << log2n) - 1)
    return (jnp.take(f, pos) == r) & (r != SENTINEL)


def make_gallop_kernel(log2n: int):
    def kernel(r_ref, f_ref, out_ref):
        r = r_ref[...]                               # (TILE_R,) int32
        f = f_ref[...]                               # (N,) int32, N = 2**log2n
        out_ref[...] = _gallop_body(r, f, log2n)
    return kernel


def make_gallop_kernel_batched(log2n: int):
    def kernel(r_ref, f_ref, out_ref):
        r = r_ref[0, :]                              # (TILE_R,) int32
        f = f_ref[0, :]                              # (N,) this query's long list
        out_ref[0, :] = _gallop_body(r, f, log2n)
    return kernel


@partial(jax.jit, static_argnames=("interpret",))
def gallop_tiles(r, f, interpret: bool = True):
    """r: (M,) int32 sentinel-padded, M % 128 == 0; f: (N,) int32 sentinel-
    padded, N a power of two. Returns (M,) bool match mask."""
    M, N = r.shape[0], f.shape[0]
    assert M % TILE_R == 0
    log2n = int(np.log2(N))
    assert (1 << log2n) == N, "f must be padded to a power of two"
    grid_spec = pl.GridSpec(
        grid=(M // TILE_R,),
        in_specs=[
            pl.BlockSpec((TILE_R,), lambda i: (i,)),
            pl.BlockSpec((N,), lambda i: (0,)),      # f resident in VMEM
        ],
        out_specs=pl.BlockSpec((TILE_R,), lambda i: (i,)),
    )
    return pl.pallas_call(
        make_gallop_kernel(log2n),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M,), jnp.bool_),
        interpret=interpret,
    )(r.astype(jnp.int32), f.astype(jnp.int32))


@partial(jax.jit, static_argnames=("interpret",))
def gallop_tiles_batched(r, f, interpret: bool = True):
    """Batched galloping: r (B, M) sentinel-padded with M % 128 == 0; f (B, N)
    sentinel-padded, N a power of two.  Grid is (batch row, r-tile); each step
    holds one query's long list in VMEM and binary-searches a 128-lane tile of
    its candidates.  Returns (B, M) bool match mask."""
    B, M = r.shape
    Bf, N = f.shape
    assert B == Bf and M % TILE_R == 0
    log2n = int(np.log2(N))
    assert (1 << log2n) == N, "f must be padded to a power of two"
    grid_spec = pl.GridSpec(
        grid=(B, M // TILE_R),
        in_specs=[
            pl.BlockSpec((1, TILE_R), lambda b, i: (b, i)),
            pl.BlockSpec((1, N), lambda b, i: (b, 0)),   # row-resident f
        ],
        out_specs=pl.BlockSpec((1, TILE_R), lambda b, i: (b, i)),
    )
    return pl.pallas_call(
        make_gallop_kernel_batched(log2n),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, M), jnp.bool_),
        interpret=interpret,
    )(r.astype(jnp.int32), f.astype(jnp.int32))
