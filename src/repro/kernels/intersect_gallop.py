"""Pallas TPU kernel: SIMD Galloping intersection (paper §5, Algorithm 4).

TPU adaptation (DESIGN.md §2.4): the paper gallops serially per element of the
short list; here one grid step takes a 128-lane tile of the short list ``r``
and runs **128 binary searches in parallel** against the long list ``f`` held
in VMEM — log2(N) rounds of branchless lower-bound probing (vector gathers),
then one gather + compare for the membership test.  Same O(m/τ · log n)
complexity as Algorithm 4 at τ = 128 with the doubling phase replaced by
full binary search (depth-optimal on vectors; sequential doubling has no TPU
advantage).

VMEM budget: f must fit in VMEM (N ≤ 2**20 → 4 MiB).  Longer lists go through
``ops.intersect_gallop`` which first searches the block-max skip index (this
kernel again) and then probes candidate blocks.
"""

from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import bitpack as core_bitpack
from repro.kernels import bitunpack as _bitunpack

TILE_R = 128
SENTINEL = np.int32(2**31 - 1)


def _gallop_body(r, f, log2n: int):
    """Branchless lower_bound of each lane of r into f + membership test."""
    lo = jnp.full(r.shape, -1, dtype=jnp.int32)
    for k in range(log2n - 1, -1, -1):               # branchless lower_bound
        probe = lo + (1 << k)
        vals = jnp.take(f, probe)                    # vector gather from VMEM
        lo = jnp.where(vals < r, probe, lo)
    pos = jnp.minimum(lo + 1, (1 << log2n) - 1)
    return (jnp.take(f, pos) == r) & (r != SENTINEL)


def make_gallop_kernel(log2n: int):
    def kernel(r_ref, f_ref, out_ref):
        r = r_ref[...]                               # (TILE_R,) int32
        f = f_ref[...]                               # (N,) int32, N = 2**log2n
        out_ref[...] = _gallop_body(r, f, log2n)
    return kernel


def make_gallop_kernel_batched(log2n: int):
    def kernel(r_ref, f_ref, out_ref):
        r = r_ref[0, :]                              # (TILE_R,) int32
        f = f_ref[0, :]                              # (N,) this query's long list
        out_ref[0, :] = _gallop_body(r, f, log2n)
    return kernel


@partial(jax.jit, static_argnames=("interpret",))
def gallop_tiles(r, f, interpret: bool = True):
    """r: (M,) int32 sentinel-padded, M % 128 == 0; f: (N,) int32 sentinel-
    padded, N a power of two. Returns (M,) bool match mask."""
    M, N = r.shape[0], f.shape[0]
    assert M % TILE_R == 0
    log2n = int(np.log2(N))
    assert (1 << log2n) == N, "f must be padded to a power of two"
    grid_spec = pl.GridSpec(
        grid=(M // TILE_R,),
        in_specs=[
            pl.BlockSpec((TILE_R,), lambda i: (i,)),
            pl.BlockSpec((N,), lambda i: (0,)),      # f resident in VMEM
        ],
        out_specs=pl.BlockSpec((TILE_R,), lambda i: (i,)),
    )
    return pl.pallas_call(
        make_gallop_kernel(log2n),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M,), jnp.bool_),
        interpret=interpret,
    )(r.astype(jnp.int32), f.astype(jnp.int32))


@partial(jax.jit, static_argnames=("interpret",))
def gallop_tiles_batched(r, f, interpret: bool = True):
    """Batched galloping: r (B, M) sentinel-padded with M % 128 == 0; f (B, N)
    sentinel-padded, N a power of two.  Grid is (batch row, r-tile); each step
    holds one query's long list in VMEM and binary-searches a 128-lane tile of
    its candidates.  Returns (B, M) bool match mask."""
    B, M = r.shape
    Bf, N = f.shape
    assert B == Bf and M % TILE_R == 0
    log2n = int(np.log2(N))
    assert (1 << log2n) == N, "f must be padded to a power of two"
    grid_spec = pl.GridSpec(
        grid=(B, M // TILE_R),
        in_specs=[
            pl.BlockSpec((1, TILE_R), lambda b, i: (b, i)),
            pl.BlockSpec((1, N), lambda b, i: (b, 0)),   # row-resident f
        ],
        out_specs=pl.BlockSpec((1, TILE_R), lambda b, i: (b, i)),
    )
    return pl.pallas_call(
        make_gallop_kernel_batched(log2n),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, M), jnp.bool_),
        interpret=interpret,
    )(r.astype(jnp.int32), f.astype(jnp.int32))


# --------------------------------------------------------------------------
# packed gallop: skip-aware partial decode fused with the search
# --------------------------------------------------------------------------
#
# The batched engine never materializes a long compressed list: per batch row
# the kernel holds the *compressed* words plus per-block metadata in VMEM,
# gather-decodes only the (host-precomputed, deduplicated) candidate blocks,
# and binary-searches the whole candidate tile against the partially decoded
# buffer — decode volume is C·block ints, not the list length (paper §6.5).
# One grid step per batch row: the decode is done once and all M candidate
# lanes search it in the same step.

def make_packed_gallop_kernel(mode: str, block_rows: int, n_exc: int):
    per = block_rows * core_bitpack.LANES

    def kernel(r_ref, w_ref, wid_ref, off_ref, max_ref, blk_ref,
               ep_ref, ea_ref, out_ref):
        r = r_ref[0]                                  # (M,) int32
        C = blk_ref.shape[-1]
        flat = _bitunpack.decode_candidates(          # (C·per,) sorted int32
            w_ref[0], wid_ref[0], off_ref[0], max_ref[0], blk_ref[0],
            ep_ref[0] if n_exc else None, ea_ref[0] if n_exc else None,
            mode=mode, block_rows=block_rows)
        log2f = int(np.log2(C * per))
        out_ref[0] = _gallop_body(r, flat, log2f)
    return kernel


@partial(jax.jit, static_argnames=("mode", "block_rows", "interpret"))
def packed_gallop_batched(r, words, widths, offsets, maxes, blk_ids,
                          exc_pos, exc_add, mode: str, block_rows: int,
                          interpret: bool = True):
    """Batched skip-aware packed gallop.  r (B, M) sentinel-padded int32;
    words (B, Tp, 128) uint32; widths/offsets/maxes (B, Kp); blk_ids (B, C)
    with C·block_rows·128 a power of two; exc_pos/exc_add (B, E) FastPFOR
    patches (-1-padded).  Returns (B, M) bool match mask."""
    B, M = r.shape
    _, C = blk_ids.shape
    E = exc_pos.shape[-1]
    per = block_rows * core_bitpack.LANES
    assert (C * per) & (C * per - 1) == 0, "C·per must be a power of two"
    Tp, Kp = words.shape[1], widths.shape[1]
    row = lambda b: (b, 0)
    row3 = lambda b: (b, 0, 0)
    grid_spec = pl.GridSpec(
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, M), row),
            pl.BlockSpec((1, Tp, core_bitpack.LANES), row3),
            pl.BlockSpec((1, Kp), row),
            pl.BlockSpec((1, Kp), row),
            pl.BlockSpec((1, Kp), row),
            pl.BlockSpec((1, C), row),
            pl.BlockSpec((1, max(E, 1)), row),
            pl.BlockSpec((1, max(E, 1)), row),
        ],
        out_specs=pl.BlockSpec((1, M), row),
    )
    ep = exc_pos if E else jnp.full((B, 1), -1, jnp.int32)
    ea = exc_add if E else jnp.zeros((B, 1), jnp.uint32)
    return pl.pallas_call(
        make_packed_gallop_kernel(mode, block_rows, E),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, M), jnp.bool_),
        interpret=interpret,
    )(r.astype(jnp.int32), words, widths.astype(jnp.int32),
      offsets.astype(jnp.int32), maxes, blk_ids.astype(jnp.int32), ep, ea)
