"""Pallas TPU kernel: integrated bit-unpack + prefix sum (paper Algorithm 1).

One grid step decodes one block: a (32, 128) packed-word VMEM tile (only the
first ``b`` rows carry data) → a (32, 128) value tile.  The prefix sum is
computed *in the same pass* as the unpacking, row by row, exactly as the
paper's integrated variant: per output row ``t ← (y[w] ≫ sh) | (y[w+1] ≪
(32−sh)) & M;  t ← P(t, v); v ← t`` — where P is selected by the delta mode.
The two-pass ("-NI") comparison point materializes deltas first (see ops.py).

Working set per grid step: 32·128·4 B in + 32·128·4 B out = 32 KiB ≪ VMEM.
Bit widths and seeds ride in scalar-prefetch (SMEM), mirroring the paper's
per-block metadata bytes.

Validated against ``ref.unpack_blocks_ref`` (pure jnp) in interpret mode for
every bit width b ∈ [0, 32] × every delta mode (tests/test_kernels.py).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core import bitpack as core_bitpack
from repro.core import deltas as core_deltas

ROWS = 32
LANES = 128


def _lane_cumsum(row):
    """Inclusive cumsum over 128 lanes via log2(128)=7 shift-adds
    (Hillis–Steele; TPU-friendly: pad+add, no scatter)."""
    x = row
    for k in (1, 2, 4, 8, 16, 32, 64):
        x = x + jnp.pad(x, (k, 0))[:LANES]
    return x


def _stride_cumsum(row, s: int, carry):
    """Per-row stride-s chain cumsum. carry: (s,) running value per phase.
    Returns (new_row, new_carry)."""
    C = LANES // s
    m = row.reshape(C, s)
    pc = jnp.cumsum(m, axis=0, dtype=jnp.uint32)
    out = pc + carry[None, :]
    return out.reshape(LANES), out.reshape(LANES // s, s)[-1]


def make_unpack_kernel(mode: str):
    """Build the Algorithm-1 kernel body for one delta mode P."""

    def kernel(widths_ref, seeds_ref, words_ref, out_ref):
        k = pl.program_id(0)
        b = widths_ref[k].astype(jnp.uint32)
        seed = seeds_ref[k]
        words = words_ref[0]                       # (32, 128) uint32
        mask = jnp.where(b >= 32, jnp.uint32(0xFFFFFFFF),
                         (jnp.uint32(1) << jnp.minimum(b, 31)) - 1)

        # prefix-sum state v (paper: "seed vector v")
        if mode == "dv":
            carry = jnp.full((LANES,), seed, dtype=jnp.uint32)
        elif mode in ("d2", "d4"):
            s = {"d2": 2, "d4": 4}[mode]
            carry = jnp.full((s,), seed, dtype=jnp.uint32)
        else:                                      # d1 / dm / none: scalar
            carry = seed

        out = jnp.zeros((ROWS, LANES), dtype=jnp.uint32)
        for r in range(ROWS):                      # static unroll, as in the
            start = jnp.uint32(r) * b              # paper's generated code
            w = (start >> 5).astype(jnp.int32)
            sh = start & 31
            lo = lax.dynamic_index_in_dim(words, w, axis=0, keepdims=False)
            hi = lax.dynamic_index_in_dim(
                words, jnp.minimum(w + 1, ROWS - 1), axis=0, keepdims=False)
            spill = (sh + b) > 32
            t = lo >> sh
            t = jnp.where(spill, t | (hi << ((jnp.uint32(32) - sh) & 31)), t)
            t = t & mask                           # single reusable mask (§4)

            # t ← P(t, v);  v ← t
            if mode == "none":
                row = t
            elif mode == "dv":
                row = t + carry
                carry = row
            elif mode == "dm":
                row = t + carry
                carry = row[LANES - 1]
            elif mode == "d1":
                row = _lane_cumsum(t) + carry
                carry = row[LANES - 1]
            else:                                  # d2 / d4
                row, carry = _stride_cumsum(t, carry.shape[0], carry)
            out = lax.dynamic_update_index_in_dim(out, row, r, axis=0)
        out_ref[0] = out

    return kernel


def decode_candidates(words, widths, offsets, maxes, blk, exc_pos, exc_add,
                      *, mode: str, block_rows: int):
    """In-kernel partial decode of one row's candidate blocks → a flat
    sorted int32 window, SENTINEL-filled on pad slots.

    This is the scratch-decode stage shared by the per-fold packed-gallop
    kernel (``intersect_gallop.make_packed_gallop_kernel``) and the fused
    megakernel (``megakernel.make_packed_fold_kernel``): gather each
    candidate block's width/offset, bit-unpack its deltas with the same
    shift/mask machinery as the Algorithm-1 kernel above (vectorized via
    ``core.bitpack.unpack_deltas``), patch FastPFOR exceptions whose block
    made the candidate list, and prefix-sum with the per-block seed.  All
    operands are this row's VMEM-resident refs read inside a Pallas kernel
    body; every shape is static so the whole stage traces into the kernel.

    ``blk`` entries ≥ K_pad are pad candidates: they decode block K_pad−1
    (harmlessly) and their ``per`` output lanes are overwritten with
    SENTINEL, so the window stays sorted and the gallop probe can never
    match a pad slot (DESIGN.md §2.6, §2.12)."""
    from repro.core.intersect import SENTINEL
    per = block_rows * LANES
    Kp = maxes.shape[0]
    C = blk.shape[0]
    pad = blk >= Kp
    ids = jnp.minimum(blk, Kp - 1)
    seeds = jnp.where(ids > 0,
                      jnp.take(maxes, jnp.maximum(ids - 1, 0)),
                      jnp.uint32(0))
    d = core_bitpack.unpack_deltas(words, jnp.take(widths, ids),
                                   jnp.take(offsets, ids), block_rows)
    if exc_pos is not None:
        eb = exc_pos // per
        slot = jnp.clip(jnp.searchsorted(blk, eb), 0, C - 1)
        ok = (exc_pos >= 0) & (jnp.take(blk, slot) == eb)
        tgt = jnp.where(ok, slot * per + exc_pos % per, C * per)
        d = d.reshape(-1).at[tgt].add(exc_add, mode="drop").reshape(d.shape)
    vals = core_deltas.prefix_sum(d, seeds, mode)
    flat = vals.reshape(-1).astype(jnp.int32)         # (C·per,) sorted
    return jnp.where(jnp.repeat(pad, per), SENTINEL, flat)


@partial(jax.jit, static_argnames=("mode", "interpret"))
def unpack_blocks(padded_words, widths, seeds, mode: str = "d1",
                  interpret: bool = True):
    """padded_words: (K, 32, 128) uint32 (block-padded packed words);
    widths, seeds: (K,).  Returns (K, 32, 128) uint32 decoded values."""
    from jax.experimental.pallas import tpu as pltpu

    K = padded_words.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # widths, seeds → SMEM
        grid=(K,),
        in_specs=[pl.BlockSpec((1, ROWS, LANES), lambda k, *_: (k, 0, 0))],
        out_specs=pl.BlockSpec((1, ROWS, LANES), lambda k, *_: (k, 0, 0)),
    )
    kernel = make_unpack_kernel(mode)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((K, ROWS, LANES), jnp.uint32),
        interpret=interpret,
    )(widths.astype(jnp.int32), seeds.astype(jnp.uint32),
      padded_words.astype(jnp.uint32))
