"""Pallas TPU kernel: flash attention forward (GQA-aware, causal/decode
masks) — the §Roofline "next lever" for every attention-bearing cell: the
(B, H, Sq, Sk) score tensor never leaves VMEM, removing the largest
materialized-buffer class from the memory roofline term (EXPERIMENTS
§Roofline last column; phi3/gemma train cells).

Canonical Pallas flash structure: grid (B, H, nq, nk) with the online-softmax
state (m, l, acc) in VMEM scratch carried across the (sequential) nk
dimension; KV tiles stream through VMEM BlockSpecs; GQA maps query head h to
KV head h // (H / H_kv) in the index maps.

Working set per grid step: q (bq, D) + k/v (bk, D) + acc (bq, D) + scores
(bq, bk), all f32: bq=bk=512, D=256 → ~3.3 MiB ≪ 16 MiB VMEM.

Forward-only (serving path; training uses XLA attention + remat until a bwd
kernel lands).  Validated against layers.attention_full in interpret mode
across GQA ratios, causal/full, ragged lengths (tests/test_flash_attention.py).
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 512
DEFAULT_BK = 512
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, scale: float, causal: bool, bq: int, bk: int,
                  n_k: int, kv_len: int | None):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)             # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)             # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), dtype=jnp.bool_)
    if causal:
        mask = mask & (q_pos >= k_pos)
    if kv_len is not None:                           # decode/ragged masking
        mask = mask & (k_pos < kv_len)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                              # (bq,)
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "kv_len", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, kv_len: int | None = None,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = True):
    """q: (B, Sq, H, D); k/v: (B, Sk, Hkv, D) with H % Hkv == 0.

    Returns (B, Sq, H, D).  kv_len masks positions ≥ kv_len (decode)."""
    B, Sq, H, D = q.shape
    _, Sk, Hkv, _ = k.shape
    assert H % Hkv == 0
    n_rep = H // Hkv
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, "pad sequences to block multiples"
    n_q, n_k = Sq // bq, Sk // bk
    scale = 1.0 / np.sqrt(D)

    # layout: (B, H, S, D) per-head tiles
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, bq=bq, bk=bk, n_k=n_k,
        kv_len=kv_len)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, n_rep=n_rep: (b, h // n_rep, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, n_rep=n_rep: (b, h // n_rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # m: running max
            pltpu.VMEM((bq,), jnp.float32),      # l: running denominator
            pltpu.VMEM((bq, D), jnp.float32),    # acc: running numerator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
