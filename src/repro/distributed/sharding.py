"""Sharding rules: parameter PartitionSpecs per model family + activation
constraint hints.

Conventions (DESIGN.md §4): mesh axes ('pod', 'data', 'model') multi-pod or
('data', 'model') single-pod.  Batch shards over BATCH_AXES = ('pod','data')
(whichever exist); tensor parallelism over 'model'; the `fsdp` preset
additionally shards large weight dims over 'data' (ZeRO-3-like, needed for
kimi-k2's ~1T params).
"""

from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# mesh-aware hint plumbing
# ---------------------------------------------------------------------------

_HINT_RULES: dict[str, P] = {}
_HINT_MESH: list = [None]


def set_hint_rules(rules: dict[str, P], mesh: "Mesh | None" = None) -> None:
    """Register activation-sharding hints + the mesh they bind to.  With no
    mesh (tests, single-device runs) hints are identity."""
    _HINT_RULES.clear()
    _HINT_RULES.update(rules)
    _HINT_MESH[0] = mesh


def shard_hint(x, name: str):
    """with_sharding_constraint if a rule is registered and a mesh was bound;
    otherwise identity (keeps model code mesh-agnostic)."""
    spec = _HINT_RULES.get(name)
    mesh = _HINT_MESH[0]
    if spec is None or mesh is None:
        return x
    if x.ndim < len(spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def current_mesh():
    """Mesh bound by set_hint_rules (None outside launcher contexts)."""
    return _HINT_MESH[0]


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

def _divisible(dim: int, mesh: Mesh, axis) -> bool:
    size = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        size *= mesh.shape[a]
    return dim % size == 0 and dim >= size


def lm_param_spec(path: str, shape: tuple[int, ...], mesh: Mesh,
                  preset: str = "tp") -> P:
    """Name-based Megatron-style rules for stacked-layer LM params.

    path: '/'-joined pytree key path, e.g. 'layers/attn/wq'.
    """
    dp = batch_axes(mesh)
    specs: list[Any] = [None] * len(shape)

    def put(idx: int, axis) -> bool:
        if specs[idx] is None and _divisible(shape[idx], mesh, axis):
            specs[idx] = axis
            return True
        return False

    name = path.split("/")[-1]
    if name in ("embed", "lm_head"):
        # (V, D): vocab over model (col-parallel logits)
        put(0, "model")
        if preset == "fsdp":
            put(1, dp if len(dp) == 1 else "data")
    elif name in ("wq", "wk", "wv", "w_in", "w_gate"):
        put(len(shape) - 1, "model")       # output-feature parallel
        if preset == "fsdp":
            put(len(shape) - 2, "data")
    elif name in ("wo", "w_out"):
        put(len(shape) - 2, "model")       # input-feature parallel
        if preset == "fsdp":
            put(len(shape) - 1, "data")
    elif name == "router":
        pass                                # small, replicated
    # norms / scalars: replicated
    # MoE stacked experts (L, E, D, F): expert dim gets 'model' instead
    if "moe" in path and len(shape) == 4:
        specs = [None] * len(shape)
        put(1, "model")                     # experts → EP
        if preset == "fsdp":
            put(2, "data")
    return P(*specs)


def recsys_param_spec(path: str, shape: tuple[int, ...], mesh: Mesh) -> P:
    name = path.split("/")[-1]
    if "table" in name or name == "embed":
        # (V, d): column-shard d over 'model' if divisible, else rows
        if _divisible(shape[-1], mesh, "model"):
            return P(None, "model")
        if _divisible(shape[0], mesh, "model"):
            return P("model", None)
    return P(*([None] * len(shape)))


def gnn_param_spec(path: str, shape: tuple[int, ...], mesh: Mesh) -> P:
    if len(shape) == 2 and _divisible(shape[-1], mesh, "model"):
        return P(None, "model")
    return P(*([None] * len(shape)))


def tree_param_shardings(params, mesh: Mesh, rule) -> Any:
    """Map a rule(path, shape, mesh) → NamedSharding over a params pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for keypath, leaf in flat:
        path = "/".join(_key_name(k) for k in keypath)
        spec = rule(path, leaf.shape, mesh)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def _key_name(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def data_sharding(mesh: Mesh, *spec_tail) -> NamedSharding:
    """Batch-dim sharding over ('pod','data')."""
    dp = batch_axes(mesh)
    return NamedSharding(mesh, P(dp, *spec_tail))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
