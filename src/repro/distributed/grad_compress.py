"""Sparse gradient compression for the data-parallel axis — the paper's codec
as a distributed-training feature (DESIGN.md §3.2).

Deep-Gradient-Compression-style: per step, each worker sends only the top-k
gradient coordinates; the *sorted index list* is delta+bit-packed with the
paper's S4-BP128-style codec (indices are exactly the paper's sorted-integer
workload), values ship bf16.  An error-feedback accumulator keeps the
residual so convergence is preserved (tested in tests/test_grad_compress.py).

Two layers:
 - jit path (``sparsify`` / ``apply_sparse``): fixed-k top-k + error feedback,
   runs inside the train step on any backend.
 - wire path (``encode_wire`` / ``decode_wire``): host-side packaging of the
   (indices, values) pair with the bitpack codec; measured compression ratio
   is reported by benchmarks/bench_gradcompress.py.
"""

from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bitpack


@partial(jax.jit, static_argnames=("k",))
def sparsify(grad_flat, residual, k: int):
    """Top-k magnitude selection with error feedback.

    Returns (indices (k,) int32 sorted, values (k,) f32, new_residual)."""
    acc = grad_flat + residual
    _, idx = jax.lax.top_k(jnp.abs(acc), k)
    idx = jnp.sort(idx)
    vals = jnp.take(acc, idx)
    new_res = acc.at[idx].set(0.0)
    return idx.astype(jnp.int32), vals, new_res


@jax.jit
def apply_sparse(shape_like, idx, vals):
    """Densify a sparse update onto zeros_like(shape_like)."""
    return jnp.zeros_like(shape_like).at[idx].set(vals)


def encode_wire(idx: np.ndarray, vals: np.ndarray):
    """Host-side wire format: bit-packed sorted indices + bf16 values."""
    packed = bitpack.encode(np.asarray(idx), mode="d1")
    vals16 = np.asarray(vals, dtype=jnp.bfloat16)
    return packed, vals16


def decode_wire(packed: bitpack.PackedList, vals16: np.ndarray):
    idx = bitpack.decode_np(packed)
    return idx.astype(np.int32), np.asarray(vals16, dtype=np.float32)


def wire_bits_per_coord(packed: bitpack.PackedList) -> float:
    """bits per transmitted coordinate: packed index + 16-bit value."""
    return bitpack.bits_per_int(packed) + 16.0


def compress_ratio(n_params: int, k: int,
                   packed: bitpack.PackedList) -> float:
    """Dense f32 all-reduce bytes vs sparse wire bytes."""
    dense_bits = n_params * 32
    sparse_bits = wire_bits_per_coord(packed) * k
    return dense_bits / max(sparse_bits, 1)
