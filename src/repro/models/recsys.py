"""RecSys architectures: DIN, SASRec, BERT4Rec, MIND.

Common substrate: huge sparse embedding tables + EmbeddingBag implemented
with ``jnp.take`` + masked segment reductions (JAX has no native
EmbeddingBag — this is part of the system, per the assignment brief).  Tables
are column-sharded over 'model' under pjit (indices replicated, gathers stay
local — DESIGN.md §4).

Entry points per model: ``init_params``, ``train_loss`` (train_batch shape),
``score`` (serve_p99 / serve_bulk: pointwise CTR/next-item scores), and
``retrieval_scores`` (retrieval_cand: one user vs n_candidates, dot-product
scoring + top-k; candidate *generation* by posting-list intersection lives in
repro/index and examples/recsys_retrieval.py).
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    arch: str                       # 'din' | 'sasrec' | 'bert4rec' | 'mind'
    n_items: int = 1 << 20
    n_cates: int = 1 << 12
    embed_dim: int = 64
    seq_len: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    attn_mlp: tuple[int, ...] = (80, 40)     # DIN attention MLP
    mlp: tuple[int, ...] = (200, 80)         # DIN prediction MLP
    n_interests: int = 4                     # MIND
    capsule_iters: int = 3                   # MIND
    n_neg: int = 127                         # sampled-softmax negatives
    compute_dtype: str = "float32"


# ---------------------------------------------------------------------------
# embedding substrate
# ---------------------------------------------------------------------------

def embedding_bag(table, ids, mask, mode: str = "mean"):
    """EmbeddingBag: (B, L) ids + (B, L) mask → (B, d). take + segment-style
    masked reduce (no native op in JAX)."""
    e = jnp.take(table, ids, axis=0)                 # (B, L, d)
    m = mask[..., None].astype(e.dtype)
    if mode == "sum":
        return (e * m).sum(axis=1)
    if mode == "max":
        return jnp.where(m > 0, e, -jnp.inf).max(axis=1)
    return (e * m).sum(axis=1) / jnp.maximum(m.sum(axis=1), 1.0)


def _mlp(params, x, act=jax.nn.relu, final_act=False):
    n = len(params)
    for i, lp in enumerate(params):
        x = x @ lp["w"] + lp["b"]
        if i < n - 1 or final_act:
            x = act(x)
    return x


def _init_mlp(rng, dims):
    keys = jax.random.split(rng, len(dims) - 1)
    return [{"w": jax.random.normal(k, (dims[i], dims[i + 1]))
             / np.sqrt(dims[i]),
             "b": jnp.zeros((dims[i + 1],))}
            for i, k in enumerate(keys)]


def _init_table(rng, n, d):
    """Rows padded to a 4096 multiple so huge tables row-shard cleanly under
    any mesh axis size (padded ids are never emitted by the pipeline)."""
    n_pad = int(np.ceil(n / 4096) * 4096)
    return jax.random.normal(rng, (n_pad, d)) * (1.0 / np.sqrt(d))


def _init_pos(rng, n, d):
    """Positional embeddings: exact length, never padded."""
    return jax.random.normal(rng, (n, d)) * (1.0 / np.sqrt(d))


# ---------------------------------------------------------------------------
# DIN — target attention CTR (arXiv:1706.06978)
# ---------------------------------------------------------------------------

def init_din(rng, cfg: RecsysConfig):
    k = jax.random.split(rng, 5)
    d = cfg.embed_dim
    de = 2 * d                                    # item ⊕ cate
    return {
        "item_table": _init_table(k[0], cfg.n_items, d),
        "cate_table": _init_table(k[1], cfg.n_cates, d),
        "att_mlp": _init_mlp(k[2], (4 * de,) + cfg.attn_mlp + (1,)),
        "pred_mlp": _init_mlp(k[3], (3 * de,) + cfg.mlp + (1,)),
    }


def _din_user_vec(params, hist_items, hist_cates, hist_mask, e_t):
    eh = jnp.concatenate([jnp.take(params["item_table"], hist_items, axis=0),
                          jnp.take(params["cate_table"], hist_cates, axis=0)],
                         axis=-1)                                   # (B,L,2d)
    et = e_t[:, None, :]
    z = jnp.concatenate([eh, et * jnp.ones_like(eh), eh - et, eh * et], -1)
    w = _mlp(params["att_mlp"], z, act=jax.nn.sigmoid)[..., 0]      # (B,L)
    w = w * hist_mask                              # DIN: no softmax (paper §4)
    return jnp.einsum("bl,bld->bd", w, eh)


def din_score(params, batch, cfg: RecsysConfig):
    e_t = jnp.concatenate(
        [jnp.take(params["item_table"], batch["target_item"], axis=0),
         jnp.take(params["cate_table"], batch["target_cate"], axis=0)], -1)
    user = _din_user_vec(params, batch["hist_items"], batch["hist_cates"],
                         batch["hist_mask"], e_t)
    z = jnp.concatenate([user, e_t, user * e_t], -1)
    return _mlp(params["pred_mlp"], z)[..., 0]     # logits (B,)


def din_loss(params, batch, cfg: RecsysConfig):
    logits = din_score(params, batch, cfg)
    labels = batch["labels"].astype(jnp.float32)
    loss = jnp.mean(
        jnp.maximum(logits, 0) - logits * labels
        + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    return loss, {"logit_mean": logits.mean()}


def din_retrieval(params, batch, cfg: RecsysConfig):
    """1 user vs n_candidates: target attention per candidate."""
    cand_items = batch["cand_items"]               # (C,)
    cand_cates = batch["cand_cates"]
    e_t = jnp.concatenate(
        [jnp.take(params["item_table"], cand_items, axis=0),
         jnp.take(params["cate_table"], cand_cates, axis=0)], -1)   # (C,2d)
    user = _din_user_vec(
        params,
        jnp.broadcast_to(batch["hist_items"], (e_t.shape[0],
                                               cfg.seq_len)),
        jnp.broadcast_to(batch["hist_cates"], (e_t.shape[0], cfg.seq_len)),
        jnp.broadcast_to(batch["hist_mask"], (e_t.shape[0], cfg.seq_len)),
        e_t)
    z = jnp.concatenate([user, e_t, user * e_t], -1)
    return _mlp(params["pred_mlp"], z)[..., 0]     # (C,)


# ---------------------------------------------------------------------------
# SASRec — causal self-attention next-item (arXiv:1808.09781)
# ---------------------------------------------------------------------------

def _init_blocks(rng, n_blocks, d, n_heads, d_ff):
    keys = jax.random.split(rng, n_blocks)
    blocks = []
    s = 1.0 / np.sqrt(d)
    for k in keys:
        k1, k2, k3, k4, k5, k6 = jax.random.split(k, 6)
        blocks.append({
            "wq": jax.random.normal(k1, (d, d)) * s,
            "wk": jax.random.normal(k2, (d, d)) * s,
            "wv": jax.random.normal(k3, (d, d)) * s,
            "wo": jax.random.normal(k4, (d, d)) * s,
            "ln1": jnp.zeros((d,)), "ln2": jnp.zeros((d,)),
            "ffn_in": jax.random.normal(k5, (d, d_ff)) * s,
            "ffn_out": jax.random.normal(k6, (d_ff, d)) / np.sqrt(d_ff),
        })
    return blocks


def _attn_blocks(blocks, x, n_heads, causal):
    B, S, d = x.shape
    hd = d // n_heads
    for bp in blocks:
        h = L.rms_norm(x, bp["ln1"])
        q = (h @ bp["wq"]).reshape(B, S, n_heads, hd)
        k = (h @ bp["wk"]).reshape(B, S, n_heads, hd)
        v = (h @ bp["wv"]).reshape(B, S, n_heads, hd)
        a = L.attention_full(q, k, v, causal=causal)
        x = x + a.reshape(B, S, d) @ bp["wo"]
        h = L.rms_norm(x, bp["ln2"])
        x = x + jax.nn.relu(h @ bp["ffn_in"]) @ bp["ffn_out"]
    return x


def init_sasrec(rng, cfg: RecsysConfig):
    k = jax.random.split(rng, 3)
    d = cfg.embed_dim
    return {
        "item_table": _init_table(k[0], cfg.n_items + 1, d),  # +1 pad id
        "pos_embed": _init_pos(k[1], cfg.seq_len, d),
        "blocks": _init_blocks(k[2], cfg.n_blocks, d, cfg.n_heads, d),
    }


def sasrec_hidden(params, hist, mask, cfg: RecsysConfig):
    x = jnp.take(params["item_table"], hist, axis=0)
    x = x + params["pos_embed"][None]
    x = x * mask[..., None]
    return _attn_blocks(params["blocks"], x, cfg.n_heads, causal=True)


def sasrec_loss(params, batch, cfg: RecsysConfig):
    """Per-position next-item with one sampled negative (paper's objective)."""
    h = sasrec_hidden(params, batch["hist"], batch["hist_mask"], cfg)
    e_pos = jnp.take(params["item_table"], batch["pos"], axis=0)
    e_neg = jnp.take(params["item_table"], batch["neg"], axis=0)
    s_pos = jnp.sum(h * e_pos, -1)
    s_neg = jnp.sum(h * e_neg, -1)
    m = batch["hist_mask"]
    loss = -(jnp.log(jax.nn.sigmoid(s_pos) + 1e-9)
             + jnp.log(1 - jax.nn.sigmoid(s_neg) + 1e-9)) * m
    return loss.sum() / jnp.maximum(m.sum(), 1.0), {}


def sasrec_score(params, batch, cfg: RecsysConfig):
    h = sasrec_hidden(params, batch["hist"], batch["hist_mask"], cfg)
    e_t = jnp.take(params["item_table"], batch["target_item"], axis=0)
    return jnp.sum(h[:, -1] * e_t, -1)


def sasrec_retrieval(params, batch, cfg: RecsysConfig):
    h = sasrec_hidden(params, batch["hist"][None], batch["hist_mask"][None],
                      cfg)[0, -1]                      # (d,)
    e_c = jnp.take(params["item_table"], batch["cand_items"], axis=0)
    return e_c @ h                                     # (C,)


# ---------------------------------------------------------------------------
# BERT4Rec — bidirectional masked item prediction (arXiv:1904.06690)
# ---------------------------------------------------------------------------

def init_bert4rec(rng, cfg: RecsysConfig):
    k = jax.random.split(rng, 3)
    d = cfg.embed_dim
    return {
        "item_table": _init_table(k[0], cfg.n_items + 2, d),  # +pad +[MASK]
        "pos_embed": _init_pos(k[1], cfg.seq_len, d),
        "blocks": _init_blocks(k[2], cfg.n_blocks, d, cfg.n_heads, 4 * d),
    }


def bert4rec_hidden(params, hist, mask, cfg: RecsysConfig):
    x = jnp.take(params["item_table"], hist, axis=0) + params["pos_embed"][None]
    x = x * mask[..., None]
    return _attn_blocks(params["blocks"], x, cfg.n_heads, causal=False)


def bert4rec_loss(params, batch, cfg: RecsysConfig):
    """Cloze objective over sampled candidates (1 true + n_neg) at masked
    positions — full-vocab softmax at n_items=2**20 × B=65536 is deliberately
    avoided (sampled softmax, standard at this scale)."""
    h = bert4rec_hidden(params, batch["hist"], batch["hist_mask"], cfg)
    mpos = batch["mask_pos"]                       # (B, M) positions
    hm = jnp.take_along_axis(h, mpos[..., None], axis=1)        # (B, M, d)
    cands = batch["cands"]                         # (B, M, 1+n_neg), [,:,0]=true
    e_c = jnp.take(params["item_table"], cands, axis=0)         # (B,M,C,d)
    logits = jnp.einsum("bmd,bmcd->bmc", hm, e_c)
    logp = jax.nn.log_softmax(logits, -1)
    m = batch["mask_valid"].astype(jnp.float32)    # (B, M)
    return -(logp[..., 0] * m).sum() / jnp.maximum(m.sum(), 1.0), {}


def bert4rec_score(params, batch, cfg: RecsysConfig):
    h = bert4rec_hidden(params, batch["hist"], batch["hist_mask"], cfg)
    e_t = jnp.take(params["item_table"], batch["target_item"], axis=0)
    return jnp.sum(h[:, -1] * e_t, -1)


def bert4rec_retrieval(params, batch, cfg: RecsysConfig):
    h = bert4rec_hidden(params, batch["hist"][None],
                        batch["hist_mask"][None], cfg)[0, -1]
    e_c = jnp.take(params["item_table"], batch["cand_items"], axis=0)
    return e_c @ h


# ---------------------------------------------------------------------------
# MIND — multi-interest capsule routing (arXiv:1904.08030)
# ---------------------------------------------------------------------------

def init_mind(rng, cfg: RecsysConfig):
    k = jax.random.split(rng, 3)
    d = cfg.embed_dim
    return {
        "item_table": _init_table(k[0], cfg.n_items, d),
        "w_caps": jax.random.normal(k[1], (d, d)) / np.sqrt(d),
        "route_init": jax.random.normal(k[2],
                                        (cfg.seq_len, cfg.n_interests)) * 0.1,
    }


def _squash(s):
    n2 = jnp.sum(s * s, -1, keepdims=True)
    return (n2 / (1 + n2)) * s / jnp.sqrt(n2 + 1e-9)


def mind_interests(params, hist, mask, cfg: RecsysConfig):
    """Dynamic B2I routing (fixed shared init logits, 3 iterations)."""
    e = jnp.take(params["item_table"], hist, axis=0)     # (B,L,d)
    eh = e @ params["w_caps"]                            # (B,L,d)
    B, Lh, d = eh.shape
    b = jnp.broadcast_to(params["route_init"][None], (B, Lh, cfg.n_interests))
    neg = -1e9 * (1.0 - mask)[..., None]
    caps = None
    for _ in range(cfg.capsule_iters):
        c = jax.nn.softmax(b + neg, axis=1)              # over history
        s = jnp.einsum("blk,bld->bkd", c, eh)
        caps = _squash(s)                                # (B,K,d)
        b = b + jnp.einsum("bkd,bld->blk", caps, eh)
    return caps


def mind_loss(params, batch, cfg: RecsysConfig):
    """Sampled softmax with label-aware max-interest scoring."""
    caps = mind_interests(params, batch["hist"], batch["hist_mask"], cfg)
    cands = batch["cands"]                               # (B, 1+n_neg)
    e_c = jnp.take(params["item_table"], cands, axis=0)  # (B,C,d)
    scores = jnp.einsum("bkd,bcd->bkc", caps, e_c).max(axis=1)
    logp = jax.nn.log_softmax(scores, -1)
    return -logp[:, 0].mean(), {}


def mind_score(params, batch, cfg: RecsysConfig):
    caps = mind_interests(params, batch["hist"], batch["hist_mask"], cfg)
    e_t = jnp.take(params["item_table"], batch["target_item"], axis=0)
    return jnp.einsum("bkd,bd->bk", caps, e_t).max(-1)


def mind_retrieval(params, batch, cfg: RecsysConfig):
    caps = mind_interests(params, batch["hist"][None],
                          batch["hist_mask"][None], cfg)[0]   # (K,d)
    e_c = jnp.take(params["item_table"], batch["cand_items"], axis=0)
    return (e_c @ caps.T).max(-1)                             # (C,)


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

INIT = {"din": init_din, "sasrec": init_sasrec, "bert4rec": init_bert4rec,
        "mind": init_mind}
LOSS = {"din": din_loss, "sasrec": sasrec_loss, "bert4rec": bert4rec_loss,
        "mind": mind_loss}
SCORE = {"din": din_score, "sasrec": sasrec_score, "bert4rec": bert4rec_score,
         "mind": mind_score}
RETRIEVAL = {"din": din_retrieval, "sasrec": sasrec_retrieval,
             "bert4rec": bert4rec_retrieval, "mind": mind_retrieval}
