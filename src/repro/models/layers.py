"""Shared transformer layers: RMSNorm, RoPE, GQA attention (full / chunked /
decode), GLU MLPs.  Pure functions over parameter pytrees; bf16 compute with
f32 accumulation.  Chunked attention implements the online-softmax (flash)
recurrence in lax.scan so 32k–500k contexts never materialize (S, S) scores.
"""

from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def rms_norm(x, w, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
    return out.astype(x.dtype)


def rope_angles(positions, head_dim: int, theta: float = 10000.0):
    """positions: (...,) int32 → cos, sin of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-np.arange(0, half, dtype=np.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., S, H, D); cos/sin: (..., S, D//2) → rotated x."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]      # broadcast over heads
    s = sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.repeat(k, n_rep, axis=2)


def attention_full(q, k, v, causal: bool = True, q_offset: int = 0,
                   scores_dtype=jnp.float32):
    """q: (B, Sq, H, D), k/v: (B, Sk, Hkv, D). Materializes (Sq, Sk) scores —
    used for short sequences; long contexts use attention_chunked.

    scores_dtype=bf16 halves the dominant memory-roofline buffer class for
    training shapes (EXPERIMENTS §Perf iteration 7); f32 is the default for
    softmax fidelity.  (The production TPU answer is a flash kernel that
    keeps scores VMEM-resident; traffic numbers here assume no such kernel.)
    """
    B, Sq, H, D = q.shape
    n_rep = H // k.shape[2]
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    scale = 1.0 / np.sqrt(D)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=scores_dtype) * scale
    logits = logits.astype(jnp.float32)
    if causal:
        qpos = jnp.arange(Sq)[:, None] + q_offset
        kpos = jnp.arange(k.shape[1])[None, :]
        logits = jnp.where(qpos >= kpos, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attention_chunked(q, k, v, chunk: int = 1024, causal: bool = True,
                      unroll: bool = False):
    """Online-softmax attention (flash recurrence, lax.scan over KV chunks).
    Never materializes more than (B, H, Sq_blk, chunk) scores."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    n_rep = H // k.shape[2]
    scale = 1.0 / np.sqrt(D)
    n_chunks = Sk // chunk
    assert Sk % chunk == 0, "pad KV to chunk multiple"
    kc = k.reshape(B, n_chunks, chunk, k.shape[2], D)
    vc = v.reshape(B, n_chunks, chunk, v.shape[2], D)

    def step(carry, inputs):
        m, l, acc = carry
        idx, kb, vb = inputs
        kb = _repeat_kv(kb, n_rep)
        vb = _repeat_kv(vb, n_rep)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kb,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = jnp.arange(Sq)[:, None]
            kpos = idx * chunk + jnp.arange(chunk)[None, :]
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(q.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, D), jnp.float32)
    (m, l, acc), _ = lax.scan(
        step, (m0, l0, a0),
        (jnp.arange(n_chunks), kc.transpose(1, 0, 2, 3, 4),
         vc.transpose(1, 0, 2, 3, 4)),
        unroll=n_chunks if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)   # (B, Sq, H, D)


def attention_decode(q, k_cache, v_cache, length):
    """Single-token decode: q (B, 1, H, D) vs cache (B, S, Hkv, D); positions
    ≥ length are masked. O(S·D) per head — linear, not quadratic (DESIGN.md
    long_500k note).

    Sharding (flash-decoding split-K; EXPERIMENTS §Perf iteration 8): q is
    replicated over 'model' and logits pinned S-sharded — without the hints
    the partitioner all-gathers the full KV cache to satisfy head-sharded
    logits (measured 215 GB of collectives per decoded token at 500k)."""
    from repro.distributed.sharding import shard_hint
    B, _, H, D = q.shape
    n_rep = H // k_cache.shape[2]
    q = shard_hint(q, "decode_q")
    k = _repeat_kv(k_cache, n_rep)
    v = _repeat_kv(v_cache, n_rep)
    scale = 1.0 / np.sqrt(D)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = shard_hint(logits, "decode_logits")
    mask = jnp.arange(k.shape[1])[None, None, None, :] < length
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def glu_mlp(x, w_in, w_gate, w_out, act: str):
    """GeGLU (gemma) / SwiGLU (llama-family) feed-forward."""
    h = jnp.einsum("...d,df->...f", x, w_in.astype(x.dtype))
    g = jnp.einsum("...d,df->...f", x, w_gate.astype(x.dtype))
    g = jax.nn.gelu(g) if act == "geglu" else jax.nn.silu(g)
    return jnp.einsum("...f,fd->...d", h * g, w_out.astype(x.dtype))
