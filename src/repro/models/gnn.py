"""GraphSAGE (mean aggregator) — full-batch, sampled-minibatch, and
batched-small-graph variants.

Message passing is implemented with ``jnp.take`` + ``jax.ops.segment_sum``
over an edge index (JAX has no CSR SpMM; the scatter path IS the system — see
kernel_taxonomy §GNN).  The neighbor sampler is a real uniform-with-
replacement sampler over CSR adjacency, jit-compatible (used inside the
minibatch train step).  Adjacency rows are sorted integer lists and are
stored compressed with the paper's codec in the data pipeline
(repro/data/graph_data.py) — the paper's technique applied to GNN substrate.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int = 2
    d_hidden: int = 128
    d_feat: int = 602
    n_classes: int = 41
    aggregator: str = "mean"
    sample_sizes: tuple[int, ...] = (25, 10)
    task: str = "node"           # 'node' | 'graph'
    compute_dtype: str = "float32"


def init_params(rng, cfg: GNNConfig):
    keys = jax.random.split(rng, cfg.n_layers * 2 + 2)
    params = {"layers": []}
    d_in = cfg.d_feat
    for i in range(cfg.n_layers):
        s = 1.0 / np.sqrt(d_in)
        params["layers"].append({
            "w_self": jax.random.normal(keys[2 * i], (d_in, cfg.d_hidden)) * s,
            "w_neigh": jax.random.normal(keys[2 * i + 1],
                                         (d_in, cfg.d_hidden)) * s,
            "b": jnp.zeros((cfg.d_hidden,)),
        })
        d_in = cfg.d_hidden
    s = 1.0 / np.sqrt(d_in)
    params["head"] = jax.random.normal(keys[-1], (d_in, cfg.n_classes)) * s
    return params


def _sage_layer(lp, h, h_neigh_mean, act=True):
    out = h @ lp["w_self"] + h_neigh_mean @ lp["w_neigh"] + lp["b"]
    if act:
        out = jax.nn.relu(out)
    # L2-normalize as in the paper (GraphSAGE §3.1)
    return out / jnp.maximum(jnp.linalg.norm(out, axis=-1, keepdims=True), 1e-6)


# ---------------------------------------------------------------------------
# full-batch (full_graph_sm / ogb_products)
# ---------------------------------------------------------------------------

def full_graph_forward(params, x, edge_src, edge_dst, cfg: GNNConfig):
    """x: (N, F); edge_src/dst: (E,) int32 (messages flow src → dst)."""
    N = x.shape[0]
    deg = jax.ops.segment_sum(jnp.ones_like(edge_dst, dtype=jnp.float32),
                              edge_dst, num_segments=N)
    inv_deg = 1.0 / jnp.maximum(deg, 1.0)
    h = x
    for i, lp in enumerate(params["layers"]):
        msg = jax.ops.segment_sum(jnp.take(h, edge_src, axis=0), edge_dst,
                                  num_segments=N)
        h = _sage_layer(lp, h, msg * inv_deg[:, None],
                        act=i < len(params["layers"]) - 1)
    return h @ params["head"]


def node_loss(params, batch, cfg: GNNConfig):
    logits = full_graph_forward(params, batch["x"], batch["edge_src"],
                                batch["edge_dst"], cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1)[:, 0]
    mask = batch["train_mask"].astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0), {}


# ---------------------------------------------------------------------------
# neighbor sampler + sampled minibatch (minibatch_lg)
# ---------------------------------------------------------------------------

def sample_neighbors(rng, indptr, indices, nodes, fanout: int):
    """Uniform-with-replacement neighbor sampling from CSR.

    nodes: (M,) → (M, fanout) sampled neighbor ids (self-loop if degree 0)."""
    deg = jnp.take(indptr, nodes + 1) - jnp.take(indptr, nodes)
    r = jax.random.randint(rng, nodes.shape + (fanout,), 0, 1 << 30)
    off = r % jnp.maximum(deg, 1)[:, None]
    idx = jnp.take(indptr, nodes)[:, None] + off
    nbr = jnp.take(indices, jnp.clip(idx, 0, indices.shape[0] - 1))
    return jnp.where(deg[:, None] > 0, nbr, nodes[:, None])


def minibatch_forward(params, feats, indptr, indices, seeds, rng,
                      cfg: GNNConfig, fanout: tuple[int, ...]):
    """2-hop sampled GraphSAGE forward for seed nodes.

    feats: (N, F) full feature table; seeds: (B,)."""
    B = seeds.shape[0]
    k1, k2 = jax.random.split(rng)
    l1 = sample_neighbors(k1, indptr, indices, seeds, fanout[0])     # (B,f1)
    l2 = sample_neighbors(k2, indptr, indices, l1.reshape(-1),
                          fanout[1]).reshape(B, fanout[0], fanout[1])

    h_seed = jnp.take(feats, seeds, axis=0)                  # (B,F)
    h_l1 = jnp.take(feats, l1, axis=0)                       # (B,f1,F)
    h_l2 = jnp.take(feats, l2, axis=0)                       # (B,f1,f2,F)

    lp0, lp1 = params["layers"][0], params["layers"][1]
    # hop-2 → hop-1 (layer 0 applied to l1 nodes)
    h_l1_new = _sage_layer(lp0, h_l1, h_l2.mean(axis=2), act=True)
    # hop-1 → seeds (layer 0 applied to seeds)
    h_seed_new = _sage_layer(lp0, h_seed, h_l1.mean(axis=1), act=True)
    # layer 1 on seeds with aggregated new hop-1 states
    h_final = _sage_layer(lp1, h_seed_new, h_l1_new.mean(axis=1), act=False)
    return h_final @ params["head"]


def minibatch_loss(params, batch, rng, cfg: GNNConfig,
                   fanout: tuple[int, ...]):
    logits = minibatch_forward(params, batch["feats"], batch["indptr"],
                               batch["indices"], batch["seeds"], rng, cfg,
                               fanout)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1)[:, 0]
    return nll.mean(), {}


# ---------------------------------------------------------------------------
# batched small graphs (molecule)
# ---------------------------------------------------------------------------

def molecule_forward(params, x, edge_src, edge_dst, node_mask, cfg: GNNConfig):
    """x: (G, n, F); edges: (G, e) int32 per-graph local ids; node_mask (G,n)."""

    def one(xg, src, dst, mask):
        n = xg.shape[0]
        deg = jax.ops.segment_sum(jnp.ones_like(dst, jnp.float32), dst,
                                  num_segments=n)
        inv = 1.0 / jnp.maximum(deg, 1.0)
        h = xg
        for i, lp in enumerate(params["layers"]):
            msg = jax.ops.segment_sum(jnp.take(h, src, axis=0), dst,
                                      num_segments=n)
            h = _sage_layer(lp, h, msg * inv[:, None], act=True)
        pooled = (h * mask[:, None]).sum(0) / jnp.maximum(mask.sum(), 1.0)
        return pooled

    pooled = jax.vmap(one)(x, edge_src, edge_dst, node_mask)   # (G, d)
    return pooled @ params["head"]


def molecule_loss(params, batch, cfg: GNNConfig):
    pred = molecule_forward(params, batch["x"], batch["edge_src"],
                            batch["edge_dst"], batch["node_mask"], cfg)
    err = (pred[:, 0] - batch["targets"]) ** 2
    return err.mean(), {}
