"""Config-driven decoder-only transformer (dense or MoE) with GQA, RoPE,
GeGLU/SwiGLU, RMSNorm; scan-over-layers; train / prefill / decode entry
points.  Covers gemma-7b, phi3-medium-14b, internlm2-1.8b,
granite-moe-1b-a400m and kimi-k2-1t-a32b via LMConfig.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.distributed.sharding import shard_hint


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 → d_model // n_heads
    act: str = "swiglu"               # 'swiglu' | 'geglu'
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    embed_scale: bool = False         # gemma multiplies embeddings by sqrt(d)
    # MoE
    n_experts: int = 0                # 0 → dense FFN
    top_k: int = 0
    capacity_factor: float = 1.25
    # numerics / memory
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "dots"               # 'none' | 'dots' | 'full'
    attn_chunk: int = 1024            # KV chunk for online-softmax attention
    unroll_scan: bool = False         # dry-run cost probes: unroll all scans
    attn_scores_dtype: str = "float32"  # 'bfloat16' = Perf iteration 7
    full_attn_max_seq: int = 8192     # above this, use chunked attention
    sharding_preset: str = "tp"       # 'tp' | 'fsdp'

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        hd = self.hd
        attn = self.d_model * hd * (self.n_heads + 2 * self.n_kv) \
            + self.n_heads * hd * self.d_model
        if self.is_moe:
            ffn = self.n_experts * 3 * self.d_model * self.d_ff \
                + self.d_model * self.n_experts
        else:
            ffn = 3 * self.d_model * self.d_ff
        embed = self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        return self.n_layers * (attn + ffn + 2 * self.d_model) + embed

    def active_param_count(self) -> int:
        """Activated params (MoE: top_k experts only) for 6·N·D accounting."""
        if not self.is_moe:
            return self.param_count()
        hd = self.hd
        attn = self.d_model * hd * (self.n_heads + 2 * self.n_kv) \
            + self.n_heads * hd * self.d_model
        ffn = self.top_k * 3 * self.d_model * self.d_ff \
            + self.d_model * self.n_experts
        embed = self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        return self.n_layers * (attn + ffn + 2 * self.d_model) + embed


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_params(rng, cfg: LMConfig):
    pdt = jnp.dtype(cfg.param_dtype)
    hd = cfg.hd
    k = iter(jax.random.split(rng, 16))
    s = 1.0 / np.sqrt(cfg.d_model)

    def mk(key, shape, scale):
        return (jax.random.normal(key, shape) * scale).astype(pdt)

    layer = {
        "ln1": jnp.zeros((cfg.n_layers, cfg.d_model), pdt),
        "ln2": jnp.zeros((cfg.n_layers, cfg.d_model), pdt),
        "attn": {
            "wq": mk(next(k), (cfg.n_layers, cfg.d_model, cfg.n_heads * hd), s),
            "wk": mk(next(k), (cfg.n_layers, cfg.d_model, cfg.n_kv * hd), s),
            "wv": mk(next(k), (cfg.n_layers, cfg.d_model, cfg.n_kv * hd), s),
            "wo": mk(next(k), (cfg.n_layers, cfg.n_heads * hd, cfg.d_model),
                     1.0 / np.sqrt(cfg.n_heads * hd)),
        },
    }
    if cfg.is_moe:
        layer["moe"] = {
            "router": mk(next(k), (cfg.n_layers, cfg.d_model, cfg.n_experts),
                         s).astype(jnp.float32),
            "w_in": mk(next(k), (cfg.n_layers, cfg.n_experts, cfg.d_model,
                                 cfg.d_ff), s),
            "w_gate": mk(next(k), (cfg.n_layers, cfg.n_experts, cfg.d_model,
                                   cfg.d_ff), s),
            "w_out": mk(next(k), (cfg.n_layers, cfg.n_experts, cfg.d_ff,
                                  cfg.d_model), 1.0 / np.sqrt(cfg.d_ff)),
        }
    else:
        layer["mlp"] = {
            "w_in": mk(next(k), (cfg.n_layers, cfg.d_model, cfg.d_ff), s),
            "w_gate": mk(next(k), (cfg.n_layers, cfg.d_model, cfg.d_ff), s),
            "w_out": mk(next(k), (cfg.n_layers, cfg.d_ff, cfg.d_model),
                        1.0 / np.sqrt(cfg.d_ff)),
        }
    params = {
        "embed": mk(next(k), (cfg.vocab, cfg.d_model), 1.0),
        "final_norm": jnp.zeros((cfg.d_model,), pdt),
        "layers": layer,
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = mk(next(k), (cfg.vocab, cfg.d_model), s)
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _layer_fwd(cfg: LMConfig, lp, x, cos, sin):
    """One decoder layer on (B, S, D). Returns (x, aux)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    hd = cfg.hd
    B, S, _ = x.shape

    h = L.rms_norm(x, lp["ln1"].astype(jnp.float32))
    q = jnp.einsum("bsd,dh->bsh", h, lp["attn"]["wq"].astype(cdt))
    kk = jnp.einsum("bsd,dh->bsh", h, lp["attn"]["wk"].astype(cdt))
    vv = jnp.einsum("bsd,dh->bsh", h, lp["attn"]["wv"].astype(cdt))
    q = q.reshape(B, S, cfg.n_heads, hd)
    kk = kk.reshape(B, S, cfg.n_kv, hd)
    vv = vv.reshape(B, S, cfg.n_kv, hd)
    q = L.apply_rope(q, cos, sin)
    kk = L.apply_rope(kk, cos, sin)
    q = shard_hint(q, "act_qkv")
    # explicit SP→replicated all-gather for KV: without this XLA falls into
    # an "involuntary full rematerialization" reshard (EXPERIMENTS §Perf i5)
    kk = shard_hint(kk, "act_kv")
    vv = shard_hint(vv, "act_kv")
    if S > cfg.full_attn_max_seq:
        attn = L.attention_chunked(q, kk, vv, chunk=cfg.attn_chunk,
                                       unroll=cfg.unroll_scan)
    else:
        attn = L.attention_full(
            q, kk, vv, scores_dtype=jnp.dtype(cfg.attn_scores_dtype))
    attn = attn.reshape(B, S, cfg.n_heads * hd)
    proj = jnp.einsum("bsh,hd->bsd", attn, lp["attn"]["wo"].astype(cdt))
    x = x + shard_hint(proj, "act_resid")   # reduce-scatter at the producer

    h = L.rms_norm(x, lp["ln2"].astype(jnp.float32))
    if cfg.is_moe:
        out, aux = moe_lib.moe_ffn(lp["moe"], h, top_k=cfg.top_k,
                                   capacity_factor=cfg.capacity_factor,
                                   act=cfg.act)
    else:
        out = L.glu_mlp(h, lp["mlp"]["w_in"], lp["mlp"]["w_gate"],
                        lp["mlp"]["w_out"], cfg.act)
        aux = jnp.float32(0.0)
    x = x + shard_hint(out, "act_resid")
    x = shard_hint(x, "act_resid")
    return x, aux


def forward(params, tokens, cfg: LMConfig, return_hidden: bool = False):
    """tokens: (B, S) int32 → logits (B, S, V) in f32, aux loss."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    if cfg.embed_scale:
        # keep compute dtype: a bare f32 scalar multiply silently promotes
        # the entire residual stream to f32 (caught via dtype-promotion
        # warning in the gemma smoke test)
        x = (x * np.sqrt(cfg.d_model)).astype(cdt)
    B, S, _ = x.shape
    cos, sin = L.rope_angles(jnp.arange(S), cfg.hd, cfg.rope_theta)
    cos = jnp.broadcast_to(cos[None], (B,) + cos.shape)
    sin = jnp.broadcast_to(sin[None], (B,) + sin.shape)

    def body(x, lp):
        x, aux = _layer_fwd(cfg, lp, x, cos, sin)
        return x, aux

    if cfg.remat != "none":
        # 'dots' saves weight matmuls but NOT batched (attention-score) dots —
        # saving (B,H,S,S) scores across a 24-layer scan is ~25 GB/chip at 4k
        # (measured in the dry-run; see EXPERIMENTS §Perf iteration log).
        policy = (jax.checkpoint_policies.nothing_saveable
                  if cfg.remat == "full"
                  else jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
        body = jax.checkpoint(body, policy=policy)
    x, auxs = lax.scan(body, x, params["layers"],
                   unroll=cfg.n_layers if cfg.unroll_scan else 1)
    x = L.rms_norm(x, params["final_norm"].astype(jnp.float32))
    if return_hidden:
        return x, jnp.sum(auxs)
    head = params.get("lm_head", params["embed"])
    logits = jnp.einsum("bsd,vd->bsv", x, head.astype(cdt),
                        preferred_element_type=jnp.float32)
    return logits, jnp.sum(auxs)


def lm_hidden(params, tokens, cfg: LMConfig):
    """forward() without the vocab projection; returns (x_final, aux)."""
    return forward(params, tokens, cfg, return_hidden=True)


def lm_loss(params, batch, cfg: LMConfig, aux_weight: float = 0.01):
    x, aux = lm_hidden(params, batch["tokens"], cfg)
    labels = batch["labels"]
    head = params.get("lm_head", params["embed"])

    # Loss head (EXPERIMENTS §Perf iteration 6): the naive head materializes
    # ~5 (B,S,V) f32 buffers (logits, log-softmax, take_along_axis backward
    # scatter, layout copy).  Instead: bf16 logits feeding a fused f32
    # logsumexp, the picked logit via a gather-dot (row-gather of the head
    # by label, then an elementwise dot — no (B,S,V) backward exists), and
    # the whole head rematerialized in the backward pass.
    def head_loss(x, head):
        logits = jnp.einsum("bsd,vd->bsv", x, head.astype(x.dtype))
        logits = shard_hint(logits, "logits")      # V over 'model'
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        # picked logit via take_along_axis on the *vocab-sharded* logits: its
        # backward is a (B,S,V)-sharded scatter.  (Iteration i6 used a
        # gather-dot on head rows instead; i10 measured its backward as a
        # *replicated* (V,D) f32 scatter + all-reduce — ~13 GB per 2-layer
        # probe at gemma's 256k vocab.  EXPERIMENTS §Perf i10.)
        picked = jnp.take_along_axis(
            logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
        nll = lse - picked.astype(jnp.float32)
        mask = (labels >= 0).astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0)

    loss = jax.checkpoint(head_loss)(x, head)
    return loss + aux_weight * aux, {"nll": loss, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode with KV cache
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: LMConfig, batch: int, max_len: int):
    cdt = jnp.dtype(cfg.compute_dtype)
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv, cfg.hd)
    return {"k": jnp.zeros(shape, cdt), "v": jnp.zeros(shape, cdt)}


def prefill(params, tokens, cfg: LMConfig):
    """Full-sequence forward that also returns the KV cache.

    tokens: (B, S). Returns (last-token logits (B, V), cache)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    if cfg.embed_scale:
        # keep compute dtype: a bare f32 scalar multiply silently promotes
        # the entire residual stream to f32 (caught via dtype-promotion
        # warning in the gemma smoke test)
        x = (x * np.sqrt(cfg.d_model)).astype(cdt)
    B, S, _ = x.shape
    cos, sin = L.rope_angles(jnp.arange(S), cfg.hd, cfg.rope_theta)
    cos = jnp.broadcast_to(cos[None], (B,) + cos.shape)
    sin = jnp.broadcast_to(sin[None], (B,) + sin.shape)
    hd = cfg.hd

    def body(x, lp):
        h = L.rms_norm(x, lp["ln1"].astype(jnp.float32))
        q = jnp.einsum("bsd,dh->bsh", h, lp["attn"]["wq"].astype(cdt))
        kk = jnp.einsum("bsd,dh->bsh", h, lp["attn"]["wk"].astype(cdt))
        vv = jnp.einsum("bsd,dh->bsh", h, lp["attn"]["wv"].astype(cdt))
        q = L.apply_rope(q.reshape(B, S, cfg.n_heads, hd), cos, sin)
        kk = L.apply_rope(kk.reshape(B, S, cfg.n_kv, hd), cos, sin)
        vv = vv.reshape(B, S, cfg.n_kv, hd)
        kk = shard_hint(kk, "kv_cache")
        vv = shard_hint(vv, "kv_cache")
        if S > cfg.full_attn_max_seq:
            attn = L.attention_chunked(q, kk, vv, chunk=cfg.attn_chunk,
                                       unroll=cfg.unroll_scan)
        else:
            attn = L.attention_full(q, kk, vv)
        attn = attn.reshape(B, S, cfg.n_heads * hd)
        x = x + jnp.einsum("bsh,hd->bsd", attn, lp["attn"]["wo"].astype(cdt))
        h = L.rms_norm(x, lp["ln2"].astype(jnp.float32))
        if cfg.is_moe:
            out, _ = moe_lib.moe_ffn(lp["moe"], h, top_k=cfg.top_k,
                                     capacity_factor=cfg.capacity_factor,
                                     act=cfg.act)
        else:
            out = L.glu_mlp(h, lp["mlp"]["w_in"], lp["mlp"]["w_gate"],
                            lp["mlp"]["w_out"], cfg.act)
        x = x + out
        x = shard_hint(x, "act_resid")
        return x, (kk, vv)

    x, (ks, vs) = lax.scan(body, x, params["layers"],
                       unroll=cfg.n_layers if cfg.unroll_scan else 1)
    x = L.rms_norm(x, params["final_norm"].astype(jnp.float32))
    head = params.get("lm_head", params["embed"])
    logits = jnp.einsum("bd,vd->bv", x[:, -1], head.astype(cdt),
                        preferred_element_type=jnp.float32)
    return logits, {"k": ks, "v": vs}


def decode_step(params, cache, token, pos, cfg: LMConfig):
    """One decode step. token: (B,) int32; pos: scalar int32 (current length).

    cache k/v: (L, B, S_max, Hkv, hd). Returns (logits (B, V), new cache)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    B = token.shape[0]
    hd = cfg.hd
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(cdt)
    if cfg.embed_scale:
        # keep compute dtype: a bare f32 scalar multiply silently promotes
        # the entire residual stream to f32 (caught via dtype-promotion
        # warning in the gemma smoke test)
        x = (x * np.sqrt(cfg.d_model)).astype(cdt)
    cos, sin = L.rope_angles(pos[None], cfg.hd, cfg.rope_theta)
    cos = jnp.broadcast_to(cos[None], (B, 1, hd // 2))
    sin = jnp.broadcast_to(sin[None], (B, 1, hd // 2))

    def body(x, layer_in):
        lp, kc, vc = layer_in
        h = L.rms_norm(x, lp["ln1"].astype(jnp.float32))
        q = jnp.einsum("bsd,dh->bsh", h, lp["attn"]["wq"].astype(cdt))
        kk = jnp.einsum("bsd,dh->bsh", h, lp["attn"]["wk"].astype(cdt))
        vv = jnp.einsum("bsd,dh->bsh", h, lp["attn"]["wv"].astype(cdt))
        q = L.apply_rope(q.reshape(B, 1, cfg.n_heads, hd), cos, sin)
        kk = L.apply_rope(kk.reshape(B, 1, cfg.n_kv, hd), cos, sin)
        vv = vv.reshape(B, 1, cfg.n_kv, hd)
        kc = lax.dynamic_update_slice(kc, kk.astype(kc.dtype), (0, pos, 0, 0))
        vc = lax.dynamic_update_slice(vc, vv.astype(vc.dtype), (0, pos, 0, 0))
        attn = L.attention_decode(q, kc, vc, pos + 1)
        attn = attn.reshape(B, 1, cfg.n_heads * hd)
        x = x + jnp.einsum("bsh,hd->bsd", attn, lp["attn"]["wo"].astype(cdt))
        h = L.rms_norm(x, lp["ln2"].astype(jnp.float32))
        if cfg.is_moe:
            out, _ = moe_lib.moe_ffn(lp["moe"], h, top_k=cfg.top_k,
                                     capacity_factor=cfg.capacity_factor,
                                     act=cfg.act)
        else:
            out = L.glu_mlp(h, lp["mlp"]["w_in"], lp["mlp"]["w_gate"],
                            lp["mlp"]["w_out"], cfg.act)
        x = x + out
        return x, (kc, vc)

    x, (ks, vs) = lax.scan(body, x, (params["layers"], cache["k"], cache["v"]),
                       unroll=cfg.n_layers if cfg.unroll_scan else 1)
    x = L.rms_norm(x, params["final_norm"].astype(jnp.float32))
    head = params.get("lm_head", params["embed"])
    logits = jnp.einsum("bd,vd->bv", x[:, 0], head.astype(cdt),
                        preferred_element_type=jnp.float32)
    return logits, {"k": ks, "v": vs}
