"""Mixture-of-Experts layer: top-k routing with capacity-bounded dispatch.

Two implementations with identical semantics (tested against each other):

``moe_ffn_local`` — single-shard sort-based dispatch (MegaBlocks/MaxText
style, no ragged ops): router → top-k → argsort by expert → position-in-group
→ scatter into an (E, C, D) buffer → batched expert GEMMs → combine.

``moe_ffn_sharded`` — the production expert-parallel path.  Under plain pjit
a *global* sort-based dispatch forces XLA to replicate the data-dependent
scatter (measured on granite train_4k: 177 GB temp, 7.5 TB collective bytes —
EXPERIMENTS §Perf iteration 1).  Here tokens stay in their (pod, data,
model-SP) shard; each device routes locally, packs per-destination send
buffers, and two ``all_to_all`` ops over the 'model' axis move tokens to
their expert shard and results back.  No global scatter exists; the MoE
communication term becomes the textbook 2×(tokens·D) per direction.

Tokens beyond capacity are dropped (standard capacity-factor semantics); a
Switch-style aux load-balancing loss is returned.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

try:                                    # jax>=0.7 exposes it at top level
    shard_map = jax.shard_map
except AttributeError:                  # pragma: no cover
    from jax.experimental.shard_map import shard_map

# jax >= 0.7 renamed the replication-check kwarg check_rep -> check_vma
import inspect as _inspect
_SHARD_MAP_NO_CHECK = (
    {"check_vma": False}
    if "check_vma" in _inspect.signature(shard_map).parameters
    else {"check_rep": False})


def init_moe_params(rng, d_model: int, d_ff: int, n_experts: int,
                    dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    s_in = 1.0 / np.sqrt(d_model)
    s_out = 1.0 / np.sqrt(d_ff)
    return {
        "router": (jax.random.normal(k1, (d_model, n_experts)) * s_in
                   ).astype(jnp.float32),      # router stays f32
        "w_in": (jax.random.normal(k2, (n_experts, d_model, d_ff)) * s_in
                 ).astype(dtype),
        "w_gate": (jax.random.normal(k3, (n_experts, d_model, d_ff)) * s_in
                   ).astype(dtype),
        "w_out": (jax.random.normal(k4, (n_experts, d_ff, d_model)) * s_out
                  ).astype(dtype),
    }


def _route(router, xf, top_k: int, n_experts: int):
    """Shared router math: returns (weights (N,k), expert ids (N,k), probs)."""
    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, expert_idx = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return weights, expert_idx, probs


def _aux_loss(expert_idx, probs, n_experts: int):
    density = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], n_experts), axis=0)
    density_prob = jnp.mean(probs, axis=0)
    return density, density_prob


def _group_positions(sorted_ids, n_groups: int):
    """Position of each element within its (sorted) group."""
    n = sorted_ids.shape[0]
    gsz = jax.ops.segment_sum(jnp.ones_like(sorted_ids), sorted_ids,
                              num_segments=n_groups)
    gstart = jnp.cumsum(gsz) - gsz
    pos = jnp.arange(n, dtype=jnp.int32) - jnp.take(
        gstart, jnp.clip(sorted_ids, 0, n_groups - 1))
    return pos, gsz


def _expert_mlp(buf, w_in, w_gate, w_out, act: str):
    h = jnp.einsum("ecd,edf->ecf", buf, w_in.astype(buf.dtype))
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(buf.dtype))
    g = jax.nn.gelu(g) if act == "geglu" else jax.nn.silu(g)
    return jnp.einsum("ecf,efd->ecd", h * g, w_out.astype(buf.dtype))


# ---------------------------------------------------------------------------
# local (single-shard) path
# ---------------------------------------------------------------------------

def moe_ffn_local(params, x, *, top_k: int, capacity_factor: float = 1.25,
                  act: str = "swiglu"):
    """x: (B, S, D) → (B, S, D), aux_loss (scalar)."""
    B, S, D = x.shape
    E = params["router"].shape[1]
    N = B * S
    xf = x.reshape(N, D)
    weights, expert_idx, probs = _route(params["router"], xf, top_k, E)
    density, density_prob = _aux_loss(expert_idx, probs, E)
    aux = jnp.sum(density * density_prob) * E

    C = max(int(np.ceil(N * top_k / E * capacity_factor)), 1)
    ids = expert_idx.reshape(-1)                               # (N·k,)
    order = jnp.argsort(ids)
    sorted_ids = ids[order]
    pos, _ = _group_positions(sorted_ids, E)
    keep = pos < C
    pos_w = jnp.where(keep, pos, C)                            # OOB → dropped
    token_of = order // top_k

    buf = jnp.zeros((E, C, D), dtype=x.dtype)
    buf = buf.at[sorted_ids, pos_w].add(
        jnp.where(keep[:, None], xf[token_of], 0).astype(x.dtype),
        mode="drop")
    out_buf = _expert_mlp(buf, params["w_in"], params["w_gate"],
                          params["w_out"], act)

    slot_vals = out_buf[sorted_ids, jnp.where(keep, pos, 0)]   # (N·k, D)
    slot_vals = jnp.where(keep[:, None], slot_vals, 0)
    w_sorted = weights.reshape(-1)[order]
    contrib = slot_vals * w_sorted[:, None].astype(x.dtype)
    out = jax.ops.segment_sum(contrib, token_of, num_segments=N)
    return out.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# expert-parallel shard_map path
# ---------------------------------------------------------------------------

def moe_ffn_sharded(params, x, *, top_k: int, capacity_factor: float,
                    act: str, mesh):
    """x: (B, S, D) sharded P(dp, 'model', None) → same layout, aux scalar."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import batch_axes

    dp = batch_axes(mesh)
    M = mesh.shape["model"]
    E = params["router"].shape[1]
    E_loc = E // M
    B, S, D = x.shape

    def body(router, w_in, w_gate, w_out, xb):
        N_loc = xb.shape[0] * xb.shape[1]
        xf = xb.reshape(N_loc, D)
        weights, expert_idx, probs = _route(router, xf, top_k, E)
        density, density_prob = _aux_loss(expert_idx, probs, E)
        axes = dp + ("model",)
        aux = jnp.sum(jax.lax.pmean(density, axes)
                      * jax.lax.pmean(density_prob, axes)) * E

        Nk = N_loc * top_k
        cap = max(int(np.ceil(Nk / M * capacity_factor)), 1)
        ids = expert_idx.reshape(-1)                    # (Nk,)
        w_flat = weights.reshape(-1)
        dest = ids // E_loc                             # target model shard
        order = jnp.argsort(dest)
        d_sorted = dest[order]
        pos, _ = _group_positions(d_sorted, M)
        keep = pos < cap
        pos_w = jnp.where(keep, pos, cap)               # OOB → dropped
        tok = order // top_k

        send = jnp.zeros((M, cap, D), x.dtype).at[d_sorted, pos_w].add(
            jnp.where(keep[:, None], xf[tok], 0).astype(x.dtype), mode="drop")
        send_eid = jnp.full((M, cap), E_loc, jnp.int32).at[
            d_sorted, pos_w].set(ids[order] % E_loc, mode="drop")
        send_src = jnp.full((M, cap), -1, jnp.int32).at[
            d_sorted, pos_w].set(order, mode="drop")

        # === all_to_all #1: tokens → their expert's shard ===
        recv = jax.lax.all_to_all(send, "model", 0, 0)
        recv_eid = jax.lax.all_to_all(send_eid, "model", 0, 0)
        re = recv.reshape(M * cap, D)
        re_id = recv_eid.reshape(M * cap)               # in [0, E_loc] (pad=E_loc)

        # local grouped GEMM over my E_loc experts
        cap2 = max(int(np.ceil(M * cap / max(E_loc, 1))), 1)
        order2 = jnp.argsort(re_id)
        id2 = re_id[order2]
        pos2, _ = _group_positions(id2, E_loc + 1)
        keep2 = (id2 < E_loc) & (pos2 < cap2)
        pos2_w = jnp.where(keep2, pos2, cap2)
        buf = jnp.zeros((E_loc, cap2, D), x.dtype).at[
            jnp.where(keep2, id2, 0), pos2_w].add(
            jnp.where(keep2[:, None], re[order2], 0).astype(x.dtype),
            mode="drop")
        ob = _expert_mlp(buf, w_in, w_gate, w_out, act)

        # un-permute locally; all_to_all #2: results → token owners
        gathered = ob[jnp.where(keep2, id2, 0), jnp.where(keep2, pos2, 0)]
        out_rows = jnp.zeros((M * cap, D), x.dtype).at[order2].add(
            jnp.where(keep2[:, None], gathered, 0))
        back = jax.lax.all_to_all(out_rows.reshape(M, cap, D), "model", 0, 0)

        # back[m, c] is the result for my original send[m, c]
        flat_back = back.reshape(M * cap, D)
        src = send_src.reshape(M * cap)                 # flat (token·k) slots
        valid = src >= 0
        src_c = jnp.clip(src, 0, Nk - 1)
        w_g = jnp.where(valid, w_flat[src_c], 0.0)
        contrib = jnp.where(valid[:, None], flat_back, 0) \
            * w_g[:, None].astype(x.dtype)
        out = jax.ops.segment_sum(
            contrib, jnp.where(valid, src_c // top_k, N_loc),
            num_segments=N_loc + 1)[:N_loc]
        return out.reshape(xb.shape), aux

    espec = P("model", None, None)
    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(None, None), espec, espec, espec,
                             P(dp, "model", None)),
                   out_specs=(P(dp, "model", None), P()),
                   **_SHARD_MAP_NO_CHECK)
    return fn(params["router"], params["w_in"], params["w_gate"],
              params["w_out"], x)


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------

def moe_ffn(params, x, *, top_k: int, capacity_factor: float = 1.25,
            act: str = "swiglu"):
    """Routes to the expert-parallel path when a mesh with a usable 'model'
    axis is bound and shapes divide; otherwise the local path (single-device
    tests, decode steps with S=1 where the token count is trivial)."""
    from repro.distributed import sharding as shd
    mesh = shd.current_mesh()
    B, S, D = x.shape
    if mesh is not None and "model" in mesh.axis_names:
        M = mesh.shape["model"]
        dpn = 1
        for a in shd.batch_axes(mesh):
            dpn *= mesh.shape[a]
        E = params["router"].shape[1]
        if M > 1 and E % M == 0 and S % M == 0 and B % max(dpn, 1) == 0:
            return moe_ffn_sharded(params, x, top_k=top_k,
                                   capacity_factor=capacity_factor,
                                   act=act, mesh=mesh)
    return moe_ffn_local(params, x, top_k=top_k,
                         capacity_factor=capacity_factor, act=act)
