"""Serve-step factories: LM prefill / decode, recsys scoring / retrieval.
These are what decode_* / long_* / serve_* / retrieval_* dry-run cells lower.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import recsys as recsys_lib
from repro.models.transformer import LMConfig, prefill, decode_step


def make_prefill_step(cfg: LMConfig):
    def step(params, tokens):
        return prefill(params, tokens, cfg)
    return step


def make_decode_step(cfg: LMConfig):
    """One new token against an existing KV cache (decode_32k / long_500k)."""
    def step(params, cache, token, pos):
        return decode_step(params, cache, token, pos, cfg)
    return step


def make_recsys_score_step(cfg: recsys_lib.RecsysConfig):
    score = recsys_lib.SCORE[cfg.arch]
    def step(params, batch):
        return score(params, batch, cfg)
    return step


def make_recsys_retrieval_step(cfg: recsys_lib.RecsysConfig, top_k: int = 100):
    retr = recsys_lib.RETRIEVAL[cfg.arch]
    def step(params, batch):
        scores = retr(params, batch, cfg)
        return jax.lax.top_k(scores, top_k)
    return step


def greedy_generate(params, cfg: LMConfig, prompt, max_new: int, cache_len):
    """Host loop driving prefill + decode_step (examples/serving demo)."""
    from repro.models.transformer import init_kv_cache
    B, S = prompt.shape
    logits, pre_cache = prefill(params, prompt, cfg)
    cache = init_kv_cache(cfg, B, cache_len)
    cache = {k: cache[k].at[:, :, :S].set(v) for k, v in
             (("k", pre_cache["k"]), ("v", pre_cache["v"]))}
    out = [jnp.argmax(logits, -1).astype(jnp.int32)]
    for i in range(max_new - 1):
        logits, cache = decode_step(params, cache, out[-1],
                                    jnp.int32(S + i), cfg)
        out.append(jnp.argmax(logits, -1).astype(jnp.int32))
    return jnp.stack(out, axis=1)
