"""Fault-tolerant checkpointing.

- step-atomic: write to ``<dir>/tmp.<step>`` then os.rename → a crash mid-write
  never corrupts the latest checkpoint; the manifest is written last inside
  the tmp dir so a renamed dir is complete by construction.
- restore scans newest→oldest and skips damaged dirs.
- elastic: arrays are saved device-agnostic (numpy); ``restore`` re-device_puts
  with the *target* mesh's shardings, so a 2×4 checkpoint restores onto 4×2 or
  1×8 (tested in tests/test_checkpoint.py).
- async: ``save_async`` snapshots to host then writes on a worker thread.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import numpy as np
import jax


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree) -> str:
        host_tree = jax.tree.map(np.asarray, tree)
        return self._write(step, host_tree)

    def save_async(self, step: int, tree) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot before thread
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree) -> str:
        tmp = os.path.join(self.dir, f"tmp.{step}")
        final = os.path.join(self.dir, f"ckpt_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat, treedef = jax.tree_util.tree_flatten_with_path(host_tree)
        manifest = {"step": step, "leaves": []}
        for i, (keypath, leaf) in enumerate(flat):
            arr = np.asarray(leaf)
            np.save(os.path.join(tmp, f"arr_{i:05d}.npy"), arr)
            manifest["leaves"].append({
                "path": jax.tree_util.keystr(keypath),
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            })
        with open(os.path.join(tmp, "manifest.json"), "w") as fh:
            json.dump(manifest, fh)                 # manifest last = complete
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._prune()
        return final

    def _prune(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"ckpt_{s:08d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("ckpt_"):
                if os.path.exists(os.path.join(self.dir, name,
                                               "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: int | None = None, shardings=None):
        """template: pytree with the target structure.  shardings: matching
        pytree of jax.sharding.Sharding (or None → host arrays)."""
        steps = self.all_steps()
        if step is None:
            candidates = list(reversed(steps))
        else:
            candidates = [step]
        last_err: Exception | None = None
        for s in candidates:
            try:
                return self._read(template, s, shardings), s
            except Exception as e:          # corrupt → try older
                last_err = e
        raise FileNotFoundError(
            f"no restorable checkpoint in {self.dir}: {last_err}")

    def _read(self, template, step: int, shardings):
        d = os.path.join(self.dir, f"ckpt_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as fh:
            manifest = json.load(fh)
        flat_t, treedef = jax.tree_util.tree_flatten(template)
        if len(flat_t) != len(manifest["leaves"]):
            raise ValueError("checkpoint/template structure mismatch")
        arrs = []
        for i, (leaf, meta) in enumerate(zip(flat_t, manifest["leaves"])):
            arr = np.load(os.path.join(d, f"arr_{i:05d}.npy"))
            if list(arr.shape) != list(leaf.shape):
                raise ValueError(
                    f"shape mismatch at {meta['path']}: "
                    f"{arr.shape} vs {leaf.shape}")
            arrs.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, arrs)
        if shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings)
        return tree
