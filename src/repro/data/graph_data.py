"""Synthetic graphs + compressed CSR adjacency.

Graphs are power-law (Barabási–Albert-ish preferential attachment, vectorized)
to mimic Reddit/OGB degree skew.  CSR neighbor rows are sorted integer lists
and are stored with the paper's codec (``CompressedCSR``): block bit packing
over the concatenated, per-row-delta-coded adjacency — the paper's technique
as GNN substrate.  ``decompress`` restores exact CSR; equality is tested in
tests/test_graph_data.py.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import bitpack, codecs


def synthetic_graph(n_nodes: int, avg_degree: int, seed: int = 0,
                    d_feat: int = 32, n_classes: int = 8):
    """Returns dict with CSR (indptr, indices), edge list, features, labels."""
    rng = np.random.default_rng(seed)
    n_edges = n_nodes * avg_degree
    # power-law destination preference
    w = (1.0 / (np.arange(n_nodes) + 1.0)) ** 0.8
    w /= w.sum()
    src = rng.integers(0, n_nodes, size=n_edges)
    dst = rng.choice(n_nodes, size=n_edges, p=w)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    # symmetrize + dedup
    s = np.concatenate([src, dst])
    d = np.concatenate([dst, src])
    key = s.astype(np.int64) * n_nodes + d
    key = np.unique(key)
    src = (key // n_nodes).astype(np.int32)
    dst = (key % n_nodes).astype(np.int32)
    # CSR
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    indptr = np.zeros(n_nodes + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    indptr = np.cumsum(indptr)
    feats = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    # learnable signal: label = argmax of the first n_classes feature dims
    labels = np.argmax(feats[:, :n_classes], axis=1).astype(np.int32)
    return {"indptr": indptr.astype(np.int32), "indices": dst,
            "edge_src": src, "edge_dst": dst,
            "x": feats, "labels": labels,
            "train_mask": (rng.random(n_nodes) < 0.5)}


@dataclasses.dataclass
class CompressedCSR:
    """CSR adjacency with the neighbor array stored via the paper's codec.

    Rows are sorted; we concatenate rows and delta-code *within* rows by
    adding per-row offsets (row i's neighbors are coded in the stream as
    i * n_nodes + neighbor, making the concatenation globally sorted — a
    standard reduction of multi-row adjacency to one sorted list)."""
    indptr: np.ndarray
    packed: bitpack.PackedList
    n_nodes: int

    @classmethod
    def compress(cls, indptr, indices, n_nodes, codec: str = "bp-d1"):
        rows = np.repeat(np.arange(n_nodes, dtype=np.int64),
                         np.diff(indptr))
        stream = rows * n_nodes + indices.astype(np.int64)
        assert np.all(np.diff(stream) > 0), "CSR rows must be sorted/unique"
        return cls(indptr=np.asarray(indptr),
                   packed=bitpack.encode(stream, mode="d1"),
                   n_nodes=n_nodes)

    def decompress(self) -> np.ndarray:
        stream = bitpack.decode_np(self.packed)
        return (stream % self.n_nodes).astype(np.int32)

    def bits_per_edge(self) -> float:
        return bitpack.bits_per_int(self.packed)


def molecule_batch(rng: np.random.Generator, n_graphs: int, n_nodes: int,
                   n_edges: int, d_feat: int):
    x = rng.normal(size=(n_graphs, n_nodes, d_feat)).astype(np.float32)
    src = rng.integers(0, n_nodes, size=(n_graphs, n_edges)).astype(np.int32)
    dst = rng.integers(0, n_nodes, size=(n_graphs, n_edges)).astype(np.int32)
    node_mask = np.ones((n_graphs, n_nodes), dtype=np.float32)
    targets = rng.normal(size=(n_graphs,)).astype(np.float32)
    return {"x": x, "edge_src": src, "edge_dst": dst,
            "node_mask": node_mask, "targets": targets}
