"""ClusterData-style synthetic sorted lists (paper §6.5/§6.6, after Anh &
Moffat [1]): 'primarily small gaps between successive integers, punctuated by
occasional larger gaps'.

We model a two-level gap process: runs of small intra-cluster gaps separated
by large inter-cluster jumps sized so the list spans the requested universe.
The benchmark reports the measured delta entropy next to the paper's (3.9 bits
dense / 14.7 bits sparse for 2**16 ints in 2**19 / 2**30) so the distributions
are comparable.
"""

from __future__ import annotations

import numpy as np


def clusterdata(rng: np.random.Generator, n: int, universe_bits: int,
                cluster_size: int = 32, small_max: int | None = None
                ) -> np.ndarray:
    """n strictly-increasing ints in [0, 2**universe_bits).

    Within-cluster gaps are uniform in [1, U/n] (so the delta entropy tracks
    the universe density like Anh-Moffat's generator: ≈3.9 bits dense,
    ≈14.7 bits sparse at the paper's Table 3 shapes); occasional large
    inter-cluster jumps consume the remaining universe."""
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    U = 1 << universe_bits
    if n >= U:
        raise ValueError("universe too small")
    if small_max is None:
        small_max = max(int(U // n), 2)
    small = rng.integers(1, small_max + 1, size=n).astype(np.int64)
    n_clusters = max(n // cluster_size, 1)
    starts = rng.choice(n, size=n_clusters, replace=False) if n_clusters < n \
        else np.arange(n)
    budget = U - 1 - int(small.sum())
    if budget > 0 and n_clusters > 0:
        w = rng.random(n_clusters)
        w /= w.sum()
        big = np.floor(w * budget).astype(np.int64)
        gaps = small.copy()
        np.add.at(gaps, starts, big)
    else:
        gaps = small
    vals = np.cumsum(gaps) - 1
    if vals[-1] >= U:                      # numeric safety; rescale tail
        vals = (vals.astype(np.float64) * (U - 1) / vals[-1]).astype(np.int64)
        vals = np.unique(vals)
    return vals


def uniformdata(rng: np.random.Generator, n: int,
                universe_bits: int) -> np.ndarray:
    U = 1 << universe_bits
    return np.sort(rng.choice(U, size=n, replace=False)).astype(np.int64)


def delta_entropy(values: np.ndarray) -> float:
    """Shannon entropy of the deltas, bits/int (paper Tables 3/5 row)."""
    v = np.asarray(values, dtype=np.int64)
    if v.size < 2:
        return 0.0
    d = np.diff(v)
    _, counts = np.unique(d, return_counts=True)
    p = counts / counts.sum()
    return float(-(p * np.log2(p)).sum())


def paired_lists(rng: np.random.Generator, m: int, n: int,
                 universe_bits: int = 26) -> tuple[np.ndarray, np.ndarray]:
    """Paper §6.6 pair construction: an 'intersection' list of size m/3 is
    unioned into both a ~m short list and a ~n long list."""
    inter = clusterdata(rng, max(m // 3, 1), universe_bits)
    extra_r = clusterdata(rng, m - len(inter), universe_bits)
    extra_f = clusterdata(rng, max(n - len(inter), 1), universe_bits)
    r = np.union1d(inter, extra_r)
    f = np.union1d(inter, extra_f)
    return r.astype(np.int64), f.astype(np.int64)
