"""Synthetic recsys interaction data (Zipfian item popularity, per-user
category affinity so models have learnable signal).  User history item-id
lists, sorted-deduped, are stored compressed with the paper's codec in the
offline feature store (``compress_histories``)."""

from __future__ import annotations

import numpy as np

from repro.core import bitpack


def _zipf_items(rng, n, size):
    x = rng.zipf(1.2, size=size)
    return (x % n).astype(np.int32)


def din_batch(rng, cfg, batch: int):
    L = cfg.seq_len
    hist_items = _zipf_items(rng, cfg.n_items, (batch, L))
    hist_cates = (hist_items % cfg.n_cates).astype(np.int32)
    lens = rng.integers(5, L + 1, size=batch)
    mask = (np.arange(L)[None] < lens[:, None]).astype(np.float32)
    # positive targets share the user's dominant category half the time
    target_item = _zipf_items(rng, cfg.n_items, (batch,))
    labels = rng.random(batch) < 0.5
    dom = hist_items[:, 0]
    target_item = np.where(labels, dom, target_item).astype(np.int32)
    return {"hist_items": hist_items, "hist_cates": hist_cates,
            "hist_mask": mask, "target_item": target_item,
            "target_cate": (target_item % cfg.n_cates).astype(np.int32),
            "labels": labels.astype(np.int32)}


def seq_batch(rng, cfg, batch: int):
    """SASRec-style: hist, per-position next-item pos/neg."""
    L = cfg.seq_len
    hist = _zipf_items(rng, cfg.n_items, (batch, L))
    pos = np.roll(hist, -1, axis=1)
    neg = _zipf_items(rng, cfg.n_items, (batch, L))
    mask = np.ones((batch, L), dtype=np.float32)
    mask[:, -1] = 0
    return {"hist": hist, "pos": pos, "neg": neg, "hist_mask": mask,
            "target_item": hist[:, 0]}


def bert4rec_batch(rng, cfg, batch: int, n_masked: int = 8):
    L = cfg.seq_len
    hist = _zipf_items(rng, cfg.n_items, (batch, L))
    mask_pos = np.stack([rng.choice(L, size=n_masked, replace=False)
                         for _ in range(batch)]).astype(np.int32)
    true_ids = np.take_along_axis(hist, mask_pos, axis=1)
    hist_masked = hist.copy()
    np.put_along_axis(hist_masked, mask_pos,
                      np.int32(cfg.n_items + 1), axis=1)   # [MASK]
    negs = _zipf_items(rng, cfg.n_items, (batch, n_masked, cfg.n_neg))
    cands = np.concatenate([true_ids[..., None], negs], axis=-1)
    return {"hist": hist_masked, "hist_mask": np.ones((batch, L), np.float32),
            "mask_pos": mask_pos, "cands": cands,
            "mask_valid": np.ones((batch, n_masked), np.float32),
            "target_item": hist[:, 0]}


def mind_batch(rng, cfg, batch: int):
    L = cfg.seq_len
    hist = _zipf_items(rng, cfg.n_items, (batch, L))
    lens = rng.integers(5, L + 1, size=batch)
    mask = (np.arange(L)[None] < lens[:, None]).astype(np.float32)
    true_ids = hist[:, 0]
    negs = _zipf_items(rng, cfg.n_items, (batch, cfg.n_neg))
    cands = np.concatenate([true_ids[:, None], negs], axis=-1)
    return {"hist": hist, "hist_mask": mask, "cands": cands,
            "target_item": true_ids}


def retrieval_batch(rng, cfg, n_candidates: int):
    L = cfg.seq_len
    hist = _zipf_items(rng, cfg.n_items, (L,))
    cand = _zipf_items(rng, cfg.n_items, (n_candidates,))
    return {"hist": hist, "hist_mask": np.ones((L,), np.float32),
            "hist_items": hist,
            "hist_cates": (hist % cfg.n_cates).astype(np.int32),
            "cand_items": cand,
            "cand_cates": (cand % cfg.n_cates).astype(np.int32)}


def compress_histories(histories: list[np.ndarray]):
    """Feature-store compression of sorted-unique user histories (paper codec
    applied to recsys substrate).  Paper-faithful codec choice: lists shorter
    than one block go to Varint (the paper's tail codec — block packing pays
    ~block_size/n × padding overhead there); longer lists are bit-packed.
    Returns (list of (kind, payload), bits/int)."""
    from repro.core import varint
    packed = []
    total_bits = 0.0
    total_n = 0
    for h in histories:
        u = np.unique(h)
        if u.size < 1024:
            enc = varint.encode(u)
            packed.append(("varint", enc))
            total_bits += varint.bits_per_int(enc) * enc.n
            total_n += enc.n
        else:
            enc = bitpack.encode(u, mode="d1")
            packed.append(("bp", enc))
            total_bits += bitpack.bits_per_int(enc) * enc.n
            total_n += enc.n
    return packed, total_bits / max(total_n, 1)
