"""Synthetic LM token pipeline.

Deterministic, shardable token stream (Zipfian unigram mixture with Markov
bigram structure so tiny models have learnable signal).  The epoch shuffle
index map — a sorted-after-dedup integer list — is stored compressed with the
paper's codec (bp-d1): the technique applied to the data-pipeline substrate
(DESIGN.md §3).
"""

from __future__ import annotations

import numpy as np

from repro.core import bitpack


class TokenStream:
    def __init__(self, vocab: int, seed: int = 0, zipf_a: float = 1.3):
        self.vocab = vocab
        self.rng = np.random.default_rng(seed)
        # bigram structure: token t likely followed by (t*7+3) % vocab
        self.next_map = (np.arange(vocab) * 7 + 3) % vocab

    def batch(self, batch_size: int, seq_len: int):
        B, S = batch_size, seq_len
        toks = np.empty((B, S), dtype=np.int32)
        toks[:, 0] = self.rng.zipf(1.3, size=B) % self.vocab
        for s in range(1, S):
            follow = self.rng.random(B) < 0.7
            rand = self.rng.zipf(1.3, size=B) % self.vocab
            toks[:, s] = np.where(follow, self.next_map[toks[:, s - 1]], rand)
        labels = np.roll(toks, -1, axis=1).astype(np.int32)
        labels[:, -1] = -1
        return {"tokens": toks, "labels": labels}


def make_shuffle_index(n_samples: int, epoch: int, seed: int = 0):
    """Shuffled sample order; returns (order, compressed sorted unique ids).

    The compressed form is what a multi-host pipeline ships to workers
    (bp-d1-packed sorted ids — the paper's codec on the wire)."""
    rng = np.random.default_rng(seed + epoch)
    order = rng.permutation(n_samples)
    packed = bitpack.encode(np.sort(order), mode="d1")
    return order, packed
