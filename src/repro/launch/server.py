"""Continuous-batching index server (DESIGN.md §2.11).

PRs 1-5 made the *offline* batch path fast; this module puts an online
serving loop in front of it.  Requests arrive one at a time (an open-loop
generator models live traffic — Poisson, bursty, or a drain backlog), an
async batcher packs them into batches, and each batch rides the existing
launch/collect split (``batch.launch_groups`` / ``batch.collect_batch``,
or the sharded fan-out) — the same dispatch seam ``execute_pipelined``
uses, so ``--resident``, ``--fuse``, ``--warmup`` and ``--shards``
compose unchanged and results stay byte-identical to the offline path.

The loop's three policies:

  admission   arrivals pack greedily into the open batch; a flush is
              *family-aligned* when the sticky ``FusionPlan`` ceilings
              already cover every scheduled group (``batch.plan_covers``,
              checked before fusion raises ceilings) — after warmup every
              flush should be aligned, which is exactly the property that
              makes steady state compile-free.
  flush       whichever fires first of max_batch (the batch is full) and
              max_wait (the oldest queued request has waited long enough);
              drain mode (a pre-submitted backlog) flushes only full
              batches so chunk boundaries are deterministic.
  backpressure  the arrival queue is bounded; open-loop arrivals that find
              it full are shed (counted, never silently dropped), and at
              most ``depth`` launched batches may be awaiting collection
              (the same double-buffering bound as the pipelined executor).

Every request records time-in-queue and end-to-end latency; ``ServerMetrics``
reports p50/p99/p999, queue-depth histogram, shed count and measured q/s.

Resilience (DESIGN.md §2.15): every request resolves — exactly one of
done / shed / timeout / error, never a hung awaiter.  Per-request
deadlines (``timeout_ms``) expire queued requests at flush assembly;
transient faults from the schedule/launch seam retry with bounded
exponential backoff; repeated failures trip a circuit-breaker
*degradation ladder* that steps fused→unfused and pallas→jax (every rung
still byte-identical to the sequential oracle — that is the point of the
differential contract) and re-promotes one rung per quiet cool-down.
``launch.faults`` injects faults at the ``launch``/``collect`` seams for
tests and ``--chaos``.

  PYTHONPATH=src python -m repro.launch.server --queries 256 --qps 500
  PYTHONPATH=src python -m repro.launch.server --queries 256 --qps 0 \\
      --warmup --check            # drain mode + offline differential
"""

from __future__ import annotations

import argparse
import asyncio
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field

import numpy as np

from repro.index import batch as batch_lib
from repro.launch import faults as faults_lib


_STOP = object()


# --------------------------------------------------------------------------
# requests + metrics
# --------------------------------------------------------------------------

@dataclass
class Request:
    """One in-flight query: terms plus the three timestamps the latency
    report is built from (arrive -> admit -> done).  ``outcome`` is the
    resolution contract: every admitted request ends in exactly one of
    ``done`` / ``timeout`` / ``error`` with its ``done`` event set (shed
    arrivals never become a Request at all)."""
    rid: int
    terms: list
    t_arrive: float
    t_admit: float = 0.0
    t_done: float = 0.0
    result: object = None
    outcome: str = "pending"
    done: asyncio.Event = field(default_factory=asyncio.Event)

    @property
    def wait_s(self) -> float:
        return self.t_admit - self.t_arrive

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_arrive


def _pctl(xs: list, q: float) -> float:
    if not xs:
        return 0.0
    return float(np.percentile(np.asarray(xs, dtype=np.float64), q))


class ServerMetrics:
    """Latency + queue accounting for one serving run.

    Latency percentiles are per-request end-to-end (arrival to collected
    result), time-in-queue is arrival to admission, and the queue-depth
    histogram buckets the depth each arrival observed into powers of two
    — the shape of that histogram (mass at 0-1 vs a fat tail) is the
    difference between a server keeping up and one melting down that a
    single mean would hide."""

    def __init__(self):
        self.latency_s: list[float] = []
        self.wait_s: list[float] = []
        self.depth_hist: dict[int, int] = {}
        self.n_shed = 0
        self.n_done = 0
        self.n_flushes = 0
        self.flush_full = 0
        self.flush_deadline = 0
        self.flush_drain = 0
        self.aligned_flushes = 0
        self.unaligned_flushes = 0
        self.n_timeout = 0          # expired per-request deadlines
        self.n_errors = 0           # requests resolved by a failed flush
        self.n_faults = 0           # faults observed at the dispatch seams
        self.n_retries = 0          # transient-fault retry attempts
        self.degraded_flushes = 0   # flushes served below the top rung
        self.t_first: float | None = None
        self.t_last: float | None = None

    def observe_depth(self, depth: int):
        b = 0 if depth <= 0 else 1 << (depth - 1).bit_length()
        self.depth_hist[b] = self.depth_hist.get(b, 0) + 1

    def record(self, req: Request):
        self.n_done += 1
        self.latency_s.append(req.latency_s)
        self.wait_s.append(req.wait_s)
        if self.t_first is None or req.t_arrive < self.t_first:
            self.t_first = req.t_arrive
        if self.t_last is None or req.t_done > self.t_last:
            self.t_last = req.t_done

    def summary(self) -> dict:
        span = ((self.t_last - self.t_first)
                if (self.t_first is not None and self.t_last is not None)
                else 0.0)
        return {
            "n_done": self.n_done,
            "n_shed": self.n_shed,
            "qps": self.n_done / span if span > 0 else 0.0,
            "p50_ms": _pctl(self.latency_s, 50) * 1e3,
            "p99_ms": _pctl(self.latency_s, 99) * 1e3,
            "p999_ms": _pctl(self.latency_s, 99.9) * 1e3,
            "mean_ms": (float(np.mean(self.latency_s)) * 1e3
                        if self.latency_s else 0.0),
            "wait_p50_ms": _pctl(self.wait_s, 50) * 1e3,
            "wait_p99_ms": _pctl(self.wait_s, 99) * 1e3,
            "queue_depth_hist": {str(k): self.depth_hist[k]
                                 for k in sorted(self.depth_hist)},
            "n_flushes": self.n_flushes,
            "flush_full": self.flush_full,
            "flush_deadline": self.flush_deadline,
            "flush_drain": self.flush_drain,
            "aligned_flushes": self.aligned_flushes,
            "unaligned_flushes": self.unaligned_flushes,
            "n_timeout": self.n_timeout,
            "n_errors": self.n_errors,
            "n_faults": self.n_faults,
            "n_retries": self.n_retries,
            "degraded_flushes": self.degraded_flushes,
        }


# --------------------------------------------------------------------------
# the degradation ladder (circuit breaker)
# --------------------------------------------------------------------------

class DegradationLadder:
    """Circuit-breaker over execution modes, cheapest-to-degrade first.

    The rungs are built from the configured (backend, fuse): fused→unfused
    first (drops the megagroup programs but keeps the backend), then
    pallas→jax (drops the kernel path entirely).  Every rung is one of the
    differentially-verified execution modes, so degraded answers remain
    byte-identical to the sequential oracle — the ladder trades
    *performance* for survival, never correctness.

    State machine: ``threshold`` consecutive flush failures step one rung
    down (streak resets); any failure re-arms the cool-down; the first
    success after a full quiet ``cooldown_s`` steps one rung back up (one
    promotion per cool-down, so a flapping fault cannot oscillate at full
    rate).  ``clock`` is injectable for deterministic tests."""

    def __init__(self, backend: str = "jax", fuse: bool = True, *,
                 threshold: int = 3, cooldown_s: float = 0.5,
                 clock=time.monotonic):
        levels = [(backend, fuse)]
        if fuse:
            levels.append((backend, False))
        if backend == "pallas":
            levels.append(("jax", False))
        self.levels = levels
        self.level = 0
        self.threshold = max(threshold, 1)
        self.cooldown_s = cooldown_s
        self.clock = clock
        self.fail_streak = 0
        self.n_degradations = 0
        self.n_promotions = 0
        self._quiet_at = clock()       # earliest instant a promotion may fire

    @property
    def current(self) -> tuple[str, bool]:
        return self.levels[self.level]

    @property
    def degraded(self) -> bool:
        return self.level > 0

    def on_failure(self) -> bool:
        """Record one failed flush; True if this tripped a degradation."""
        self.fail_streak += 1
        self._quiet_at = self.clock() + self.cooldown_s
        if (self.fail_streak >= self.threshold
                and self.level < len(self.levels) - 1):
            self.level += 1
            self.fail_streak = 0
            self.n_degradations += 1
            return True
        return False

    def on_success(self) -> bool:
        """Record one successful flush; True if this re-promoted a rung."""
        self.fail_streak = 0
        if self.level > 0 and self.clock() >= self._quiet_at:
            self.level -= 1
            self.n_promotions += 1
            self._quiet_at = self.clock() + self.cooldown_s
            return True
        return False


# --------------------------------------------------------------------------
# arrival processes (open loop: the generator never waits for results)
# --------------------------------------------------------------------------

def arrival_gaps(n: int, qps: float, pattern: str = "poisson",
                 seed: int = 0, burst: int = 8) -> list[float]:
    """Inter-arrival gaps (seconds) for ``n`` requests at offered load
    ``qps``.  ``qps <= 0`` means a drain backlog: everything arrives at
    t=0.  ``poisson`` is the memoryless baseline; ``bursty`` keeps the
    same mean rate but releases requests in bursts of ``burst`` (the
    queue-depth tail a Poisson sweep understates); ``uniform`` is the
    deterministic floor."""
    if n <= 0:
        return []
    if qps is None or qps <= 0:
        return [0.0] * n
    rng = np.random.default_rng(seed)
    if pattern == "poisson":
        return [float(g) for g in rng.exponential(1.0 / qps, n)]
    if pattern == "uniform":
        return [1.0 / qps] * n
    if pattern == "bursty":
        gaps = []
        for i in range(n):
            if i % burst == 0:
                gaps.append(float(rng.exponential(burst / qps)))
            else:
                gaps.append(0.0)
        return gaps
    raise ValueError(f"unknown arrival pattern {pattern!r}")


# --------------------------------------------------------------------------
# the server
# --------------------------------------------------------------------------

class ContinuousBatchingServer:
    """Async continuous-batching loop over the batched engine.

    Scheduling (group assembly, fusion, program launch) happens on the
    event-loop thread in flush order — the byte-identity invariant
    (DESIGN.md §2.6) requires shared-state mutations (pool staging, plan
    ceilings, layout memos) to occur in schedule order, and a single
    thread makes that order the flush order by construction.  Collection
    (blocking on device results) runs on a one-worker executor so the
    loop keeps batching while the device works; one worker keeps collects
    in launch order.  At most ``depth`` launched batches may be awaiting
    collection (the pipelined executor's double-buffering bound).

    ``sharded`` (a ``shard.ShardedIndex``) swaps the launch seam for the
    SPMD fan-out — everything else, including byte-identity, is
    unchanged.

    ``mutable`` (a ``segments.MutableIndex``) serves a *live* corpus:
    every flush snapshots the current generation + mutable-segment prefix
    lock-free, launches against that snapshot, and completes at collect
    with tombstone filtering + the decoded-path mutable hits
    (``MutableIndex.finalize``).  The server shares the mutable index's
    sticky plan, so a background generation swap pre-warmed through it
    keeps steady state at 0 compiles."""

    def __init__(self, index=None, *, backend: str = "jax",
                 max_batch: int = 32,
                 max_wait_ms: float = 2.0, max_queue: int = 256,
                 depth: int = 2, max_results: int = 1 << 16,
                 max_group_size: int = batch_lib.MAX_GROUP_SIZE,
                 cache=None, pool=None, fuse: bool = True, plan=None,
                 sharded=None, mutable=None, drain: bool = False,
                 stats: dict | None = None,
                 metrics: ServerMetrics | None = None,
                 timeout_ms: float | None = None,
                 injector: "faults_lib.FaultInjector | None" = None,
                 max_retries: int = 3, retry_backoff_ms: float = 5.0,
                 breaker_threshold: int = 3, cooldown_ms: float = 500.0,
                 clock=time.monotonic):
        assert max_batch >= 1 and depth >= 1 and max_queue >= 1
        self.index = index
        self.backend = backend
        self.max_batch = max_batch
        self.max_wait_s = max_wait_ms * 1e-3
        self.max_queue = max_queue
        self.depth = depth
        self.max_results = max_results
        self.max_group_size = max_group_size
        self.cache = cache
        self.pool = pool
        self.fuse = fuse
        self.mutable = mutable
        if plan is not None:
            self.plan = plan
        elif mutable is not None:
            self.plan = mutable.plan       # share the sticky plan: merges
        else:                              # pre-warm through it pre-swap
            self.plan = batch_lib.FusionPlan() if fuse else None
        self.sharded = sharded
        self.drain = drain
        self.stats: dict = {} if stats is None else stats
        self.metrics = metrics if metrics is not None else ServerMetrics()
        self.timeout_s = timeout_ms * 1e-3 if timeout_ms else None
        self.injector = injector
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_ms * 1e-3
        self.ladder = DegradationLadder(backend, fuse,
                                        threshold=breaker_threshold,
                                        cooldown_s=cooldown_ms * 1e-3,
                                        clock=clock)
        self.requests: list[Request | None] = []
        self._next_rid = 0
        self._queue: asyncio.Queue | None = None

    # -- the dispatch seam (mirrors execute_pipelined's default hooks) -----

    def _snapshot(self):
        """One lock-free state grab per flush (``None`` on frozen indexes);
        schedule/launch/finalize of that flush all serve this snapshot, so
        a concurrent generation swap never splits a batch."""
        return self.mutable.snapshot() if self.mutable is not None else None

    def _schedule(self, chunk, stats, account: bool = True, snap=None,
                  fuse: bool | None = None):
        if fuse is None:
            fuse = self.fuse
        if snap is not None:
            groups = self.mutable.schedule(snap, chunk, stats=stats,
                                           cache=self.cache)
        elif self.sharded is not None:
            groups = batch_lib.schedule(self.sharded.index, chunk,
                                        pool=self.sharded.pool_map,
                                        stats=stats)
        else:
            groups = batch_lib.schedule(self.index, chunk, cache=self.cache,
                                        stats=stats, pool=self.pool)
        if fuse:
            # family-signature admission accounting: does the sticky plan
            # already cover this flush?  Must be read *before* fuse_groups
            # raises ceilings (which would make coverage trivially true).
            if account:
                if batch_lib.plan_covers(groups, self.plan):
                    self.metrics.aligned_flushes += 1
                else:
                    self.metrics.unaligned_flushes += 1
            groups = batch_lib.fuse_groups(groups, plan=self.plan,
                                           stats=stats)
        return groups

    def _launch(self, groups, n_queries, stats, snap=None,
                backend: str | None = None):
        if backend is None:
            backend = self.backend
        if snap is not None:
            return self.mutable.launch(
                snap, groups, n_queries, backend=backend,
                max_results=self.max_results,
                max_group_size=self.max_group_size, stats=stats)
        if self.sharded is not None:
            from repro.index import shard as shard_lib
            return shard_lib.launch_groups_sharded(
                self.sharded, groups, n_queries=n_queries,
                backend=backend, max_results=self.max_results,
                max_group_size=self.max_group_size, stats=stats)
        return batch_lib.launch_groups(
            groups, n_queries=n_queries, backend=backend,
            max_results=self.max_results,
            max_group_size=self.max_group_size, pool=self.pool,
            stats=stats)

    # -- admission ---------------------------------------------------------

    def _new_request(self, terms) -> Request:
        req = Request(rid=self._next_rid, terms=list(terms),
                      t_arrive=time.perf_counter())
        self._next_rid += 1
        return req

    def submit_nowait(self, terms) -> Request | None:
        """Open-loop admission: enqueue or shed (bounded queue, never
        blocks the arrival process)."""
        self.metrics.observe_depth(self._queue.qsize())
        if self._queue.full():
            self.metrics.n_shed += 1
            return None
        req = self._new_request(terms)
        self._queue.put_nowait(req)
        return req

    async def submit(self, terms) -> Request:
        """Closed-loop admission: block until the queue has room (drain
        mode — a backlog that waits instead of shedding)."""
        self.metrics.observe_depth(self._queue.qsize())
        req = self._new_request(terms)
        await self._queue.put(req)
        return req

    # -- the batching loop -------------------------------------------------

    async def _batcher(self, finishers: list):
        loop = asyncio.get_running_loop()
        sem = asyncio.Semaphore(self.depth)
        collector = ThreadPoolExecutor(max_workers=1)
        try:
            stopping = False
            while not stopping:
                first = await self._queue.get()
                if first is _STOP:
                    break
                batch = [first]
                reason = "full"
                deadline = loop.time() + self.max_wait_s
                while len(batch) < self.max_batch:
                    try:
                        nxt = self._queue.get_nowait()
                    except asyncio.QueueEmpty:
                        if self.drain:
                            # backlog mode: only full batches (deterministic
                            # chunk boundaries) — wait for the next arrival
                            # or the end of the stream
                            nxt = await self._queue.get()
                        else:
                            left = deadline - loop.time()
                            if left <= 0:
                                reason = "deadline"
                                break
                            try:
                                nxt = await asyncio.wait_for(
                                    self._queue.get(), left)
                            except asyncio.TimeoutError:
                                reason = "deadline"
                                break
                    if nxt is _STOP:
                        stopping = True
                        reason = "drain"
                        break
                    batch.append(nxt)
                await self._flush(batch, reason, loop, sem, collector,
                                  finishers)
            # bound in-flight work before the run tears the executor down
            for _ in range(self.depth):
                await sem.acquire()
        finally:
            collector.shutdown(wait=True)

    def _resolve_error(self, reqs: list[Request]):
        """A failed flush must still resolve every request it carried:
        result None, outcome ``error``, done event set.  No fault may
        leave an awaiter hanging — that is the resolution contract."""
        now = time.perf_counter()
        for r in reqs:
            r.result = None
            r.t_done = now
            r.outcome = "error"
            self.metrics.n_errors += 1
            r.done.set()

    async def _flush(self, reqs: list[Request], reason: str, loop, sem,
                     collector, finishers: list):
        await sem.acquire()             # at most `depth` awaiting collection
        m = self.metrics
        now = time.perf_counter()
        if self.timeout_s is not None:
            # per-request deadlines, enforced at flush assembly: a request
            # that already waited out its budget in the queue resolves as
            # an explicit timeout instead of burning a launch slot
            live = []
            for r in reqs:
                if now - r.t_arrive > self.timeout_s:
                    r.t_admit = r.t_done = now
                    r.outcome = "timeout"
                    m.n_timeout += 1
                    r.done.set()
                else:
                    live.append(r)
            reqs = live
            if not reqs:
                sem.release()
                return
        for r in reqs:
            r.t_admit = now
        m.n_flushes += 1
        if reason == "full":
            m.flush_full += 1
        elif reason == "deadline":
            m.flush_deadline += 1
        else:
            m.flush_drain += 1

        backend, fuse = self.ladder.current
        if self.ladder.degraded:
            m.degraded_flushes += 1
        attempt = 0
        account = True
        while True:
            try:
                if self.injector is not None:
                    self.injector.fire("launch")
                snap = self._snapshot()
                groups = self._schedule([r.terms for r in reqs], self.stats,
                                        account=account, snap=snap,
                                        fuse=fuse)
                pending = self._launch(groups, len(reqs), self.stats,
                                       snap=snap, backend=backend)
                break
            except faults_lib.TransientFault:
                # bounded retry with exponential backoff; repeated
                # transients also feed the breaker, so a persistent
                # "transient" eventually serves from a lower rung
                m.n_faults += 1
                account = False
                self.ladder.on_failure()
                if attempt >= self.max_retries:
                    self._resolve_error(reqs)
                    sem.release()
                    return
                attempt += 1
                m.n_retries += 1
                await asyncio.sleep(
                    self.retry_backoff_s * (2 ** (attempt - 1)))
                backend, fuse = self.ladder.current
            except Exception:
                # non-retryable: resolve the batch as errors, trip the
                # breaker, keep the serving loop alive
                m.n_faults += 1
                self.ladder.on_failure()
                self._resolve_error(reqs)
                sem.release()
                return

        def collect():
            if self.injector is not None:
                self.injector.fire("collect")
            results = batch_lib.collect_batch(pending)
            if snap is not None:
                results = self.mutable.finalize(
                    snap, [r.terms for r in reqs], results,
                    self.max_results)
            done = time.perf_counter()
            for r, res in zip(reqs, results):
                r.result = res
                r.t_done = done
            return reqs

        fut = loop.run_in_executor(collector, collect)

        async def finish():
            err = None
            try:
                await fut
            except Exception as e:      # noqa: BLE001 — resolved below
                err = e
            finally:
                sem.release()
            if err is not None:
                m.n_faults += 1
                self.ladder.on_failure()
                self._resolve_error(reqs)
                return
            self.ladder.on_success()
            for r in reqs:
                r.outcome = "done"
                m.record(r)
                r.done.set()

        finishers.append(asyncio.ensure_future(finish()))

    # -- one full open-loop run --------------------------------------------

    async def run(self, queries: list[list[int]],
                  gaps: list[float] | None = None) -> list:
        """Feed ``queries`` through the server with the given inter-arrival
        gaps (``None`` = drain backlog) and return per-query results in
        submission order (``None`` for shed requests)."""
        if gaps is None:
            gaps = [0.0] * len(queries)
        self._queue = asyncio.Queue(maxsize=self.max_queue)
        finishers: list = []
        batcher = asyncio.ensure_future(self._batcher(finishers))
        reqs: list[Request | None] = []
        for terms, gap in zip(queries, gaps):
            if gap > 0:
                await asyncio.sleep(gap)
            if self.drain:
                reqs.append(await self.submit(terms))
            else:
                reqs.append(self.submit_nowait(terms))
        await self._queue.put(_STOP)
        await batcher
        if finishers:
            await asyncio.gather(*finishers)
        self.requests = reqs
        return [r.result if r is not None else None for r in reqs]

    def outcomes(self) -> list[str]:
        """Per-request resolution of the last ``run``, submission order:
        ``shed`` / ``done`` / ``timeout`` / ``error`` — auditing that no
        request ever went unresolved is one list comprehension."""
        return ["shed" if r is None else r.outcome for r in self.requests]


def warm_server(server: ContinuousBatchingServer,
                queries: list[list[int]] | None = None,
                seed: int = 0) -> dict:
    """AOT-warm the server's sticky plan / pool through its *own* dispatch
    seam (same schedule/launch hooks the live loop uses), repeated to the
    signature fixed point — after this every flush whose groups the plan
    covers compiles nothing.

    Unlike the offline ``batch.warmup`` this also walks the batch-dim
    (Bp) bucket ladder: deadline flushes under live load are *variable
    sized* (1..max_batch), and every ladder bucket a flush lands in is a
    distinct program signature — an offline warm at one fixed batch size
    leaves all the smaller buckets cold, which is exactly the hidden
    compile tail a p99 report would eat.  Returns the same dict shape as
    ``batch.warmup`` (n_compiles / n_signatures / passes / converged /
    time_s)."""
    t0 = time.perf_counter()
    c0 = batch_lib._compile_count()
    if queries is None:
        if server.mutable is not None:
            view = server.mutable.snapshot().gen.view
        elif server.sharded is not None:
            view = server.sharded.index
        else:
            view = server.index
        queries = batch_lib.synth_warmup_queries(
            view, 2 * server.max_batch, seed=seed)

    # every ×1.5-ladder bucket a 1..max_batch flush can land in
    sizes, b = [], 1
    while b < batch_lib._bucket_rows(server.max_batch):
        sizes.append(b)
        b = b * 3 // 2 if b >= 2 else b + 1
    sizes.append(server.max_batch)

    def one_pass(stats):
        for size in sizes:
            for lo in range(0, len(queries), size):
                chunk = queries[lo: lo + size]
                snap = server._snapshot()
                groups = server._schedule(chunk, stats, account=False,
                                          snap=snap)
                pending = server._launch(groups, len(chunk), stats,
                                         snap=snap)
                batch_lib.collect_batch(pending)

    n_signatures, passes, converged = batch_lib.warm_to_fixed_point(one_pass)
    return {"n_compiles": batch_lib._compile_count() - c0,
            "n_signatures": n_signatures,
            "passes": passes,
            "converged": converged,
            "time_s": time.perf_counter() - t0}


def serve_open_loop(index, queries, *, qps: float = 0.0,
                    pattern: str = "poisson", seed: int = 0,
                    warmup: bool = False, **server_kw):
    """Synchronous one-call wrapper: build a server, optionally AOT-warm
    it, and push ``queries`` through at offered load ``qps`` (``0`` =
    drain backlog).  Returns ``(results, server)`` — results in
    submission order (``None`` where shed), the server exposing
    ``.metrics`` / ``.stats`` / the warmup report at ``.warm_report``."""
    drain = qps is None or qps <= 0
    server = ContinuousBatchingServer(index, drain=drain, **server_kw)
    # the query stream is its own most representative warmup sample
    # (serve.py uses the same rationale for the offline path)
    server.warm_report = (warm_server(server, queries, seed=seed)
                          if warmup else None)
    gaps = arrival_gaps(len(queries), qps, pattern, seed=seed)
    results = asyncio.run(server.run(queries, gaps))
    return results, server


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def main(argv=None):
    ap = argparse.ArgumentParser(
        description="open-loop continuous-batching server over the "
                    "paper-index engine")
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--qps", type=float, default=0.0,
                    help="offered load (requests/s); 0 = drain backlog "
                         "(everything arrives at t=0, full batches only)")
    ap.add_argument("--pattern", choices=["poisson", "bursty", "uniform"],
                    default="poisson")
    ap.add_argument("--batch", type=int, default=32,
                    help="max batch per flush")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="deadline flush: max time the oldest queued "
                         "request waits before a partial batch launches")
    ap.add_argument("--max-queue", type=int, default=256,
                    help="bounded arrival queue; open-loop arrivals that "
                         "find it full are shed")
    ap.add_argument("--depth", type=int, default=2,
                    help="max launched batches awaiting collection")
    ap.add_argument("--backend", choices=["jax", "pallas"], default="jax")
    ap.add_argument("--fuse", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--warmup", action="store_true",
                    help="AOT-warm the fused family ladder through the "
                         "server's own dispatch seam before serving")
    ap.add_argument("--resident", action="store_true",
                    help="stage the device-resident index before serving")
    ap.add_argument("--shards", type=int, default=0,
                    help="serve against an N-shard SPMD fan-out index")
    ap.add_argument("--check", action="store_true",
                    help="differential: compare every served result "
                         "against offline execute_batch")
    ap.add_argument("--timeout-ms", type=float, default=None,
                    help="per-request deadline: a request still queued "
                         "after this long resolves as an explicit timeout")
    ap.add_argument("--chaos", type=str, default=None,
                    help="fault-injection spec, e.g. "
                         "'transient@launch:0.01,delay@launch:2' "
                         "(see launch/faults.py)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shared-vocab", action="store_true")
    args = ap.parse_args(argv)

    from repro.index import builder, corpus as corpus_lib, source
    corpus = corpus_lib.synthesize(n_docs=1 << 16, n_queries=args.queries,
                                   seed=5, shared_vocab=args.shared_vocab)
    injector = (faults_lib.FaultInjector(args.chaos, seed=args.seed)
                if args.chaos else None)
    kw = dict(backend=args.backend, max_batch=args.batch,
              max_wait_ms=args.max_wait_ms, max_queue=args.max_queue,
              depth=args.depth, fuse=args.fuse,
              timeout_ms=args.timeout_ms, injector=injector)
    if args.shards:
        sharded = builder.build_sharded(
            corpus.postings, corpus.n_docs, n_shards=args.shards,
            codec_name="fastpfor-d1", B=16, n_parts=max(args.shards, 2))
        idx = sharded.index
        kw["sharded"] = sharded
    else:
        idx = builder.build(corpus.postings, corpus.n_docs,
                            codec_name="fastpfor-d1", B=16, n_parts=2)
        if args.resident:
            pool = source.ResidentPool()
            pool.warm(idx)
            kw["pool"] = pool
    results, server = serve_open_loop(idx, corpus.queries, qps=args.qps,
                                      pattern=args.pattern, seed=args.seed,
                                      warmup=args.warmup, **kw)
    if server.warm_report is not None:
        wu = server.warm_report
        print(f"[server] warmup: {wu['n_compiles']} compiles over "
              f"{wu['n_signatures']} signatures in {wu['passes']} passes "
              f"({wu['time_s']:.2f}s)")
        if not wu["converged"]:
            print("[server] warning: warmup stopped at max_passes before "
                  "the signature ladder converged — serving may compile")
    s = server.metrics.summary()
    mode = (f"--shards {args.shards}" if args.shards
            else ("--resident" if args.resident else "cold"))
    load = (f"qps {args.qps:g} ({args.pattern})" if args.qps > 0
            else "drain backlog")
    print(f"[server] paper-index {mode} ({args.backend}"
          f"{', fused' if args.fuse else ', unfused'}, batch {args.batch}, "
          f"wait {args.max_wait_ms:g} ms, {load}): "
          f"{s['n_done']} done / {s['n_shed']} shed, "
          f"{s['qps']:.1f} q/s, latency p50 {s['p50_ms']:.2f} ms / "
          f"p99 {s['p99_ms']:.2f} ms / p99.9 {s['p999_ms']:.2f} ms, "
          f"queue wait p99 {s['wait_p99_ms']:.2f} ms, "
          f"{s['n_flushes']} flushes "
          f"(full {s['flush_full']}, deadline {s['flush_deadline']}, "
          f"drain {s['flush_drain']}; "
          f"{s['aligned_flushes']} family-aligned), "
          f"{server.stats.get('n_dispatches', 0)} dispatches, "
          f"{server.stats.get('n_compiles', 0)} compiles")
    print(f"[server]   queue depth histogram (pow2 buckets): "
          f"{s['queue_depth_hist']}")
    lad = server.ladder
    if (s["n_timeout"] or s["n_errors"] or s["n_faults"]
            or lad.n_degradations or injector is not None):
        print(f"[server]   resilience: {s['n_timeout']} timed out, "
              f"{s['n_errors']} errored, {s['n_faults']} faults seen, "
              f"{s['n_retries']} retries, "
              f"{s['degraded_flushes']} degraded flushes "
              f"({lad.n_degradations} degradations / "
              f"{lad.n_promotions} promotions, final rung "
              f"{lad.current[0]}{'+fuse' if lad.current[1] else ''})")
        if injector is not None:
            print(f"[server]   chaos fired: {injector.counts()}")
    if args.check:
        served = [(q, r) for q, r in zip(corpus.queries, results)
                  if r is not None]
        offline = batch_lib.execute_batch(
            idx if not args.shards else sharded.index,
            [q for q, _ in served], backend=args.backend, fuse=args.fuse)
        for (q, got), want in zip(served, offline):
            assert got.count == want.count and \
                np.array_equal(got.docs, want.docs), f"mismatch on {q}"
        print(f"[server] differential check: {len(served)} served results "
              f"byte-identical to offline execute_batch")
    return results, server


if __name__ == "__main__":
    main()
