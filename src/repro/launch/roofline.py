"""Roofline analysis from compiled dry-run artifacts (EXPERIMENTS §Roofline).

  compute    = HLO_FLOPs   / (chips × peak_FLOP/s)
  memory     = HLO_bytes   / (chips × HBM_bw)
  collective = coll_bytes  / (chips × link_bw)

HLO_FLOPs / HLO_bytes from compiled.cost_analysis(); collective bytes from
parsing the compiled HLO text (operand sizes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute).

TPU v5e constants: 197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s/link ICI.
"""

from __future__ import annotations

import json
import os
import re

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
ICI_BW = 50e9              # B/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'f32[16,128]' → bytes; tuple shapes handled by caller."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_MATERIALIZING_OPS = {
    "dot", "convolution", "gather", "scatter", "scatter-add", "sort",
    "fusion", "concatenate", "dynamic-update-slice", "pad",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "custom-call", "copy", "rng-bit-generator",
}


def materialized_bytes(hlo_text: str) -> int:
    """Sum of result-buffer bytes over instructions that genuinely
    materialize on TPU (dots, gathers/scatters, fusion outputs, collectives,
    layout copies) — the TPU-fusion-aware memory-traffic proxy.

    Rationale (EXPERIMENTS §Roofline methodology): XLA:CPU's 'bytes accessed'
    counts every unfused operand touch and overstates TPU HBM traffic by
    5–50×; on TPU, elementwise/reduce chains fuse into their producers and
    consumers and never round-trip HBM, so only the whitelist above hits
    memory.  Elementwise-only segments are charged via the result buffers of
    the dots/gathers they fuse into.  Traffic ≈ 2× materialized bytes
    (write + later read).  Raw 'bytes accessed' is reported alongside as the
    unfused upper bound.
    """
    total = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*"
                     r"(\([^=]*\)|[\w\[\],{}\s]+?)\s+([\w\-]+)\(", s)
        if not m:
            continue
        op = m.group(2)
        base = op.replace("-start", "").replace("-done", "")
        if base not in _MATERIALIZING_OPS or op.endswith("-done"):
            continue
        total += _shape_bytes(m.group(1))
    return 2 * total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op, by op kind."""
    out = {k: 0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # match: '%name = f32[...] all-reduce(...)' or fusion-free variants
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^=]*\)|[\w\[\],{}\s]+?)\s+"
                     r"([\w\-]+)\(", s)
        if not m:
            continue
        op = m.group(2)
        base = op.replace("-start", "").replace("-done", "")
        if base in _COLLECTIVES and not op.endswith("-done"):
            out[base] += _shape_bytes(m.group(1))
            count[base] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = count
    return out


def roofline_terms(result: dict) -> dict:
    """result: one dryrun JSON record → the three terms (seconds) + verdict.

    cost_analysis flops/bytes are per-device executable numbers in SPMD
    lowering; we report per-chip seconds directly."""
    n = result["n_devices"]
    t_compute = result["flops"] / PEAK_FLOPS
    t_mem_raw = result["bytes_accessed"] / HBM_BW
    mat = result.get("materialized_bytes")
    t_memory = (mat / HBM_BW) if mat else t_mem_raw
    t_coll = result["collective_bytes"]["total"] / ICI_BW
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    model_flops = result.get("model_flops", 0)
    total_hlo_flops = result["flops"] * n
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_memory_raw_s": t_mem_raw,     # unfused upper bound (XLA:CPU)
        "t_collective_s": t_coll,
        "dominant": dominant,
        "bound_s": max(t_compute, t_memory, t_coll),
        "model_flops": model_flops,
        "useful_flop_ratio": (model_flops / total_hlo_flops
                              if total_hlo_flops else 0.0),
        "roofline_fraction": (
            (model_flops / n / PEAK_FLOPS)
            / max(t_compute, t_memory, t_coll)
            if max(t_compute, t_memory, t_coll) > 0 else 0.0),
    }


def load_results(out_dir: str) -> list[dict]:
    out = []
    for name in sorted(os.listdir(out_dir)):
        if name.endswith(".json"):
            with open(os.path.join(out_dir, name)) as fh:
                out.append(json.load(fh))
    return out


def format_table(results: list[dict]) -> str:
    rows = ["| arch | shape | mesh | compute s | memory s | collective s | "
            "dominant | useful-FLOP ratio | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in results:
        if not r.get("ok"):
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"FAILED | | | | | |")
            continue
        t = roofline_terms(r)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {t['t_compute_s']:.3e} | {t['t_memory_s']:.3e} "
            f"| {t['t_collective_s']:.3e} | {t['dominant']} "
            f"| {t['useful_flop_ratio']:.3f} | {t['roofline_fraction']:.3f} |")
    return "\n".join(rows)


if __name__ == "__main__":
    import sys
    results = load_results(sys.argv[1] if len(sys.argv) > 1
                           else "results/dryrun")
    print(format_table(results))
