"""Production mesh construction (DESIGN.md §4).

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax import;
tests and benchmarks see the real single device.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def _mk(shape, axes):
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices tests have."""
    return _mk((data, model), ("data", "model"))
