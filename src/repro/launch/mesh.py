"""Production mesh construction (DESIGN.md §4, §2.5).

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax import;
tests and benchmarks see the real single device.

AxisType compatibility: newer jax exposes ``jax.sharding.AxisType`` and
``jax.make_mesh(..., axis_types=...)``; the pinned jax 0.4.37 has
``jax.make_mesh`` but no AxisType.  Mesh construction therefore only passes
``axis_types`` when the running jax provides it — every mesh here is
Auto-typed anyway, which is exactly what an axis-type-free mesh means, so
the two paths are semantically identical.  This is what lets the sharded
index executor (``repro.index.shard``) and the multi-device tests run under
``--xla_force_host_platform_device_count`` on the pinned jax.
"""

from __future__ import annotations

import numpy as np

import jax

try:                                    # jax >= 0.6
    from jax.sharding import AxisType as _AxisType
except ImportError:                     # pinned jax 0.4.37: Auto is implicit
    _AxisType = None


def _mk(shape, axes):
    if _AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(_AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices tests have."""
    return _mk((data, model), ("data", "model"))


def make_index_mesh(n_devices: int | None = None):
    """1-D ('data',) mesh for sharded index serving (DESIGN.md §2.5/§2.9).

    Index parts shard along 'data' only — there is no model axis in the
    query engine — so this is a plain ``Mesh`` over the first ``n_devices``
    local devices (all of them by default).  Uses the raw Mesh constructor,
    not ``jax.make_mesh``, so the device order is exactly ``jax.devices()``
    order: the shard→device placement map stays the identity and is easy to
    audit (``ShardedIndex.placement``)."""
    devs = jax.devices()
    if n_devices is None:
        n_devices = len(devs)
    assert 1 <= n_devices <= len(devs), (n_devices, len(devs))
    return jax.sharding.Mesh(np.array(devs[:n_devices]), ("data",))
