import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes; record memory/cost analysis + collective bytes.

Cost accounting note (EXPERIMENTS §Dry-run): XLA's cost_analysis counts a
while-loop body ONCE regardless of trip count, so scanned-over-layers models
would be undercounted.  For LM cells we therefore compile two *unrolled*
probes (n_layers=1 and n_layers=2, all inner scans unrolled) and extrapolate
linearly — exact for layer-homogeneous stacks: v(L) = v1 + (L-1)·(v2-v1).
The full-depth scan compile is still performed and provides memory_analysis
(the fits-on-chip proof) and the compile-health check.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b \
      --shape train_4k --mesh pod --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --list

One (arch, shape, mesh) per process (jax fixes the device count at first
init; scripts/run_dryruns.sh loops cells as subprocesses).
"""

import argparse
import dataclasses
import json
import time
import traceback


def _compile_cell(cell, mesh):
    import jax
    kw = {}
    if cell.out_shardings is not None:
        kw["out_shardings"] = cell.out_shardings
    with mesh:
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings, **kw)
        lowered = jitted.lower(*cell.in_specs)
        compiled = lowered.compile()
    return compiled


def _costs(compiled):
    from repro.launch import roofline
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    hlo = compiled.as_text()
    coll = roofline.collective_bytes(hlo)
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "materialized_bytes": roofline.materialized_bytes(hlo),
            "collective_bytes": coll}


def _extrapolate(v1: dict, v2: dict, L: int) -> dict:
    def lin(a, b):
        return a + (L - 1) * (b - a)
    coll = {}
    for k in v1["collective_bytes"]:
        if k == "counts":
            coll[k] = {kk: int(lin(v1["collective_bytes"][k][kk],
                                   v2["collective_bytes"][k][kk]))
                       for kk in v1["collective_bytes"][k]}
        else:
            coll[k] = int(lin(v1["collective_bytes"][k],
                              v2["collective_bytes"][k]))
    return {"flops": lin(v1["flops"], v2["flops"]),
            "bytes_accessed": lin(v1["bytes_accessed"], v2["bytes_accessed"]),
            "materialized_bytes": int(lin(v1["materialized_bytes"],
                                          v2["materialized_bytes"])),
            "collective_bytes": coll}


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: str | None,
             verbose: bool = True, skip_full: bool = False) -> dict:
    import jax
    from repro.configs.base import get_config
    from repro.launch.cells import build_cell
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    spec = get_config(arch)
    n_chips = len(mesh.devices.ravel())

    timings = {}
    mem = None
    if not skip_full:
        cell = build_cell(spec, shape, mesh)
        t0 = time.monotonic()
        compiled = _compile_cell(cell, mesh)
        timings["full_compile_s"] = round(time.monotonic() - t0, 2)
        mem = compiled.memory_analysis()
        full_costs = _costs(compiled)
    else:
        cell = build_cell(spec, shape, mesh)
        full_costs = None

    if spec.family == "lm":
        # unrolled L=1 / L=2 probes → exact per-layer extrapolation
        probes = {}
        for L in (1, 2):
            pcfg = dataclasses.replace(spec.config, n_layers=L,
                                       unroll_scan=True)
            pspec = dataclasses.replace(spec, config=pcfg)
            pcell = build_cell(pspec, shape, mesh)
            t0 = time.monotonic()
            pc = _compile_cell(pcell, mesh)
            timings[f"probe{L}_compile_s"] = round(time.monotonic() - t0, 2)
            probes[L] = _costs(pc)
        costs = _extrapolate(probes[1], probes[2], spec.config.n_layers)
        costs["scan_body_costs"] = full_costs
    else:
        costs = full_costs if full_costs is not None else _costs(
            _compile_cell(cell, mesh))

    result = {
        "arch": arch, "shape": shape, "mesh": mesh_kind,
        "n_devices": n_chips,
        **{k: costs[k] for k in ("flops", "bytes_accessed",
                                 "materialized_bytes", "collective_bytes")},
        "memory": ({
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        } if mem is not None else None),
        "model_flops": cell.static_meta.get("model_flops", 0),
        "timings": timings,
        "ok": True,
    }
    if verbose:
        print(f"[dryrun] {arch} × {shape} × {mesh_kind}: OK  {timings}")
        if mem is not None:
            print(f"  memory_analysis: args={mem.argument_size_in_bytes:,} "
                  f"temp={mem.temp_size_in_bytes:,} "
                  f"out={mem.output_size_in_bytes:,}")
        print(f"  cost_analysis (per-device): flops={result['flops']:.3e} "
              f"bytes={result['bytes_accessed']:.3e}")
        print(f"  collective_bytes: {result['collective_bytes']['total']:,}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fn = os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}.json")
        with open(fn, "w") as fh:
            json.dump(result, fh, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str)
    ap.add_argument("--shape", type=str)
    ap.add_argument("--mesh", choices=["pod", "multipod"], default="pod")
    ap.add_argument("--out", type=str, default=None)
    ap.add_argument("--skip-full", action="store_true",
                    help="probes only (costs, no memory analysis)")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    if args.list:
        from repro.configs.base import all_arch_ids, get_config
        for a in all_arch_ids():
            print(a, "→", ", ".join(get_config(a).shapes))
        return

    try:
        run_cell(args.arch, args.shape, args.mesh, args.out,
                 skip_full=args.skip_full)
    except Exception:
        traceback.print_exc()
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            fn = os.path.join(
                args.out, f"{args.arch}__{args.shape}__{args.mesh}.json")
            with open(fn, "w") as fh:
                json.dump({"arch": args.arch, "shape": args.shape,
                           "mesh": args.mesh, "ok": False,
                           "error": traceback.format_exc()}, fh, indent=1)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
