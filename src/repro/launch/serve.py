"""Serving launcher.

  --arch paper-index : conjunctive query serving (the paper's system);
                       --batch N > 1 routes through the shape-bucketed
                       batched scheduler (repro.index.batch), --backend
                       {jax,pallas} picks the intersect backend
  --arch <lm id>     : prefill + greedy decode on the smoke-reduced model
  --arch <recsys id> : batched scoring

  PYTHONPATH=src python -m repro.launch.serve --arch paper-index --queries 20
  PYTHONPATH=src python -m repro.launch.serve --arch paper-index \\
      --queries 256 --batch 64 --backend jax --cache --shared-vocab
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_config


def serve_index(args):
    from repro.index import builder, corpus as corpus_lib, engine
    corpus = corpus_lib.synthesize(n_docs=1 << 16, n_queries=args.queries,
                                   seed=5, shared_vocab=args.shared_vocab)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="fastpfor-d1", B=16, n_parts=2)
    queries = corpus.queries
    cache = engine.DecodeCache() if args.cache else None

    def cache_note():
        if cache is None:
            return ""
        return f", cache hit rate {cache.hit_rate:.2f}"

    if args.batch > 1:
        from repro.index import batch as batch_lib

        def run_all():
            out, stats = [], {}
            for lo in range(0, len(queries), args.batch):
                out.extend(batch_lib.execute_batch(
                    idx, queries[lo: lo + args.batch],
                    backend=args.backend, cache=cache, stats=stats))
            return out, stats

        run_all()                               # warm / compile
        t0 = time.perf_counter()
        results, stats = run_all()
        dt = time.perf_counter() - t0
        hits = sum(r.count for r in results)
        print(f"[serve] paper-index --batch {args.batch} ({args.backend}): "
              f"{len(queries)} queries, {len(queries) / dt:.1f} q/s "
              f"({dt / len(queries) * 1e3:.2f} ms/query), {hits} hits, "
              f"{stats['n_programs']} device programs, "
              f"{stats.get('decoded_ints', 0) / len(queries):.0f} "
              f"decoded ints/query "
              f"({stats.get('skip_folds', 0)} skip folds), "
              f"{idx.stats()['bits_per_int']:.2f} bits/int"
              f"{cache_note()}")
        return
    for q in queries:                       # warm / compile every signature
        engine.query(idx, q, cache=cache)
    stats: dict = {}
    t0 = time.perf_counter()
    hits = sum(engine.query(idx, q, cache=cache, stats=stats).count
               for q in queries)
    dt = time.perf_counter() - t0
    print(f"[serve] paper-index: {len(queries)} queries, "
          f"{len(queries) / dt:.1f} q/s "
          f"({dt / len(queries) * 1e3:.2f} ms/query), {hits} hits, "
          f"{stats.get('decoded_ints', 0) / len(queries):.0f} "
          f"decoded ints/query ({stats.get('skip_folds', 0)} skip folds), "
          f"{idx.stats()['bits_per_int']:.2f} bits/int"
          f"{cache_note()}")


def serve_lm(args, spec):
    from repro.models.transformer import init_params
    from repro.serve.steps import greedy_generate
    cfg = spec.smoke_config()
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = args.batch or 4
    prompt = jax.random.randint(jax.random.PRNGKey(1), (batch, 16),
                                0, cfg.vocab)
    t0 = time.perf_counter()
    out = greedy_generate(params, cfg, prompt, max_new=args.tokens,
                          cache_len=16 + args.tokens)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    print(f"[serve] {spec.arch_id}: batch={batch} generated "
          f"{args.tokens} tokens in {dt:.2f}s "
          f"({batch * args.tokens / dt:.1f} tok/s); sample: "
          f"{np.asarray(out[0, :8]).tolist()}")


def serve_recsys(args, spec):
    from repro.data import recsys_data
    from repro.models import recsys
    cfg = spec.smoke_config()
    params = recsys.INIT[cfg.arch](jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    mk = {"din": recsys_data.din_batch, "sasrec": recsys_data.seq_batch,
          "bert4rec": recsys_data.bert4rec_batch,
          "mind": recsys_data.mind_batch}[cfg.arch]
    batch = args.batch or 4
    b = {k: jnp.asarray(v) for k, v in mk(rng, cfg, batch).items()}
    score = jax.jit(lambda p, bb: recsys.SCORE[cfg.arch](p, bb, cfg))
    score(params, b)                        # warm
    t0 = time.perf_counter()
    s = score(params, b)
    jax.block_until_ready(s)
    dt = time.perf_counter() - t0
    print(f"[serve] {spec.arch_id}: scored batch={batch} in "
          f"{dt * 1e3:.2f} ms; mean score {float(s.mean()):.4f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--queries", type=int, default=20)
    ap.add_argument("--batch", type=int, default=0,
                    help="paper-index: >1 enables batched scheduler; "
                         "lm/recsys: batch size (default 4)")
    ap.add_argument("--backend", choices=["jax", "pallas"], default="jax")
    ap.add_argument("--cache", action="store_true",
                    help="paper-index: serve with a DecodeCache and report "
                         "its hit rate")
    ap.add_argument("--shared-vocab", action="store_true",
                    help="paper-index: Zipf-shared query term ids "
                         "(realistic cache hit rates)")
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()
    if args.arch == "paper-index":
        return serve_index(args)
    spec = get_config(args.arch)
    if spec.family == "lm":
        return serve_lm(args, spec)
    if spec.family == "recsys":
        return serve_recsys(args, spec)
    raise SystemExit(f"no serving mode for family {spec.family}")


if __name__ == "__main__":
    main()
