"""Serving launcher.

  --arch paper-index : batched conjunctive query serving (the paper's system)
  --arch <lm id>     : prefill + greedy decode on the smoke-reduced model
  --arch <recsys id> : batched scoring

  PYTHONPATH=src python -m repro.launch.serve --arch paper-index --queries 20
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_config


def serve_index(args):
    from repro.index import builder, corpus as corpus_lib, engine
    corpus = corpus_lib.synthesize(n_docs=1 << 16, n_queries=args.queries,
                                   seed=5)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="fastpfor-d1", B=16, n_parts=2)
    engine.query(idx, corpus.queries[0])
    t0 = time.perf_counter()
    hits = sum(engine.query(idx, q).count for q in corpus.queries)
    dt = (time.perf_counter() - t0) / len(corpus.queries) * 1e3
    print(f"[serve] paper-index: {len(corpus.queries)} queries, "
          f"{dt:.2f} ms/query, {hits} hits, "
          f"{idx.stats()['bits_per_int']:.2f} bits/int")


def serve_lm(args, spec):
    from repro.models.transformer import init_params
    from repro.serve.steps import greedy_generate
    cfg = spec.smoke_config()
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (args.batch, 16),
                                0, cfg.vocab)
    t0 = time.perf_counter()
    out = greedy_generate(params, cfg, prompt, max_new=args.tokens,
                          cache_len=16 + args.tokens)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    print(f"[serve] {spec.arch_id}: batch={args.batch} generated "
          f"{args.tokens} tokens in {dt:.2f}s "
          f"({args.batch * args.tokens / dt:.1f} tok/s); sample: "
          f"{np.asarray(out[0, :8]).tolist()}")


def serve_recsys(args, spec):
    from repro.data import recsys_data
    from repro.models import recsys
    cfg = spec.smoke_config()
    params = recsys.INIT[cfg.arch](jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    mk = {"din": recsys_data.din_batch, "sasrec": recsys_data.seq_batch,
          "bert4rec": recsys_data.bert4rec_batch,
          "mind": recsys_data.mind_batch}[cfg.arch]
    b = {k: jnp.asarray(v) for k, v in mk(rng, cfg, args.batch).items()}
    score = jax.jit(lambda p, bb: recsys.SCORE[cfg.arch](p, bb, cfg))
    score(params, b)                        # warm
    t0 = time.perf_counter()
    s = score(params, b)
    jax.block_until_ready(s)
    dt = time.perf_counter() - t0
    print(f"[serve] {spec.arch_id}: scored batch={args.batch} in "
          f"{dt * 1e3:.2f} ms; mean score {float(s.mean()):.4f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--queries", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()
    if args.arch == "paper-index":
        return serve_index(args)
    spec = get_config(args.arch)
    if spec.family == "lm":
        return serve_lm(args, spec)
    if spec.family == "recsys":
        return serve_recsys(args, spec)
    raise SystemExit(f"no serving mode for family {spec.family}")


if __name__ == "__main__":
    main()
