"""Serving launcher.

  --arch paper-index : conjunctive query serving (the paper's system);
                       --batch N > 1 routes through the shape-bucketed
                       batched scheduler (repro.index.batch), --backend
                       {jax,pallas} picks the intersect backend,
                       --resident stages the device-resident index
                       (source.ResidentPool), --pipeline D double-buffers
                       batches at depth D with a per-stage timing breakdown
                       (stage/assemble/dispatch/block;
                       repro.index.pipeline), --fuse (default) collapses
                       each batch to O(1) fused megagroup programs
                       (--no-fuse for the per-signature A/B), --warmup
                       precompiles the fused family ladder before the
                       timed run (AOT signature warmup, DESIGN.md §2.10)
  --arch <lm id>     : prefill + greedy decode on the smoke-reduced model
  --arch <recsys id> : batched scoring

  PYTHONPATH=src python -m repro.launch.serve --arch paper-index --queries 20
  PYTHONPATH=src python -m repro.launch.serve --arch paper-index \\
      --queries 256 --batch 64 --backend jax --cache --shared-vocab
  PYTHONPATH=src python -m repro.launch.serve --arch paper-index \\
      --queries 256 --batch 32 --pipeline 2
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_config


def coerce_index_flags(args) -> list[str]:
    """Normalise paper-index flag interactions, returning one warning line
    per coerced or ignored flag.

    Earlier revisions rewrote flags silently (``--shards`` turned
    ``--batch 1`` into 32 and dropped ``--cache`` with only a partial
    note), so a user could not tell the run they asked for from the run
    they got.  Every implied rewrite is now explicit; ``args`` is mutated
    in place so the serving paths read the *effective* values."""
    warnings = []
    # durability / chaos / live-traffic flags (DESIGN.md §2.15) — resolved
    # first because --wal can imply --mutate, which the branches below read
    if getattr(args, "wal", None) and not getattr(args, "mutate", 0):
        warnings.append("--wal implies the mutable index: --mutate 0 -> 256")
        args.mutate = 256
    if getattr(args, "chaos", None) and not getattr(args, "wal", None):
        warnings.append("--chaos without --wal: durability crash points "
                        "(wal.*/snapshot.*/merge.*) have no durable "
                        "directory to recover from — only launch/collect "
                        "seam faults can fire safely")
    if (getattr(args, "timeout_ms", None) is not None
            and not getattr(args, "qps", 0)):
        warnings.append("--timeout-ms ignored without --qps (offline and "
                        "drain serving have no per-request deadlines)")
        args.timeout_ms = None
    if getattr(args, "qps", 0):
        if args.pipeline:
            warnings.append("--pipeline ignored with --qps (the live "
                            "server bounds in-flight batches itself)")
            args.pipeline = 0
        if args.shards:
            warnings.append("--shards ignored with --qps (use "
                            "repro.launch.server --shards for live "
                            "sharded serving)")
            args.shards = 0
        if args.batch <= 1:
            warnings.append(f"--qps implies batched mode: "
                            f"--batch {args.batch} -> 32")
            args.batch = 32
    if getattr(args, "mutate", 0):
        if args.batch <= 1:
            warnings.append(f"--mutate implies batched mode: "
                            f"--batch {args.batch} -> 32")
            args.batch = 32
        if args.pipeline:
            warnings.append("--pipeline ignored with --mutate (the mutable "
                            "path batches against generation snapshots)")
            args.pipeline = 0
        if args.cache:
            warnings.append("--cache ignored with --mutate (decoded "
                            "results change as the corpus mutates)")
            args.cache = False
        if not args.resident:
            warnings.append("--mutate implies the device-resident index: "
                            "--resident on (each generation owns a warmed "
                            "ResidentPool)")
            args.resident = True
        return warnings
    if getattr(args, "delete_frac", None) is not None:
        warnings.append("--delete-frac ignored without --mutate")
        args.delete_frac = None
    if args.shards:
        if args.batch <= 1:
            warnings.append(f"--shards implies batched mode: "
                            f"--batch {args.batch} -> 32")
            args.batch = 32
        if not args.pipeline:
            warnings.append("--shards implies pipelined serving: "
                            "--pipeline 0 -> 2")
            args.pipeline = 2
        if args.cache:
            warnings.append("--cache ignored with --shards (per-shard "
                            "device residency supersedes the decode cache)")
            args.cache = False
        if not args.resident:
            warnings.append("--shards implies the device-resident index: "
                            "--resident on")
            args.resident = True
    elif args.pipeline:
        if args.batch <= 1:
            warnings.append(f"--pipeline implies batched mode: "
                            f"--batch {args.batch} -> 32")
            args.batch = 32
        if not args.resident:
            warnings.append("--pipeline implies the device-resident index: "
                            "--resident on")
            args.resident = True
    if args.warmup and not args.fuse:
        warnings.append("--warmup warms the fused family ladder; with "
                        "--no-fuse the signature fixed-point loop covers it")
    return warnings


# --codec flag value -> builder codec name ("auto" goes to the storage
# autotuner; everything else pins one family index-wide)
_CODEC_NAMES = {"auto": "auto", "bitpack": "bp-d1",
                "streamvbyte": "streamvbyte-d1", "composite": "composite-d1",
                "fastpfor": "fastpfor-d1", "varint": "varint"}


def _codec_name(args) -> str:
    return _CODEC_NAMES[getattr(args, "codec", "fastpfor")]


def _print_codec_stats(args, idx) -> None:
    """Storage report next to the build: bytes/int plus how many lists
    landed in each codec family (the autotuner's visible output)."""
    st = idx.stats()
    counts = " ".join(f"{k}:{v}" for k, v in
                      sorted(st["codec_counts"].items()))
    print(f"[serve] index codec {getattr(args, 'codec', 'fastpfor')}: "
          f"{st['bytes_per_int']:.2f} bytes/int "
          f"({st['bits_per_int']:.2f} bits/int) [{counts}]")


def serve_index(args):
    from repro.index import builder, corpus as corpus_lib, engine, source
    for w in coerce_index_flags(args):
        print(f"[serve] warning: {w}")
    from repro.kernels import ops as kernel_ops
    kmode = kernel_ops.set_kernel_mode(getattr(args, "kernel_mode", "auto"))
    if args.backend == "pallas":
        print(f"[serve] pallas kernel mode: {kmode}"
              + (" (interpret — timings not comparable to compiled; "
                 "see DESIGN.md §2.12)" if kmode == "interpret" else ""))
    corpus = corpus_lib.synthesize(n_docs=1 << 16, n_queries=args.queries,
                                   seed=5, shared_vocab=args.shared_vocab)
    if getattr(args, "qps", 0):
        return serve_index_live(args, corpus)
    if getattr(args, "mutate", 0):
        return serve_index_mutable(args, corpus)
    if args.shards:
        return serve_index_sharded(args, corpus)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name=_codec_name(args), B=16, n_parts=2)
    _print_codec_stats(args, idx)
    queries = corpus.queries
    cache = engine.DecodeCache() if args.cache else None
    pool = None
    if args.resident or args.pipeline:
        pool = source.ResidentPool()
        t0 = time.perf_counter()
        pool.warm(idx)
        ps = pool.stats()
        print(f"[serve] resident index: staged {ps['staged_lists']} lists "
              f"({ps['staged_ints']} ints) in {time.perf_counter() - t0:.2f}s")

    def cache_note():
        note = ""
        if cache is not None:
            note += f", cache hit rate {cache.hit_rate:.2f}"
        if pool is not None:
            ps = pool.stats()
            note += (f", pool {ps['resident_lists']} lists resident "
                     f"({ps['evicted_lists']} evicted)")
        return note

    if args.batch > 1:
        from repro.index import batch as batch_lib
        from repro.index import pipeline as pipe_lib

        depth = args.pipeline
        plan = batch_lib.FusionPlan() if args.fuse else None

        def run_all(stats=None, timings=None):
            stats = {} if stats is None else stats
            if depth:
                out = pipe_lib.execute_pipelined(
                    idx, queries, batch_size=args.batch, depth=depth,
                    backend=args.backend, cache=cache, pool=pool,
                    fuse=args.fuse, plan=plan, stats=stats,
                    timings=timings)
            else:
                out = []
                for lo in range(0, len(queries), args.batch):
                    out.extend(batch_lib.execute_batch(
                        idx, queries[lo: lo + args.batch],
                        backend=args.backend, cache=cache, pool=pool,
                        fuse=args.fuse, plan=plan, stats=stats))
            return out, stats

        if args.warmup and args.fuse:
            # AOT signature warmup: compile the fused family ladder before
            # the first timed batch (DESIGN.md §2.10); the query stream is
            # its own most representative sample
            wu = batch_lib.warmup(idx, queries, plan=plan,
                                  batch_size=args.batch,
                                  backend=args.backend, pool=pool,
                                  cache=cache)
            print(f"[serve] warmup: {wu['n_compiles']} compiles over "
                  f"{wu['n_signatures']} signatures in {wu['passes']} "
                  f"passes ({wu['time_s']:.2f}s)")
            if not wu.get("converged", True):
                print("[serve] warning: warmup stopped at max_passes "
                      "before the signature ladder reached a fixed point "
                      "— steady-state serving may still compile")
        else:
            # Warm to steady state: cache fills / pool staging change how
            # terms resolve between passes (decoded vs packed), which
            # changes group signatures — so repeat until no new program
            # signature appears, otherwise the timed loop pays compile on
            # its first batches.
            n_sigs, passes, converged = batch_lib.warm_to_fixed_point(
                lambda s: run_all(stats=s))
            if not converged:
                print(f"[serve] warning: signature warm loop stopped at "
                      f"max_passes ({passes} passes, {n_sigs} signatures) "
                      f"without converging — the timed run may pay hidden "
                      f"compiles")
        timings = pipe_lib.StageTimings() if depth else None
        t0 = time.perf_counter()
        results, stats = run_all(timings=timings)
        dt = time.perf_counter() - t0
        hits = sum(r.count for r in results)
        mode = (f"--pipeline {depth} (batch {args.batch})" if depth
                else f"--batch {args.batch}")
        n_batches = max((len(queries) + args.batch - 1) // args.batch, 1)
        print(f"[serve] paper-index {mode} ({args.backend}"
              f"{', fused' if args.fuse else ', unfused'}): "
              f"{len(queries)} queries, {len(queries) / dt:.1f} q/s "
              f"({dt / len(queries) * 1e3:.2f} ms/query), {hits} hits, "
              f"{stats.get('n_dispatches', 0)} dispatches "
              f"({stats.get('n_dispatches', 0) / n_batches:.1f}/batch, "
              f"{len(stats.get('signatures', ()))} programs, "
              f"{stats.get('n_compiles', 0)} compiles), "
              f"{stats.get('decoded_ints', 0) / len(queries):.0f} "
              f"decoded ints/query "
              f"({stats.get('skip_folds', 0)} skip folds, "
              f"{stats.get('resident_hits', 0)} resident hits), "
              f"{idx.stats()['bits_per_int']:.2f} bits/int"
              f"{cache_note()}")
        if timings is not None:
            tot = max(timings.stage + timings.assemble + timings.dispatch
                      + timings.block, 1e-9)
            print(f"[serve]   pipeline depth {depth}: "
                  f"stage {timings.stage * 1e3:.1f} ms "
                  f"({timings.stage / tot:.0%}), "
                  f"assemble {timings.assemble * 1e3:.1f} ms "
                  f"({timings.assemble / tot:.0%}), "
                  f"dispatch {timings.dispatch * 1e3:.1f} ms "
                  f"({timings.dispatch / tot:.0%}), "
                  f"block {timings.block * 1e3:.1f} ms "
                  f"({timings.block / tot:.0%}) "
                  f"over {timings.batches} batches")
        return
    # warm / compile every signature; two passes when residency (cache or
    # pool) changes how terms resolve — steady state, not first-touch
    for _ in range(2 if (cache is not None or pool is not None) else 1):
        for q in queries:
            engine.query(idx, q, cache=cache, pool=pool)
    stats: dict = {}
    t0 = time.perf_counter()
    hits = sum(engine.query(idx, q, cache=cache, pool=pool,
                            stats=stats).count
               for q in queries)
    dt = time.perf_counter() - t0
    print(f"[serve] paper-index: {len(queries)} queries, "
          f"{len(queries) / dt:.1f} q/s "
          f"({dt / len(queries) * 1e3:.2f} ms/query), {hits} hits, "
          f"{stats.get('decoded_ints', 0) / len(queries):.0f} "
          f"decoded ints/query ({stats.get('skip_folds', 0)} skip folds), "
          f"{idx.stats()['bits_per_int']:.2f} bits/int"
          f"{cache_note()}")


def _injector(args):
    """Build the chaos FaultInjector from --chaos (None when unarmed)."""
    spec = getattr(args, "chaos", None)
    if not spec:
        return None
    from repro.launch import faults as faults_lib
    return faults_lib.FaultInjector(spec, seed=getattr(args, "seed", 0) or 0)


def _bootstrap_mutable(args, corpus, injector=None):
    """Shared --mutate bootstrap: build the MutableIndex (WAL-backed when
    --wal is set), apply the add/seal/delete stream, and — if an injected
    crash fires mid-mutation — recover from the WAL directory and keep
    going with the recovered state (DESIGN.md §2.15)."""
    from repro.index import segments
    log = None
    if getattr(args, "wal", None):
        from repro.index import durability
        log = durability.DurableLog(args.wal, injector=injector)
    n_mut = args.mutate
    del_frac = 0.1 if args.delete_frac is None else args.delete_frac
    t0 = time.perf_counter()
    mi = segments.MutableIndex.from_postings(
        corpus.postings, corpus.n_docs, codec_name=_codec_name(args),
        B=16, n_parts=2, n_shards=args.shards, wal=log)
    print(f"[serve] mutable index bootstrapped: {corpus.n_docs} docs "
          f"sealed in {time.perf_counter() - t0:.2f}s"
          + (f", {args.shards} shards" if args.shards else "")
          + (f", WAL at {args.wal}" if log is not None else ""))

    queries = corpus.queries
    rng = np.random.default_rng(7)
    term_pool = sorted({t for q in queries for t in q})
    n_del = int(del_frac * n_mut)
    crashed = False
    try:
        for i in range(n_mut):
            k = int(rng.integers(1, 4))
            mi.add(sorted(rng.choice(term_pool, size=k,
                                     replace=False).tolist()))
            if n_mut > 1 and i == n_mut // 2:
                mi.seal()               # live stream: seal mid-mutation
        if n_del:
            for d in rng.choice(mi.next_doc_id, size=n_del, replace=False):
                mi.delete(int(d))
    except Exception as e:              # noqa: BLE001 — chaos crash path
        from repro.launch import faults as faults_lib
        if not isinstance(e, faults_lib.InjectedCrash) or log is None:
            raise
        # the injected "process death": everything not yet applied is
        # lost; recovery replays snapshot + WAL tail and serving resumes
        print(f"[serve] chaos: {e} — recovering from {args.wal}")
        crashed = True
        injector.disarm_all()
        t0 = time.perf_counter()
        mi = segments.MutableIndex.recover(args.wal, injector=injector)
        print(f"[serve] recovered in {time.perf_counter() - t0:.2f}s: "
              f"replayed {mi._wal_replayed} WAL records, "
              f"{mi.counters()['n_segments']} segments, "
              f"{mi.counters()['mutable_docs']} mutable docs")
    c = mi.counters()
    stream = (f"crash cut the +{n_mut}/-{n_del} mutation stream short"
              if crashed else f"+{n_mut} docs / -{n_del} tombstones")
    print(f"[serve] mutable index: {stream} -> "
          f"generation {c['generation']}, {c['n_segments']} sealed "
          f"segments + {c['mutable_docs']} mutable docs, "
          f"{c['tombstones']} tombstones, {c['n_seals']} seals, "
          f"vocab {c['vocab']}")
    return mi, n_del


def _recovery_differential(args, mi, queries):
    """--wal epilogue: recover a second index from the durable directory
    and assert it answers byte-identically to the live one."""
    from repro.index import segments
    t0 = time.perf_counter()
    ri = segments.MutableIndex.recover(args.wal)
    dt = time.perf_counter() - t0
    got = mi.execute_batch(queries, backend=args.backend, fuse=args.fuse)
    rec = ri.execute_batch(queries, backend=args.backend, fuse=args.fuse)
    for q, g, r in zip(queries, got, rec):
        assert g.count == r.count and np.array_equal(g.docs, r.docs), \
            f"recovery mismatch on {q}"
    print(f"[serve] recovery check: replayed {ri._wal_replayed} WAL "
          f"records in {dt:.2f}s; {len(queries)} queries byte-identical "
          f"to the live index")


def serve_index_mutable(args, corpus):
    """--mutate N: live-corpus serving demo over the segmented mutable
    index (DESIGN.md §2.14).

    Bootstraps a MutableIndex from the synthetic corpus, applies N adds
    (with a mid-stream seal) and ``--delete-frac``·N tombstones, warms to
    the signature fixed point, then runs the timed loop *while a
    background merge compacts the sealed segments* — the printed q/s is
    throughput during the merge, and the run ends with a differential
    check against a rebuild-from-scratch index.  With --wal DIR every
    mutation is journaled and the run ends with a crash-recovery
    differential as well (DESIGN.md §2.15)."""
    from repro.index import batch as batch_lib, builder, engine
    injector = _injector(args)
    n_mut = args.mutate
    del_frac = 0.1 if args.delete_frac is None else args.delete_frac
    mi, n_del = _bootstrap_mutable(args, corpus, injector)
    queries = corpus.queries

    def run_all(stats=None):
        stats = {} if stats is None else stats
        out = []
        for lo in range(0, len(queries), args.batch):
            out.extend(mi.execute_batch(queries[lo: lo + args.batch],
                                        backend=args.backend,
                                        fuse=args.fuse, stats=stats))
        return out, stats

    t0 = time.perf_counter()
    c0 = batch_lib._compile_count()
    n_sigs, passes, converged = batch_lib.warm_to_fixed_point(
        lambda s: run_all(stats=s))
    if args.warmup:
        print(f"[serve] warmup: {batch_lib._compile_count() - c0} compiles "
              f"over {n_sigs} signatures in {passes} passes "
              f"({time.perf_counter() - t0:.2f}s)")
    if not converged:
        print("[serve] warning: signature warm loop stopped at max_passes "
              "without converging — the timed run may pay hidden compiles")

    # timed loop under a live background merge: the candidate generation
    # pre-warms through the shared sticky plan before the atomic swap;
    # --chaos merge.* points fire through the stage hook and exercise the
    # merge retry path
    merge_hook = injector.merge_hook() if injector is not None else None
    merge_thread = mi.merge_async(warm_queries=queries,
                                  backend=args.backend, hook=merge_hook)
    stats: dict = {}
    t0 = time.perf_counter()
    loops = 0
    while loops == 0 or (merge_thread.is_alive() and loops < 64):
        results, _ = run_all(stats=stats)
        loops += 1
    dt = time.perf_counter() - t0
    merge_thread.join()
    n_q = loops * len(queries)
    hits = sum(r.count for r in results)
    c = mi.counters()
    print(f"[serve] paper-index --mutate {n_mut} "
          f"--delete-frac {del_frac:g} ({args.backend}"
          f"{', fused' if args.fuse else ', unfused'}, "
          f"batch {args.batch}): {n_q} queries in {loops} loops during "
          f"background merge, {n_q / dt:.1f} q/s "
          f"({dt / n_q * 1e3:.2f} ms/query), {hits} hits, "
          f"{stats.get('n_compiles', 0)} compiles")
    print(f"[serve]   post-merge: generation {c['generation']}, "
          f"{c['n_segments']} segments, {c['n_merges']} merges, "
          f"{c['next_doc_id']} doc ids ({c['tombstones']} tombstoned)")
    if c.get("merge_failures"):
        print(f"[serve]   merge retries: {c['merge_failures']} failed "
              f"attempts, last error: {c['last_merge_error'] or 'cleared'}")

    # differential: the served state vs a rebuild-from-scratch index
    idx = builder.build(mi.live_postings(), max(mi.next_doc_id, 1),
                        codec_name=_codec_name(args), B=16, n_parts=2)
    final, _ = run_all()
    for q, got in zip(queries, final):
        want = engine.query(idx, q)
        assert got.count == want.count and \
            np.array_equal(got.docs, want.docs), f"mismatch on {q}"
    print(f"[serve] differential check: {len(queries)} queries "
          f"byte-identical to rebuild-from-scratch")
    if getattr(args, "wal", None):
        _recovery_differential(args, mi, queries)
    if injector is not None:
        print(f"[serve] chaos: {injector.counts()}")
    return final


def serve_index_live(args, corpus):
    """--qps Q: open-loop live serving through the continuous-batching
    server (repro.launch.server) with the resilience knobs — per-request
    deadlines (--timeout-ms), injected faults (--chaos), and a durable
    mutable corpus (--wal, --mutate).  DESIGN.md §2.11 and §2.15.

    Every submitted request resolves to exactly one of done / shed /
    timeout / error; the epilogue audits that and, for --mutate, runs the
    served-results differential against the live index (plus the WAL
    recovery differential when --wal is set)."""
    from repro.index import batch as batch_lib, builder, source
    from repro.launch import server as server_lib
    injector = _injector(args)
    queries = corpus.queries
    kw = dict(backend=args.backend, max_batch=args.batch, fuse=args.fuse,
              timeout_ms=getattr(args, "timeout_ms", None),
              injector=injector)
    mi = idx = None
    if getattr(args, "mutate", 0):
        mi, _ = _bootstrap_mutable(args, corpus, injector)
        kw["mutable"] = mi
    else:
        idx = builder.build(corpus.postings, corpus.n_docs,
                            codec_name=_codec_name(args), B=16, n_parts=2)
        _print_codec_stats(args, idx)
        if args.resident:
            pool = source.ResidentPool()
            pool.warm(idx)
            kw["pool"] = pool
    results, server = server_lib.serve_open_loop(
        idx, queries, qps=args.qps, warmup=args.warmup,
        seed=getattr(args, "seed", 0) or 0, **kw)
    s = server.metrics.summary()
    outs = server.outcomes()
    assert len(outs) == len(queries) and "pending" not in outs, \
        "unresolved requests after run()"  # the zero-lost-requests audit
    lad = server.ladder
    print(f"[serve] paper-index --qps {args.qps:g} ({args.backend}"
          f"{', fused' if args.fuse else ', unfused'}, "
          f"batch {args.batch}"
          + (f", timeout {args.timeout_ms:g} ms"
             if getattr(args, "timeout_ms", None) is not None else "")
          + f"): {s['n_done']} done / {s['n_shed']} shed / "
          f"{s['n_timeout']} timed out / {s['n_errors']} errored, "
          f"{s['qps']:.1f} q/s, p50 {s['p50_ms']:.2f} ms, "
          f"p99 {s['p99_ms']:.2f} ms")
    print(f"[serve]   resilience: {s['n_faults']} faults, "
          f"{s['n_retries']} retries, {s['degraded_flushes']} degraded "
          f"flushes, {lad.n_degradations} degradations / "
          f"{lad.n_promotions} promotions, final rung "
          f"{lad.current[0]}{'+fused' if lad.current[1] else '+unfused'}")
    if injector is not None:
        print(f"[serve] chaos: {injector.counts()}")
    # differential: every answered request must match a clean re-execution
    # against the same (final) corpus state — degraded or retried flushes
    # included
    served = [(q, r) for q, r in zip(queries, results) if r is not None]
    if served:
        qs = [q for q, _ in served]
        if mi is not None:
            want = mi.execute_batch(qs, backend=args.backend,
                                    fuse=args.fuse)
        else:
            want = batch_lib.execute_batch(idx, qs, backend=args.backend,
                                           fuse=args.fuse)
        for (q, got), w in zip(served, want):
            assert got.count == w.count and \
                np.array_equal(got.docs, w.docs), f"mismatch on {q}"
        print(f"[serve] differential check: {len(served)} answered "
              f"queries byte-identical to direct execution")
    if mi is not None and getattr(args, "wal", None):
        _recovery_differential(args, mi, queries)
    return results


def serve_index_sharded(args, corpus):
    """--shards N: multi-device fan-out serving (repro.index.shard).

    Each index part's working set is pinned to its shard's device; batches
    fan out to all shards in one SPMD dispatch and per-part hits
    concatenate in part order — byte-identical to single-device serving.
    Run under XLA_FLAGS=--xla_force_host_platform_device_count=N to get N
    host-platform devices on CPU-only machines (must be set before jax
    initializes; with fewer devices, shards share them contiguously)."""
    from repro.index import builder, pipeline as pipe_lib, shard as shard_lib
    t0 = time.perf_counter()
    sharded = builder.build_sharded(
        corpus.postings, corpus.n_docs, n_shards=args.shards,
        codec_name=_codec_name(args), B=16,
        n_parts=max(args.shards, 2))
    _print_codec_stats(args, sharded.index)
    st = sharded.stats()
    print(f"[serve] sharded index: {st['n_shards']} shards on "
          f"{st['n_devices']} devices, warmed in "
          f"{time.perf_counter() - t0:.2f}s")
    for s in st["shards"]:
        print(f"[serve]   shard {s['shard']} -> {s['device']}: "
              f"parts {s['parts']}, {s['resident_lists']} lists "
              f"({s['resident_ints']} ints) resident")
    queries = corpus.queries
    batch = args.batch                  # coerce_index_flags normalised these
    depth = args.pipeline
    from repro.index import batch as batch_lib
    plan = batch_lib.FusionPlan() if args.fuse else None

    def run_all(stats=None, timings=None):
        return shard_lib.execute_sharded(
            sharded, queries, batch_size=batch, depth=depth,
            backend=args.backend, fuse=args.fuse, plan=plan,
            stats=stats, timings=timings)

    # warm to signature fixed point (same rationale as the batched path);
    # with --warmup the compile accounting of the pass is reported
    c0 = batch_lib._compile_count()
    t0 = time.perf_counter()
    n_sigs, passes, converged = batch_lib.warm_to_fixed_point(
        lambda s: run_all(stats=s))
    if args.warmup:
        print(f"[serve] warmup: {batch_lib._compile_count() - c0} compiles "
              f"over {n_sigs} signatures in {passes} passes "
              f"({time.perf_counter() - t0:.2f}s)")
    if not converged:
        print(f"[serve] warning: signature warm loop stopped at max_passes "
              f"({passes} passes, {n_sigs} signatures) without converging "
              f"— the timed run may pay hidden compiles")
    timings = pipe_lib.StageTimings()
    stats: dict = {}
    t0 = time.perf_counter()
    results = run_all(stats=stats, timings=timings)
    dt = time.perf_counter() - t0
    hits = sum(r.count for r in results)
    n_batches = max((len(queries) + batch - 1) // batch, 1)
    print(f"[serve] paper-index --shards {args.shards} "
          f"(batch {batch}, depth {depth}, {args.backend}"
          f"{', fused' if args.fuse else ', unfused'}): "
          f"{len(queries)} queries, {len(queries) / dt:.1f} q/s "
          f"({dt / len(queries) * 1e3:.2f} ms/query), {hits} hits, "
          f"{stats.get('n_dispatches', 0)} dispatches "
          f"({stats.get('n_dispatches', 0) / n_batches:.1f}/batch, "
          f"{len(stats.get('signatures', ()))} programs, "
          f"{stats.get('n_compiles', 0)} compiles)")
    tot = max(timings.stage + timings.assemble + timings.dispatch
              + timings.block, 1e-9)
    print(f"[serve]   stage {timings.stage * 1e3:.1f} ms "
          f"({timings.stage / tot:.0%}), "
          f"assemble {timings.assemble * 1e3:.1f} ms "
          f"({timings.assemble / tot:.0%}), "
          f"dispatch {timings.dispatch * 1e3:.1f} ms "
          f"({timings.dispatch / tot:.0%}), "
          f"block {timings.block * 1e3:.1f} ms ({timings.block / tot:.0%})")
    return results


def serve_lm(args, spec):
    from repro.models.transformer import init_params
    from repro.serve.steps import greedy_generate
    cfg = spec.smoke_config()
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = args.batch or 4
    prompt = jax.random.randint(jax.random.PRNGKey(1), (batch, 16),
                                0, cfg.vocab)
    t0 = time.perf_counter()
    out = greedy_generate(params, cfg, prompt, max_new=args.tokens,
                          cache_len=16 + args.tokens)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    print(f"[serve] {spec.arch_id}: batch={batch} generated "
          f"{args.tokens} tokens in {dt:.2f}s "
          f"({batch * args.tokens / dt:.1f} tok/s); sample: "
          f"{np.asarray(out[0, :8]).tolist()}")


def serve_recsys(args, spec):
    from repro.data import recsys_data
    from repro.models import recsys
    cfg = spec.smoke_config()
    params = recsys.INIT[cfg.arch](jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    mk = {"din": recsys_data.din_batch, "sasrec": recsys_data.seq_batch,
          "bert4rec": recsys_data.bert4rec_batch,
          "mind": recsys_data.mind_batch}[cfg.arch]
    batch = args.batch or 4
    b = {k: jnp.asarray(v) for k, v in mk(rng, cfg, batch).items()}
    score = jax.jit(lambda p, bb: recsys.SCORE[cfg.arch](p, bb, cfg))
    score(params, b)                        # warm
    t0 = time.perf_counter()
    s = score(params, b)
    jax.block_until_ready(s)
    dt = time.perf_counter() - t0
    print(f"[serve] {spec.arch_id}: scored batch={batch} in "
          f"{dt * 1e3:.2f} ms; mean score {float(s.mean()):.4f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--queries", type=int, default=20)
    ap.add_argument("--batch", type=int, default=0,
                    help="paper-index: >1 enables batched scheduler; "
                         "lm/recsys: batch size (default 4)")
    ap.add_argument("--backend", choices=["jax", "pallas"], default="jax")
    ap.add_argument("--kernel-mode", choices=["auto", "compiled", "interpret"],
                    default="auto",
                    help="Pallas kernel execution mode: auto probes the "
                         "runtime backend (compiled Mosaic on TPU, "
                         "interpret elsewhere; REPRO_PALLAS_INTERPRET "
                         "overrides); compiled/interpret force it "
                         "(DESIGN.md §2.12)")
    ap.add_argument("--pipeline", type=int, default=0, metavar="DEPTH",
                    help="paper-index: double-buffered pipelined serving "
                         "with DEPTH batches in flight (implies the "
                         "device-resident index and batched mode — batch "
                         "size defaults to 32 unless --batch is given; "
                         "0 = off)")
    ap.add_argument("--shards", type=int, default=0, metavar="N",
                    help="paper-index: serve the index sharded across N "
                         "data-parallel device shards (implies batched + "
                         "pipelined + resident; run under XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N for N "
                         "host devices; 0 = off)")
    ap.add_argument("--resident", action="store_true",
                    help="paper-index: stage the device-resident index "
                         "(source.ResidentPool) before serving")
    ap.add_argument("--fuse", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="paper-index: coarsen each batch's groups into "
                         "megagroup families — O(1) device programs per "
                         "batch (--no-fuse keeps one program per shape "
                         "signature; results are identical)")
    ap.add_argument("--warmup", action="store_true",
                    help="paper-index: AOT signature warmup — precompile "
                         "the fused family ladder before the timed run so "
                         "steady-state serving never compiles")
    ap.add_argument("--codec",
                    choices=["auto", "bitpack", "streamvbyte", "composite",
                             "fastpfor", "varint"],
                    default="fastpfor",
                    help="paper-index: posting-list codec family (auto = "
                         "the cost-model storage autotuner picks codec + "
                         "skip policy per list; DESIGN.md §2.13)")
    ap.add_argument("--mutate", type=int, default=0, metavar="N",
                    help="paper-index: live-corpus demo — apply N adds "
                         "(with a mid-stream seal) plus --delete-frac "
                         "tombstones to a segmented mutable index, then "
                         "serve the timed loop during a background merge "
                         "and differential-check against a rebuild "
                         "(DESIGN.md §2.14; implies batched mode)")
    ap.add_argument("--delete-frac", type=float, default=None, metavar="F",
                    help="paper-index: fraction of --mutate adds to "
                         "tombstone (default 0.1; requires --mutate)")
    ap.add_argument("--wal", default=None, metavar="DIR",
                    help="paper-index: durable mutable index — journal "
                         "every add/delete/seal to a write-ahead log in "
                         "DIR, checkpoint atomic snapshots, and end the "
                         "run with a crash-recovery differential "
                         "(implies --mutate; DESIGN.md §2.15)")
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="paper-index: deterministic fault injection — "
                         "comma-separated kind@point[:arg] rules, e.g. "
                         "'crash@wal.append.add:40' or "
                         "'transient@launch:0.05' (kinds: crash, torn, "
                         "transient, error, delay; see "
                         "repro.launch.faults; DESIGN.md §2.15)")
    ap.add_argument("--timeout-ms", type=float, default=None, metavar="MS",
                    help="paper-index: per-request deadline for --qps live "
                         "serving — requests still queued past the "
                         "deadline resolve as timed out, never hang")
    ap.add_argument("--qps", type=float, default=0.0, metavar="Q",
                    help="paper-index: open-loop live serving at offered "
                         "load Q through the continuous-batching server "
                         "(0 = offline batch mode; composes with "
                         "--mutate/--wal/--chaos/--timeout-ms)")
    ap.add_argument("--seed", type=int, default=0,
                    help="paper-index: seed for --chaos fault schedules "
                         "and --qps arrival gaps")
    ap.add_argument("--cache", action="store_true",
                    help="paper-index: serve with a DecodeCache and report "
                         "its hit rate")
    ap.add_argument("--shared-vocab", action="store_true",
                    help="paper-index: Zipf-shared query term ids "
                         "(realistic cache hit rates)")
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()
    if args.arch == "paper-index":
        return serve_index(args)
    spec = get_config(args.arch)
    if spec.family == "lm":
        return serve_lm(args, spec)
    if spec.family == "recsys":
        return serve_recsys(args, spec)
    raise SystemExit(f"no serving mode for family {spec.family}")


if __name__ == "__main__":
    main()
