"""Training launcher: ``--arch <id>`` selects an assigned architecture at its
*smoke-reduced* size for local runs (full sizes are dry-run-only on CPU).

  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
      --steps 100 --ckpt-dir /tmp/ck

On a real cluster this module is invoked once per host under
``jax.distributed.initialize()``; the mesh comes from launch.mesh and the
shardings from launch.cells — identical code paths to the dry-run.
"""

from __future__ import annotations

import argparse

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.data import graph_data, lm_data, recsys_data
from repro.optim import adamw
from repro.train import steps as train_steps
from repro.train.trainer import Trainer, TrainerConfig


def _lm_setup(cfg, batch, seq):
    from repro.models.transformer import init_params
    params = init_params(jax.random.PRNGKey(0), cfg)
    stream = lm_data.TokenStream(cfg.vocab, seed=0)

    def it():
        while True:
            b = stream.batch(batch, seq)
            yield {k: jnp.asarray(v) for k, v in b.items()}

    opt_cfg = adamw.AdamWConfig(lr=3e-3)
    return params, train_steps.make_lm_train_step(cfg, opt_cfg), it(), opt_cfg


def _gnn_setup(cfg, batch, _seq):
    from repro.models import gnn
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    g = graph_data.synthetic_graph(5000, 10, d_feat=cfg.d_feat,
                                   n_classes=cfg.n_classes)
    rng = np.random.default_rng(0)

    def it():
        while True:
            seeds = rng.integers(0, 5000, size=batch).astype(np.int32)
            yield {"feats": jnp.asarray(g["x"]),
                   "indptr": jnp.asarray(g["indptr"]),
                   "indices": jnp.asarray(g["indices"]),
                   "seeds": jnp.asarray(seeds),
                   "labels": jnp.asarray(g["labels"][seeds])}

    opt_cfg = adamw.AdamWConfig(lr=1e-2, weight_decay=0.0)
    return params, train_steps.make_gnn_train_step(
        cfg, "minibatch", opt_cfg, fanout=(10, 5)), it(), opt_cfg


def _recsys_setup(cfg, batch, _seq):
    from repro.models import recsys
    params = recsys.INIT[cfg.arch](jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    mk = {"din": recsys_data.din_batch, "sasrec": recsys_data.seq_batch,
          "bert4rec": recsys_data.bert4rec_batch,
          "mind": recsys_data.mind_batch}[cfg.arch]

    def it():
        while True:
            yield {k: jnp.asarray(v) for k, v in mk(rng, cfg, batch).items()}

    opt_cfg = adamw.AdamWConfig(lr=1e-3, weight_decay=0.0)
    return params, train_steps.make_recsys_train_step(cfg, opt_cfg), it(), \
        opt_cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    spec = get_config(args.arch)
    cfg = spec.smoke_config()
    setup = {"lm": _lm_setup, "gnn": _gnn_setup,
             "recsys": _recsys_setup}[spec.family]
    params, step, data, opt_cfg = setup(cfg, args.batch, args.seq)
    trainer = Trainer(step, params, adamw.init(params, opt_cfg), data,
                      TrainerConfig(total_steps=args.steps,
                                    ckpt_every=args.ckpt_every,
                                    ckpt_dir=args.ckpt_dir, log_every=10))
    trainer.install_preemption_handler()
    res = trainer.run(start_step=trainer.try_restore())
    print(f"[train] {args.arch}: {res}")


if __name__ == "__main__":
    main()
