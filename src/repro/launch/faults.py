"""Deterministic fault-injection harness (DESIGN.md §2.15).

The durability layer (``index/durability.py``), the background merge, and
the continuous-batching server all have failure seams that are unreachable
from a normal test run: a process can die between a WAL append and the
in-memory apply, a snapshot can crash between its tmp write and the atomic
rename, and the schedule/launch seam can raise transient runtime errors
under load.  This module makes every one of those seams *drivable*: code
under test calls ``injector.fire("<point>")`` at each named point, and a
seeded, deterministic schedule decides whether that call returns quietly,
raises a simulated crash, tears the write in half, raises a retryable
transient, or just sleeps.

Fault kinds:

  crash      raise ``InjectedCrash`` at the Nth hit of the point — the
             test treats it as process death and drives recovery.
  torn       WAL-append points only: the caller is told to write a
             *partial* record frame and then raise ``InjectedCrash`` —
             the torn-tail case recovery must truncate, never replay.
  transient  raise ``TransientFault`` (the retryable class the server's
             bounded-backoff retry loop catches).  ``arg >= 1`` fires on
             the first N hits (deterministic tests); ``arg < 1`` fires
             with that probability per hit from the injector's seeded RNG.
  error      raise ``InjectedError`` — a non-retryable failure; the
             server must resolve the affected requests as errors, never
             hang their awaiters.
  delay      sleep ``arg`` milliseconds at the point (slow-seam
             simulation for deadline/timeout tests).

Registered points (``CRASH_POINTS`` is the fault-matrix CI sweep):

  wal.append.add / wal.append.delete / wal.append.seal
             fired by ``DurableLog.append`` before the record bytes land
  snapshot.write / snapshot.rename
             fired by ``DurableLog.checkpoint`` before any tmp file is
             written / between the tmp manifest write and the atomic
             rename (the manifest-last discipline's critical instant)
  merge.<stage>
             the six ``MutableIndex.merge`` phase boundaries, reached by
             passing ``injector.merge_hook()`` as the merge hook
  launch / collect
             the server's schedule+launch seam (event-loop thread) and
             collect seam (executor thread) — transient/error/delay only

Spec strings (``serve.py --chaos``, bench, CI) are comma-separated
``kind@point[:arg]`` clauses::

  crash@merge.build            crash at the first merge build boundary
  crash@wal.append.add:10      crash at the 10th WAL add append
  torn@wal.append.add:5        tear the 5th add record mid-frame
  transient@launch:0.01        1% transient faults at the launch seam
  transient@launch:3           transient faults on the first 3 launches
  delay@launch:5               5 ms of injected latency per launch

Everything is deterministic given (spec, seed): counted rules keep their
own countdown, probabilistic rules draw from one seeded RNG in fire order.
"""

from __future__ import annotations

import dataclasses
import os
import random
import time


class InjectedCrash(RuntimeError):
    """A simulated process death at a named crash point.  Test harnesses
    catch it where a supervisor would observe the exit, then recover."""


class TransientFault(RuntimeError):
    """A retryable failure from the schedule/launch seam (the class the
    server's bounded exponential-backoff retry loop catches)."""


class InjectedError(RuntimeError):
    """A non-retryable injected failure: the server must resolve the
    affected requests as explicit errors, not retry and not hang."""


MERGE_STAGES = ("snapshot", "decode", "build", "stage", "warm", "swap")

WAL_APPEND_POINTS = ("wal.append.add", "wal.append.delete",
                     "wal.append.seal")
SNAPSHOT_POINTS = ("snapshot.write", "snapshot.rename")
MERGE_POINTS = tuple(f"merge.{s}" for s in MERGE_STAGES)

# the fault-matrix sweep: every point at which a crash must leave the
# durable directory recoverable to a byte-identical serving state
CRASH_POINTS = WAL_APPEND_POINTS + SNAPSHOT_POINTS + MERGE_POINTS

# points whose write can be torn mid-frame (WAL record appends)
TEAR_POINTS = WAL_APPEND_POINTS

# server seams: transient/error/delay make sense here, a "crash" does not
# (the serving loop is the supervisor — it must degrade, not die)
SEAM_POINTS = ("launch", "collect")

KNOWN_POINTS = CRASH_POINTS + SEAM_POINTS


@dataclasses.dataclass
class _Rule:
    kind: str          # crash | torn | transient | error | delay
    point: str
    arg: float         # occurrence count / probability / delay-ms
    remaining: int     # countdown for counted rules (-1 = unbounded)


class FaultInjector:
    """One deterministic fault schedule: parsed from a spec string (or
    armed programmatically), shared across the WAL, the merge hook and
    the server seams so a single ``--chaos`` flag drives them all."""

    def __init__(self, spec: str = "", seed: "int | None" = None):
        if seed is None:
            # CI exports a commit-derived REPRO_CHAOS_SEED so every push
            # explores a different probabilistic schedule, reproducibly
            try:
                seed = int(os.environ.get("REPRO_CHAOS_SEED", "0"))
            except ValueError:
                seed = 0
        self.seed = seed
        self._rng = random.Random(seed)
        self._rules: list[_Rule] = []
        self.hits: dict[str, int] = {}
        self.fired: list[tuple[str, str]] = []
        if spec:
            for clause in spec.split(","):
                clause = clause.strip()
                if not clause:
                    continue
                try:
                    kind, rest = clause.split("@", 1)
                except ValueError:
                    raise ValueError(
                        f"bad chaos clause {clause!r}: want kind@point[:arg]")
                point, _, arg = rest.partition(":")
                self.arm(kind.strip(), point.strip(),
                         float(arg) if arg else 1.0)

    # -- arming ------------------------------------------------------------

    def arm(self, kind: str, point: str, arg: float = 1.0) -> None:
        """Add one rule.  Counted kinds (crash/torn; transient/error with
        ``arg >= 1``) count hits *from now*, so arming mid-run is exact."""
        if point not in KNOWN_POINTS:
            raise ValueError(f"unknown fault point {point!r} "
                             f"(registered: {', '.join(KNOWN_POINTS)})")
        if kind in ("crash", "torn"):
            if kind == "torn" and point not in TEAR_POINTS:
                raise ValueError(f"{point!r} is not tearable "
                                 f"(tear points: {', '.join(TEAR_POINTS)})")
            if point in SEAM_POINTS:
                raise ValueError(
                    f"{point!r} is a server seam — use transient/error/"
                    f"delay (the serving loop must degrade, not die)")
            self._rules.append(_Rule(kind, point, arg, max(int(arg), 1)))
        elif kind in ("transient", "error"):
            rem = int(arg) if arg >= 1 else -1
            self._rules.append(_Rule(kind, point, arg, rem))
        elif kind == "delay":
            self._rules.append(_Rule(kind, point, arg, -1))
        else:
            raise ValueError(f"unknown fault kind {kind!r} "
                             f"(crash, torn, transient, error, delay)")

    def disarm_all(self) -> None:
        """Drop every pending rule (a test's recovery path must not be
        re-crashed by rules armed for the run that just 'died')."""
        self._rules.clear()

    @property
    def armed(self) -> int:
        return len(self._rules)

    # -- the injection point ----------------------------------------------

    def fire(self, point: str) -> str | None:
        """Called by instrumented code at a named point.  Raises the
        scheduled fault, sleeps the scheduled delay, or returns ``"torn"``
        to tell a WAL append to write a partial frame and then raise.
        Returns None when nothing is scheduled."""
        self.hits[point] = self.hits.get(point, 0) + 1
        action = None
        for rule in list(self._rules):
            if rule.point != point:
                continue
            if rule.kind in ("crash", "torn"):
                rule.remaining -= 1
                if rule.remaining > 0:
                    continue
                self._rules.remove(rule)
                self.fired.append((rule.kind, point))
                if rule.kind == "torn":
                    action = "torn"      # the caller tears, then raises
                else:
                    raise InjectedCrash(f"injected crash at {point}")
            elif rule.kind in ("transient", "error"):
                if rule.remaining == 0:
                    continue
                if rule.remaining > 0:
                    rule.remaining -= 1
                elif self._rng.random() >= rule.arg:
                    continue
                self.fired.append((rule.kind, point))
                if rule.kind == "transient":
                    raise TransientFault(f"injected transient at {point}")
                raise InjectedError(f"injected error at {point}")
            elif rule.kind == "delay":
                self.fired.append(("delay", point))
                time.sleep(rule.arg * 1e-3)
        return action

    # -- adapters ----------------------------------------------------------

    def merge_hook(self, inner=None):
        """A ``MutableIndex.merge(hook=...)`` adapter firing the
        ``merge.<stage>`` points (optionally chaining an existing hook)."""
        def hook(stage: str):
            if inner is not None:
                inner(stage)
            self.fire(f"merge.{stage}")
        return hook

    def counts(self) -> dict[str, int]:
        """Fired-fault totals by ``kind@point`` — the chaos run report."""
        out: dict[str, int] = {}
        for kind, point in self.fired:
            key = f"{kind}@{point}"
            out[key] = out.get(key, 0) + 1
        return out
