"""Dry-run cell builder: (arch × shape × mesh) → a LoweringCell with the step
function, ShapeDtypeStruct inputs (no allocation) and in_shardings.

This is the single source of truth for how every one of the 40 assigned cells
(+ paper-index bonus cells) is sharded on the production mesh (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchSpec
from repro.distributed import sharding as shd
from repro.models import gnn as gnn_lib
from repro.models import recsys as recsys_lib
from repro.models import transformer as tfm
from repro.optim import adamw
from repro.serve import steps as serve_steps
from repro.train import steps as train_steps


@dataclasses.dataclass
class LoweringCell:
    arch_id: str
    shape_name: str
    fn: Callable
    in_specs: tuple          # pytree of ShapeDtypeStruct
    in_shardings: tuple      # matching pytree of NamedSharding
    static_meta: dict        # model_flops etc. for the roofline
    out_shardings: object = None   # None → XLA default propagation


def _ns(mesh, *spec):
    return NamedSharding(mesh, P(*spec))


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _pad_to(n: int, mult: int) -> int:
    return int(np.ceil(n / mult) * mult)


def _mesh_size(mesh, axes) -> int:
    s = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        s *= mesh.shape[a]
    return s


def _set_lm_hints(mesh, seq_parallel: bool = True):
    dp = shd.batch_axes(mesh)
    shd.set_hint_rules({
        # Megatron-style sequence parallelism: residuals shard S over 'model'
        # (cuts saved-activation memory ~model_size×; EXPERIMENTS §Perf)
        "act_resid": P(dp, "model" if seq_parallel else None, None),
        "act_qkv": P(dp, None, "model", None),
        "kv_cache": P(dp, "model", None, None),  # S-sharded, matches decode
        "act_kv": P(dp, None, None, None),       # explicit SP→replicated AG
        "moe_buffer": P("model", dp, None),
        "logits": P(dp, None, "model"),
    }, mesh)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_param_shardings(cfg, mesh):
    pshape = jax.eval_shape(lambda: tfm.init_params(jax.random.PRNGKey(0),
                                                    cfg))
    rule = lambda path, shape, m: shd.lm_param_spec(path, shape, m,
                                                    cfg.sharding_preset)
    return pshape, shd.tree_param_shardings(pshape, mesh, rule)


def _lm_cell(spec: ArchSpec, shape_name: str, mesh: Mesh) -> LoweringCell:
    cfg = spec.config
    sh = spec.shapes[shape_name]
    dp = shd.batch_axes(mesh)
    _set_lm_hints(mesh)
    pshape, pshard = _lm_param_shardings(cfg, mesh)
    B, S = sh["global_batch"], sh["seq_len"]
    tok_shard = _ns(mesh, dp) if B % _mesh_size(mesh, dp) == 0 \
        else _ns(mesh)
    model_flops = 6 * cfg.active_param_count() * B * S

    if sh["kind"] == "train":
        opt_cfg = adamw.AdamWConfig(
            moment_dtype="bfloat16" if cfg.param_dtype == "bfloat16"
            else "float32")
        oshape = jax.eval_shape(lambda p: adamw.init(p, opt_cfg), pshape)
        oshard = {"mu": pshard, "nu": pshard, "step": _ns(mesh)}
        fn = train_steps.make_lm_train_step(cfg, opt_cfg)
        batch = {"tokens": _sds((B, S), jnp.int32),
                 "labels": _sds((B, S), jnp.int32)}
        bshard = {"tokens": _ns(mesh, dp, None), "labels": _ns(mesh, dp, None)}
        rng = _sds((2,), jnp.uint32)
        return LoweringCell(
            spec.arch_id, shape_name, fn,
            (pshape, oshape, batch, rng),
            (pshard, oshard, bshard, _ns(mesh)),
            {"model_flops": model_flops})

    if sh["kind"] == "prefill":
        fn = serve_steps.make_prefill_step(cfg)
        tokens = _sds((B, S), jnp.int32)
        return LoweringCell(
            spec.arch_id, shape_name, fn, (pshape, tokens),
            (pshard, _ns(mesh, dp, None)),
            {"model_flops": 2 * cfg.active_param_count() * B * S})

    if sh["kind"] == "decode":
        fn = serve_steps.make_decode_step(cfg)
        cdt = jnp.dtype(cfg.compute_dtype)
        cache_shape = (cfg.n_layers, B, S, cfg.n_kv, cfg.hd)
        cache = {"k": _sds(cache_shape, cdt), "v": _sds(cache_shape, cdt)}
        # decode sharding: batch over dp when divisible, KV length over
        # 'model' (flash-decoding-style split-K; DESIGN.md §4)
        bdim = dp if B % _mesh_size(mesh, dp) == 0 else None
        sdims = "model"
        if B == 1 and S % _mesh_size(mesh, dp + ("model",)) == 0:
            sdims = dp + ("model",)          # long-context: whole-mesh SP
        # flash-decoding hints: q replicated over 'model', logits S-sharded
        shd.set_hint_rules({
            "decode_q": P(bdim, None, None, None),
            "decode_logits": P(bdim, None, None, sdims),
            "moe_buffer": P("model", dp, None),
        }, mesh)
        cspec = _ns(mesh, None, bdim, sdims, None, None)
        cshard = {"k": cspec, "v": cspec}
        token = _sds((B,), jnp.int32)
        pos = _sds((), jnp.int32)
        # out_shardings pinned: without this XLA may replicate the returned
        # updated cache — an all-gather of the entire KV cache per decoded
        # token (measured 4.3 s of collectives on phi3 long_500k;
        # EXPERIMENTS §Perf iteration 8)
        out_sh = (_ns(mesh, bdim, "model" if cfg.vocab %
                      mesh.shape["model"] == 0 else None), cshard)
        return LoweringCell(
            spec.arch_id, shape_name, fn,
            (pshape, cache, token, pos),
            (pshard, cshard, _ns(mesh, bdim), _ns(mesh)),
            {"model_flops": 2 * cfg.active_param_count() * B
             + 2 * 2 * cfg.n_layers * B * S * cfg.n_kv * cfg.hd},
            out_shardings=out_sh)
    raise ValueError(sh["kind"])


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _gnn_cell(spec: ArchSpec, shape_name: str, mesh: Mesh) -> LoweringCell:
    sh = spec.shapes[shape_name]
    dp = shd.batch_axes(mesh)
    dp_all = dp + ("model",)
    dpn = _mesh_size(mesh, dp_all)
    shd.set_hint_rules({}, mesh)
    import dataclasses as dc
    cfg = dc.replace(spec.config, d_feat=sh["d_feat"],
                     n_classes=sh["n_classes"],
                     task="graph" if sh["kind"] == "molecule" else "node")
    pshape = jax.eval_shape(
        lambda: gnn_lib.init_params(jax.random.PRNGKey(0), cfg))
    pshard = shd.tree_param_shardings(pshape, mesh, shd.gnn_param_spec)
    opt_cfg = adamw.AdamWConfig(weight_decay=0.0)
    oshape = jax.eval_shape(lambda p: adamw.init(p, opt_cfg), pshape)
    oshard = {"mu": pshard, "nu": pshard, "step": _ns(mesh)}
    rng = _sds((2,), jnp.uint32)

    if sh["kind"] == "full":
        N = _pad_to(sh["n_nodes"], dpn)
        E = _pad_to(sh["n_edges"], dpn)
        fn = train_steps.make_gnn_train_step(cfg, "full", opt_cfg)
        batch = {"x": _sds((N, sh["d_feat"]), jnp.float32),
                 "edge_src": _sds((E,), jnp.int32),
                 "edge_dst": _sds((E,), jnp.int32),
                 "labels": _sds((N,), jnp.int32),
                 "train_mask": _sds((N,), jnp.bool_)}
        bshard = {"x": _ns(mesh, dp, None),
                  "edge_src": _ns(mesh, dp_all),
                  "edge_dst": _ns(mesh, dp_all),
                  "labels": _ns(mesh, dp), "train_mask": _ns(mesh, dp)}
        flops = 0
        for i in range(cfg.n_layers):
            din = sh["d_feat"] if i == 0 else cfg.d_hidden
            flops += 2 * N * din * cfg.d_hidden * 2 + 2 * E * din
        return LoweringCell(spec.arch_id, shape_name, fn,
                            (pshape, oshape, batch, rng),
                            (pshard, oshard, bshard, _ns(mesh)),
                            {"model_flops": 3 * flops})

    if sh["kind"] == "minibatch":
        N = _pad_to(sh["n_nodes"], dpn)
        E = _pad_to(sh["n_edges"], dpn)
        Bn = sh["batch_nodes"]
        f1, f2 = sh["fanout"]
        fn = train_steps.make_gnn_train_step(cfg, "minibatch", opt_cfg,
                                             fanout=sh["fanout"])
        batch = {"feats": _sds((N, sh["d_feat"]), jnp.float32),
                 "indptr": _sds((N + 1,), jnp.int32),
                 "indices": _sds((E,), jnp.int32),
                 "seeds": _sds((Bn,), jnp.int32),
                 "labels": _sds((Bn,), jnp.int32)}
        bshard = {"feats": _ns(mesh, dp, None), "indptr": _ns(mesh),
                  "indices": _ns(mesh, dp_all),
                  "seeds": _ns(mesh, dp), "labels": _ns(mesh, dp)}
        n_sub = Bn * (1 + f1 + f1 * f2)
        flops = 2 * n_sub * sh["d_feat"] * cfg.d_hidden * 2 * 3
        return LoweringCell(spec.arch_id, shape_name, fn,
                            (pshape, oshape, batch, rng),
                            (pshard, oshard, bshard, _ns(mesh)),
                            {"model_flops": 3 * flops})

    if sh["kind"] == "molecule":
        G = sh["batch"]
        fn = train_steps.make_gnn_train_step(cfg, "molecule", opt_cfg)
        batch = {"x": _sds((G, sh["n_nodes"], sh["d_feat"]), jnp.float32),
                 "edge_src": _sds((G, sh["n_edges"]), jnp.int32),
                 "edge_dst": _sds((G, sh["n_edges"]), jnp.int32),
                 "node_mask": _sds((G, sh["n_nodes"]), jnp.float32),
                 "targets": _sds((G,), jnp.float32)}
        bshard = {k: _ns(mesh, dp) if v.ndim == 1
                  else _ns(mesh, dp, *([None] * (v.ndim - 1)))
                  for k, v in batch.items()}
        flops = 2 * G * sh["n_nodes"] * sh["d_feat"] * cfg.d_hidden * 2 * 2
        return LoweringCell(spec.arch_id, shape_name, fn,
                            (pshape, oshape, batch, rng),
                            (pshard, oshard, bshard, _ns(mesh)),
                            {"model_flops": 3 * flops})
    raise ValueError(sh["kind"])


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------

def _recsys_batch_specs(cfg, arch: str, B: int, mesh):
    dp = shd.batch_axes(mesh)
    L = cfg.seq_len
    bspec = _ns(mesh, dp) if B % _mesh_size(mesh, dp) == 0 else _ns(mesh)
    b2 = _ns(mesh, dp, None) if B % _mesh_size(mesh, dp) == 0 else _ns(mesh)
    if arch == "din":
        specs = {"hist_items": _sds((B, L), jnp.int32),
                 "hist_cates": _sds((B, L), jnp.int32),
                 "hist_mask": _sds((B, L), jnp.float32),
                 "target_item": _sds((B,), jnp.int32),
                 "target_cate": _sds((B,), jnp.int32),
                 "labels": _sds((B,), jnp.int32)}
    elif arch == "sasrec":
        specs = {"hist": _sds((B, L), jnp.int32),
                 "pos": _sds((B, L), jnp.int32),
                 "neg": _sds((B, L), jnp.int32),
                 "hist_mask": _sds((B, L), jnp.float32),
                 "target_item": _sds((B,), jnp.int32)}
    elif arch == "bert4rec":
        M = 8
        specs = {"hist": _sds((B, L), jnp.int32),
                 "hist_mask": _sds((B, L), jnp.float32),
                 "mask_pos": _sds((B, M), jnp.int32),
                 "cands": _sds((B, M, 1 + cfg.n_neg), jnp.int32),
                 "mask_valid": _sds((B, M), jnp.float32),
                 "target_item": _sds((B,), jnp.int32)}
    else:  # mind
        specs = {"hist": _sds((B, L), jnp.int32),
                 "hist_mask": _sds((B, L), jnp.float32),
                 "cands": _sds((B, 1 + cfg.n_neg), jnp.int32),
                 "target_item": _sds((B,), jnp.int32)}
    shards = {k: bspec if v.ndim == 1
              else (_ns(mesh, dp, *([None] * (v.ndim - 1)))
                    if B % _mesh_size(mesh, dp) == 0
                    else _ns(mesh))
              for k, v in specs.items()}
    return specs, shards


def _recsys_cell(spec: ArchSpec, shape_name: str, mesh: Mesh) -> LoweringCell:
    cfg = spec.config
    sh = spec.shapes[shape_name]
    dp = shd.batch_axes(mesh)
    shd.set_hint_rules({}, mesh)
    pshape = jax.eval_shape(
        lambda: recsys_lib.INIT[cfg.arch](jax.random.PRNGKey(0), cfg))
    pshard = shd.tree_param_shardings(pshape, mesh, shd.recsys_param_spec)
    rng = _sds((2,), jnp.uint32)
    # rough dense-compute model flops (embedding gathers excluded)
    d = cfg.embed_dim

    if sh["kind"] == "train":
        B = sh["batch"]
        opt_cfg = adamw.AdamWConfig(weight_decay=0.0)
        oshape = jax.eval_shape(lambda p: adamw.init(p, opt_cfg), pshape)
        oshard = {"mu": pshard, "nu": pshard, "step": _ns(mesh)}
        fn = train_steps.make_recsys_train_step(cfg, opt_cfg)
        batch, bshard = _recsys_batch_specs(cfg, cfg.arch, B, mesh)
        flops = _recsys_flops(cfg, B)
        return LoweringCell(spec.arch_id, shape_name, fn,
                            (pshape, oshape, batch, rng),
                            (pshard, oshard, bshard, _ns(mesh)),
                            {"model_flops": 3 * flops})

    if sh["kind"] == "score":
        B = sh["batch"]
        fn = serve_steps.make_recsys_score_step(cfg)
        batch, bshard = _recsys_batch_specs(cfg, cfg.arch, B, mesh)
        return LoweringCell(spec.arch_id, shape_name, fn,
                            (pshape, batch), (pshard, bshard),
                            {"model_flops": _recsys_flops(cfg, B)})

    if sh["kind"] == "retrieval":
        C = sh["n_candidates"]
        fn = serve_steps.make_recsys_retrieval_step(cfg, sh["top_k"])
        L = cfg.seq_len
        dp_all = dp + ("model",)
        cspec = _ns(mesh, dp_all) if C % _mesh_size(mesh, dp_all) == 0 \
            else _ns(mesh)
        batch = {"hist": _sds((L,), jnp.int32),
                 "hist_items": _sds((L,), jnp.int32),
                 "hist_cates": _sds((L,), jnp.int32),
                 "hist_mask": _sds((L,), jnp.float32),
                 "cand_items": _sds((C,), jnp.int32),
                 "cand_cates": _sds((C,), jnp.int32)}
        bshard = {"hist": _ns(mesh), "hist_items": _ns(mesh),
                  "hist_cates": _ns(mesh), "hist_mask": _ns(mesh),
                  "cand_items": cspec, "cand_cates": cspec}
        if cfg.arch == "din":
            flops = 2 * C * (cfg.seq_len * 8 * d * 80 + 3 * 2 * d * 200)
        else:
            flops = 2 * C * d
        return LoweringCell(spec.arch_id, shape_name, fn,
                            (pshape, batch), (pshard, bshard),
                            {"model_flops": flops})
    raise ValueError(sh["kind"])


def _recsys_flops(cfg, B: int) -> int:
    d, L = cfg.embed_dim, cfg.seq_len
    if cfg.arch == "din":
        att = L * (8 * d * 80 + 80 * 40 + 40)
        mlp = 6 * d * 200 + 200 * 80 + 80
        return 2 * B * (att + mlp)
    if cfg.arch in ("sasrec", "bert4rec"):
        dff = d if cfg.arch == "sasrec" else 4 * d
        per_block = 4 * d * d * L + 2 * L * L * d + 2 * L * d * dff
        return 2 * B * cfg.n_blocks * per_block
    # mind: routing iterations + sampled softmax
    return 2 * B * (L * d * d + cfg.capsule_iters * L * cfg.n_interests * d
                    + (1 + cfg.n_neg) * cfg.n_interests * d)


# ---------------------------------------------------------------------------
# paper-index cells (bonus)
# ---------------------------------------------------------------------------

def _index_cell(spec: ArchSpec, shape_name: str, mesh: Mesh) -> LoweringCell:
    from repro.core import bitpack as bp
    from repro.core import intersect as its
    sh = spec.shapes[shape_name]
    dp = shd.batch_axes(mesh)
    shd.set_hint_rules({}, mesh)
    if sh["kind"] == "svs":
        Q, M, N = sh["n_queries"], sh["m"], sh["n"]

        def fn(r_batch, f_batch):
            mask = jax.vmap(its.intersect_gallop)(r_batch, f_batch)
            vals, cnt = jax.vmap(its.compact)(r_batch, mask)
            return vals, cnt

        ins = (_sds((Q, M), jnp.int32), _sds((Q, N), jnp.int32))
        shards = (_ns(mesh, dp + ("model",), None),
                  _ns(mesh, dp + ("model",), None))
        return LoweringCell(spec.arch_id, shape_name, fn, ins, shards,
                            {"model_flops": Q * M * int(np.log2(N))})
    if sh["kind"] == "decode_lists":
        K = sh["n_blocks"]

        def fn(flat_words, widths, offsets, seeds):
            return bp.decode_integrated(flat_words, widths, offsets, seeds,
                                        "d1", 32)

        ins = (_sds((K * 32, 128), jnp.uint32), _sds((K,), jnp.int32),
               _sds((K,), jnp.int32), _sds((K,), jnp.uint32))
        shards = (_ns(mesh, dp + ("model",), None), _ns(mesh, dp),
                  _ns(mesh, dp), _ns(mesh, dp))
        return LoweringCell(spec.arch_id, shape_name, fn, ins, shards,
                            {"model_flops": K * 4096 * 8})
    raise ValueError(sh["kind"])


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------

_BUILDERS = {"lm": _lm_cell, "gnn": _gnn_cell, "recsys": _recsys_cell,
             "index": _index_cell}


def build_cell(spec: ArchSpec, shape_name: str, mesh: Mesh) -> LoweringCell:
    if shape_name not in spec.shapes:
        raise KeyError(f"{spec.arch_id} has no shape {shape_name!r}")
    return _BUILDERS[spec.family](spec, shape_name, mesh)
