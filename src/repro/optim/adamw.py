"""AdamW with global-norm clipping — pure pytree ops, sharding-transparent
(optimizer state inherits/extends parameter shardings under pjit)."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    moment_dtype: str = "float32"   # bf16 for ~1T-param models (DESIGN §4)


def init(params, cfg: AdamWConfig):
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(grads, state, params, cfg: AdamWConfig, lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g
        nu32 = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * g * g
        upd = (mu32 / bc1) / (jnp.sqrt(nu32 / bc2) + cfg.eps)
        if p.ndim >= 2:                      # no decay on norms/biases
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * upd
        return (newp.astype(p.dtype), mu32.astype(mu.dtype),
                nu32.astype(nu.dtype))

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    flat, treedef = jax.tree_util.tree_flatten(
        out, is_leaf=lambda x: isinstance(x, tuple))
    newp = jax.tree_util.tree_unflatten(treedef, [x[0] for x in flat])
    mu = jax.tree_util.tree_unflatten(treedef, [x[1] for x in flat])
    nu = jax.tree_util.tree_unflatten(treedef, [x[2] for x in flat])
    return newp, {"mu": mu, "nu": nu, "step": step}, {"grad_norm": gnorm}


def cosine_schedule(step, base_lr=1.0, warmup: int = 100,
                    total: int = 10000, min_frac: float = 0.1):
    t = step.astype(jnp.float32)
    warm = t / jnp.maximum(warmup, 1)
    prog = jnp.clip((t - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * jnp.where(t < warmup, warm, cos)
