"""bert4rec [recsys] embed_dim=64 n_blocks=2 n_heads=2 seq_len=200
interaction=bidir-seq [arXiv:1904.06690; paper]."""
from repro.configs.base import ArchSpec, register
from repro.models.recsys import RecsysConfig
from repro.configs.recsys_shapes import RECSYS_SHAPES

SPEC = register(ArchSpec(
    arch_id="bert4rec",
    family="recsys",
    config=RecsysConfig(
        name="bert4rec", arch="bert4rec", embed_dim=64, n_blocks=2,
        n_heads=2, seq_len=200, n_items=1 << 20),
    shapes=dict(RECSYS_SHAPES),
    source="arXiv:1904.06690; paper",
))
