"""graphsage-reddit [gnn] n_layers=2 d_hidden=128 aggregator=mean
sample_sizes=25-10 [arXiv:1706.02216; paper].

Each shape cell carries its own graph: cora (full_graph_sm), reddit
(minibatch_lg; d_feat=602, 41 classes), ogbn-products (full-batch-large),
batched molecules."""
from repro.configs.base import ArchSpec, register
from repro.models.gnn import GNNConfig

SPEC = register(ArchSpec(
    arch_id="graphsage-reddit",
    family="gnn",
    config=GNNConfig(
        name="graphsage-reddit", n_layers=2, d_hidden=128,
        aggregator="mean", sample_sizes=(25, 10), d_feat=602, n_classes=41),
    shapes={
        "full_graph_sm": {"kind": "full", "n_nodes": 2708, "n_edges": 10556,
                          "d_feat": 1433, "n_classes": 7},
        "minibatch_lg": {"kind": "minibatch", "n_nodes": 232965,
                         "n_edges": 114615892, "batch_nodes": 1024,
                         "fanout": (15, 10), "d_feat": 602, "n_classes": 41},
        "ogb_products": {"kind": "full", "n_nodes": 2449029,
                         "n_edges": 61859140, "d_feat": 100, "n_classes": 47},
        "molecule": {"kind": "molecule", "n_nodes": 30, "n_edges": 64,
                     "batch": 128, "d_feat": 32, "n_classes": 1},
    },
    source="arXiv:1706.02216; paper",
))
