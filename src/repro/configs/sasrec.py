"""sasrec [recsys] embed_dim=50 n_blocks=2 n_heads=1 seq_len=50
interaction=self-attn-seq [arXiv:1808.09781; paper]."""
from repro.configs.base import ArchSpec, register
from repro.models.recsys import RecsysConfig
from repro.configs.recsys_shapes import RECSYS_SHAPES

SPEC = register(ArchSpec(
    arch_id="sasrec",
    family="recsys",
    config=RecsysConfig(
        name="sasrec", arch="sasrec", embed_dim=50, n_blocks=2, n_heads=1,
        seq_len=50, n_items=1 << 20),
    shapes=dict(RECSYS_SHAPES),
    source="arXiv:1808.09781; paper",
))
