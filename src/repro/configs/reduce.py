"""Reduced same-family configs for CPU smoke tests (the FULL configs are
exercised only via the dry-run)."""
import dataclasses


def reduced(spec):
    if spec.family == "lm":
        c = spec.config
        return dataclasses.replace(
            c, n_layers=2, d_model=64, n_heads=4,
            n_kv=4 if c.n_kv == c.n_heads else 2, d_ff=128, vocab=512,
            head_dim=16, n_experts=min(c.n_experts, 8) if c.is_moe else 0,
            top_k=min(c.top_k, 2) if c.is_moe else 0,
            param_dtype="float32", remat="none", full_attn_max_seq=256,
            attn_chunk=64)
    if spec.family == "gnn":
        c = spec.config
        return dataclasses.replace(c, d_hidden=32, d_feat=16, n_classes=8)
    if spec.family == "recsys":
        c = spec.config
        return dataclasses.replace(c, n_items=1024, n_cates=64,
                                   seq_len=16, n_neg=7)
    return spec.config
