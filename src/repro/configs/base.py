"""Architecture registry: every assigned arch is a selectable config
(``--arch <id>``), each paired with its own input-shape set (the 40 dry-run
cells), plus the paper's own index/serving 'architecture'."""

from __future__ import annotations

import dataclasses
from typing import Any

_REGISTRY: dict[str, "ArchSpec"] = {}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                 # 'lm' | 'gnn' | 'recsys' | 'index'
    config: Any                 # LMConfig / GNNConfig / RecsysConfig / dict
    shapes: dict[str, dict]     # shape name → shape params
    source: str = ""            # citation tag from the assignment

    def smoke_config(self):
        """Reduced same-family config for CPU smoke tests."""
        from repro.configs import reduce as reduce_lib
        return reduce_lib.reduced(self)


def register(spec: ArchSpec) -> ArchSpec:
    _REGISTRY[spec.arch_id] = spec
    return spec


def get_config(arch_id: str) -> ArchSpec:
    _ensure_loaded()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def all_arch_ids() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_loaded = False


def _ensure_loaded():
    # a real flag, not `if _REGISTRY`: importing any single config module
    # directly (e.g. paper_index for DEFAULT_COST_TABLE) pre-registers one
    # arch, which must not short-circuit loading the rest
    global _loaded
    if _loaded:
        return
    _loaded = True
    from repro.configs import (  # noqa: F401
        gemma_7b, phi3_medium_14b, internlm2_1_8b, granite_moe_1b, kimi_k2,
        graphsage_reddit, mind, sasrec, din, bert4rec, paper_index)


# Canonical LM shape set (shared by all 5 LM archs)
LM_SHAPES = {
    "train_4k": {"kind": "train", "seq_len": 4096, "global_batch": 256},
    "prefill_32k": {"kind": "prefill", "seq_len": 32768, "global_batch": 32},
    "decode_32k": {"kind": "decode", "seq_len": 32768, "global_batch": 128},
    "long_500k": {"kind": "decode", "seq_len": 524288, "global_batch": 1},
}
