"""mind [recsys] embed_dim=64 n_interests=4 capsule_iters=3
interaction=multi-interest [arXiv:1904.08030; unverified]."""
from repro.configs.base import ArchSpec, register
from repro.models.recsys import RecsysConfig
from repro.configs.recsys_shapes import RECSYS_SHAPES

SPEC = register(ArchSpec(
    arch_id="mind",
    family="recsys",
    config=RecsysConfig(
        name="mind", arch="mind", embed_dim=64, n_interests=4,
        capsule_iters=3, seq_len=50, n_items=1 << 23, n_neg=127),
    shapes=dict(RECSYS_SHAPES),
    source="arXiv:1904.08030; unverified",
))
