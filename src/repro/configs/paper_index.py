"""The paper's own 'architecture': the compressed-posting-list conjunctive
query engine (HYB+M2, SvS, SIMD intersection) — bonus dry-run cells beyond
the 40 assigned (batched galloping intersection sharded over the mesh)."""
from repro.configs.base import ArchSpec, register

SPEC = register(ArchSpec(
    arch_id="paper-index",
    family="index",
    config={"codec": "bp-d1", "B": 16, "n_docs": 1 << 22},
    shapes={
        "svs_batch": {"kind": "svs", "n_queries": 4096, "m": 4096,
                      "n": 1 << 20},
        "decode_bulk": {"kind": "decode_lists", "n_blocks": 8192},
    },
    source="Lemire, Boytsov, Kurz 2014 (this paper)",
))
