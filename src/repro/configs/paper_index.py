"""The paper's own 'architecture': the compressed-posting-list conjunctive
query engine (HYB+M2, SvS, SIMD intersection) — bonus dry-run cells beyond
the 40 assigned (batched galloping intersection sharded over the mesh)."""
from repro.configs.base import ArchSpec, register

SPEC = register(ArchSpec(
    arch_id="paper-index",
    family="index",
    config={"codec": "bp-d1", "B": 16, "n_docs": 1 << 22},
    shapes={
        "svs_batch": {"kind": "svs", "n_queries": 4096, "m": 4096,
                      "n": 1 << 20},
        "decode_bulk": {"kind": "decode_lists", "n_blocks": 8192},
    },
    source="Lemire, Boytsov, Kurz 2014 (this paper)",
))

# Default measured cost table for the build-time storage autotuner
# (builder.CostModel; DESIGN.md §2.13).  Regenerate with
# ``python -m benchmarks.bench_decode --json <path>`` and paste the
# ``decode_ns_per_int`` / ``gallop_ns_per_probe`` fields here — these
# numbers were measured on this container (kernel_mode=interpret; mean of
# the dense/sparse ClusterData profiles at 2^16 ints).  Varint is the
# deliberate scalar-loop baseline, which is why its per-int cost sits ~30x
# above the vectorized codecs.  Builds work without a local bench run
# because this table ships with the repo.
DEFAULT_COST_TABLE = {
    "decode_ns_per_int": {
        "bp-d1": 13.4,
        "bp8-d1": 13.4,
        "fastpfor-d1": 15.3,
        "streamvbyte-d1": 20.9,
        "composite-d1": 19.7,
        "varint": 562.4,
    },
    # fixed per-decode overhead (ns/list): device decodes pay a dispatch
    # before the first int lands; host decodes (varint, composite tail)
    # do not — this term is what hands short lists to composite on
    # *measured* wall clock (builder._decode_cost derives composite from
    # its bp8-head + varint-tail parts, so no entry is needed here).
    "dispatch_ns_per_list": {
        "bp-d1": 245700.0,
        "bp8-d1": 215100.0,
        "fastpfor-d1": 253900.0,
        "streamvbyte-d1": 375600.0,
        "varint": 6100.0,
    },
    "gallop_ns_per_probe": 18.9,
    # weight converting stored bytes into cost units (ns per byte): the
    # knob trading storage against decode speed in the autotuner score.
    "space_ns_per_byte": 2.0,
}
