"""granite-moe-1b-a400m [moe] 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32e top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

vocab padded 49155 → 49168 (next multiple of 16) for clean vocab sharding;
padded ids are never emitted by the pipeline."""
from repro.configs.base import ArchSpec, LM_SHAPES, register
from repro.models.transformer import LMConfig

SPEC = register(ArchSpec(
    arch_id="granite-moe-1b-a400m",
    family="lm",
    config=LMConfig(
        name="granite-moe-1b-a400m", n_layers=24, d_model=1024, n_heads=16,
        n_kv=8, d_ff=512, vocab=49168, head_dim=64, act="swiglu",
        n_experts=32, top_k=8, tie_embeddings=True,
        sharding_preset="tp"),
    shapes=dict(LM_SHAPES),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
))
