"""Shared recsys shape set (each of the 4 recsys archs × these 4 cells)."""

RECSYS_SHAPES = {
    "train_batch": {"kind": "train", "batch": 65536},
    "serve_p99": {"kind": "score", "batch": 512},
    "serve_bulk": {"kind": "score", "batch": 262144},
    "retrieval_cand": {"kind": "retrieval", "batch": 1,
                       "n_candidates": 1048576, "top_k": 100},
}
