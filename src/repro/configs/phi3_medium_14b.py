"""phi3-medium-14b [dense] 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352 — RoPE SwiGLU GQA [arXiv:2404.14219; unverified]."""
from repro.configs.base import ArchSpec, LM_SHAPES, register
from repro.models.transformer import LMConfig

SPEC = register(ArchSpec(
    arch_id="phi3-medium-14b",
    family="lm",
    config=LMConfig(
        name="phi3-medium-14b", n_layers=40, d_model=5120, n_heads=40,
        n_kv=10, d_ff=17920, vocab=100352, head_dim=128, act="swiglu",
        rope_theta=10000.0, sharding_preset="tp"),
    shapes=dict(LM_SHAPES),
    source="arXiv:2404.14219; unverified",
))
