"""internlm2-1.8b [dense] 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92544 — GQA [arXiv:2403.17297; hf]."""
from repro.configs.base import ArchSpec, LM_SHAPES, register
from repro.models.transformer import LMConfig

SPEC = register(ArchSpec(
    arch_id="internlm2-1.8b",
    family="lm",
    config=LMConfig(
        name="internlm2-1.8b", n_layers=24, d_model=2048, n_heads=16,
        n_kv=8, d_ff=8192, vocab=92544, head_dim=128, act="swiglu",
        rope_theta=1000000.0, sharding_preset="tp"),
    shapes=dict(LM_SHAPES),
    source="arXiv:2403.17297; hf",
))
