"""din [recsys] embed_dim=18 seq_len=100 attn_mlp=80-40 mlp=200-80
interaction=target-attn [arXiv:1706.06978; paper]."""
from repro.configs.base import ArchSpec, register
from repro.models.recsys import RecsysConfig
from repro.configs.recsys_shapes import RECSYS_SHAPES

SPEC = register(ArchSpec(
    arch_id="din",
    family="recsys",
    config=RecsysConfig(
        name="din", arch="din", embed_dim=18, seq_len=100,
        attn_mlp=(80, 40), mlp=(200, 80), n_items=1 << 20, n_cates=1 << 12),
    shapes=dict(RECSYS_SHAPES),
    source="arXiv:1706.06978; paper",
))
