"""gemma-7b [dense] 28L d_model=3072 16H (GQA kv=16) d_ff=24576 vocab=256000
— GeGLU, head_dim=256 [arXiv:2403.08295; hf]."""
from repro.configs.base import ArchSpec, LM_SHAPES, register
from repro.models.transformer import LMConfig

SPEC = register(ArchSpec(
    arch_id="gemma-7b",
    family="lm",
    config=LMConfig(
        name="gemma-7b", n_layers=28, d_model=3072, n_heads=16, n_kv=16,
        d_ff=24576, vocab=256000, head_dim=256, act="geglu",
        tie_embeddings=True, embed_scale=True, rope_theta=10000.0,
        sharding_preset="tp"),
    shapes=dict(LM_SHAPES),
    source="arXiv:2403.08295; hf",
))
