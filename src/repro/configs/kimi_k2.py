"""kimi-k2-1t-a32b [moe] 61L d_model=7168 64H (GQA kv=8) d_ff=2048 (expert),
vocab=163840, MoE 384e top-8 — trillion-param MoE (paper-table)
[arXiv:2501.kimi2; unverified].

Simplifications noted in DESIGN.md: uniform MoE layers (the released model has
a dense first layer + 1 shared expert); params/moments bf16 + fsdp preset —
at 512 chips: ~2 TB bf16 weights → ~4 GB/chip, moments 2×."""
from repro.configs.base import ArchSpec, LM_SHAPES, register
from repro.models.transformer import LMConfig

SPEC = register(ArchSpec(
    arch_id="kimi-k2-1t-a32b",
    family="lm",
    config=LMConfig(
        name="kimi-k2-1t-a32b", n_layers=61, d_model=7168, n_heads=64,
        n_kv=8, d_ff=2048, vocab=163840, head_dim=112, act="swiglu",
        n_experts=384, top_k=8, param_dtype="bfloat16",
        capacity_factor=1.25, sharding_preset="fsdp", remat="full"),
    shapes=dict(LM_SHAPES),
    source="arXiv:2501.kimi2; unverified",
))
