"""Codec registry (paper §3's scheme zoo, by name *and* by payload type).

Names mirror the paper: ``bp-<mode>`` is the S4-BP128 family at TPU block
geometry, ``bp-<mode>-ni`` the two-pass (non-integrated) variant,
``fastpfor-<mode>`` the patched family, ``varint`` the scalar baseline,
``streamvbyte-<mode>`` the byte-oriented lane-parallel codec (arXiv
1709.08990) and ``composite-<mode>`` the bitpack-blocks + varint-tail pair
(SNIPPETS.md §1 shape).

``codec_for`` / ``family_of`` resolve a codec from a *payload* object —
the per-list dispatch the storage autotuner relies on (DESIGN.md §2.13):
an index may mix codec families per posting list, so decode and storage
accounting key on what each payload actually is, not on the index-level
codec name.  ``get_codec("auto")`` returns the default family for the few
legacy call sites that still thread an index-level codec around; every
payload-bearing path resolves through the registry.
"""

from __future__ import annotations

import numpy as np

from repro.core import bitpack, composite, fastpfor, streamvbyte, varint
from repro.core.deltas import MODES


class _BPCodec:
    def __init__(self, mode: str, integrated: bool = True,
                 block_rows: int | None = None):
        self.mode, self.integrated, self.block_rows = mode, integrated, block_rows

    def encode(self, values):
        return bitpack.encode(values, mode=self.mode, block_rows=self.block_rows)

    def decode(self, pl):
        return bitpack.decode(pl) if self.integrated else bitpack.decode_ni(pl)

    def decode_np(self, pl):
        return np.asarray(self.decode(pl))[: pl.n]

    def bits_per_int(self, pl):
        return bitpack.bits_per_int(pl)


class _PForCodec:
    def __init__(self, mode: str, block_rows: int = 32):
        self.mode, self.block_rows = mode, block_rows

    def encode(self, values):
        return fastpfor.encode(values, mode=self.mode, block_rows=self.block_rows)

    def decode(self, pl):
        return fastpfor.decode(pl)

    def decode_np(self, pl):
        return fastpfor.decode_np(pl)

    def bits_per_int(self, pl):
        return fastpfor.bits_per_int(pl)


class _VarintCodec:
    mode = "d1"

    def encode(self, values):
        return varint.encode(values)

    def decode(self, vl):
        return varint.decode(vl)

    def decode_np(self, vl):
        return varint.decode(vl)

    def bits_per_int(self, vl):
        return varint.bits_per_int(vl)


class _SVBCodec:
    def __init__(self, mode: str, block_rows: int = streamvbyte.DEFAULT_ROWS):
        self.mode, self.block_rows = mode, block_rows

    def encode(self, values):
        return streamvbyte.encode(values, mode=self.mode,
                                  block_rows=self.block_rows)

    def decode(self, sl):
        return streamvbyte.decode(sl)

    def decode_np(self, sl):
        return streamvbyte.decode_np(sl)

    def bits_per_int(self, sl):
        return streamvbyte.bits_per_int(sl)


class _CompositeCodec:
    def __init__(self, mode: str, block_rows: int = composite.DEFAULT_ROWS):
        self.mode, self.block_rows = mode, block_rows

    def encode(self, values):
        return composite.encode(values, mode=self.mode,
                                block_rows=self.block_rows)

    def decode(self, cl):
        return composite.decode(cl)

    def decode_np(self, cl):
        return composite.decode_np(cl)

    def bits_per_int(self, cl):
        return composite.bits_per_int(cl)


def get_codec(name: str):
    name = name.lower()
    if name == "varint":
        return _VarintCodec()
    if name == "auto":      # per-list dispatch happens via codec_for
        return _BPCodec("d1")
    parts = name.split("-")
    fam = parts[0]
    mode = parts[1] if len(parts) > 1 else "d1"
    if mode not in MODES:
        raise ValueError(f"unknown delta mode {mode!r} in codec {name!r}")
    if fam == "bp":
        return _BPCodec(mode, integrated="ni" not in parts)
    if fam == "bp8":    # 1024-integer blocks (finer width granularity)
        return _BPCodec(mode, integrated="ni" not in parts, block_rows=8)
    if fam == "fastpfor":
        return _PForCodec(mode)
    if fam in ("streamvbyte", "svb"):
        return _SVBCodec(mode)
    if fam == "composite":
        return _CompositeCodec(mode)
    raise ValueError(f"unknown codec {name!r}")


def codec_for(payload):
    """Resolve the decode/accounting codec from a payload object (per-list
    registry dispatch — mixed-codec indexes key on payload type)."""
    if isinstance(payload, fastpfor.PatchedList):
        return _PForCodec(payload.mode, payload.block_rows)
    if isinstance(payload, bitpack.PackedList):
        return _BPCodec(payload.mode, block_rows=payload.block_rows)
    if isinstance(payload, varint.VarintList):
        return _VarintCodec()
    if isinstance(payload, streamvbyte.SVBList):
        return _SVBCodec(payload.mode, payload.block_rows)
    if isinstance(payload, composite.CompositeList):
        return _CompositeCodec(payload.mode, payload.block_rows)
    return None


def family_of(payload) -> str:
    """Codec family name of a payload (per-codec list-count reporting)."""
    if isinstance(payload, fastpfor.PatchedList):
        return "fastpfor"
    if isinstance(payload, bitpack.PackedList):
        return "bp8" if payload.block_rows == 8 else "bp"
    if isinstance(payload, varint.VarintList):
        return "varint"
    if isinstance(payload, streamvbyte.SVBList):
        return "streamvbyte"
    if isinstance(payload, composite.CompositeList):
        return "composite"
    return "unknown"


ALL_CODECS = (
    ["varint"]
    + [f"bp-{m}" for m in ("d1", "d2", "d4", "dm", "dv")]
    + [f"bp-{m}-ni" for m in ("d1", "d2", "d4", "dm", "dv")]
    + [f"fastpfor-{m}" for m in ("d1", "d2", "d4", "dm", "dv")]
    + [f"streamvbyte-{m}" for m in ("d1", "d2", "d4", "dm", "dv")]
    + ["composite-d1"]
)
