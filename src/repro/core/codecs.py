"""Codec registry (paper §3's scheme zoo, by name).

Names mirror the paper: ``bp-<mode>`` is the S4-BP128 family at TPU block
geometry, ``bp-<mode>-ni`` the two-pass (non-integrated) variant,
``fastpfor-<mode>`` the patched family, ``varint`` the scalar baseline.
"""

from __future__ import annotations

import numpy as np

from repro.core import bitpack, fastpfor, varint
from repro.core.deltas import MODES


class _BPCodec:
    def __init__(self, mode: str, integrated: bool = True,
                 block_rows: int | None = None):
        self.mode, self.integrated, self.block_rows = mode, integrated, block_rows

    def encode(self, values):
        return bitpack.encode(values, mode=self.mode, block_rows=self.block_rows)

    def decode(self, pl):
        return bitpack.decode(pl) if self.integrated else bitpack.decode_ni(pl)

    def decode_np(self, pl):
        return np.asarray(self.decode(pl))[: pl.n]

    def bits_per_int(self, pl):
        return bitpack.bits_per_int(pl)


class _PForCodec:
    def __init__(self, mode: str, block_rows: int = 32):
        self.mode, self.block_rows = mode, block_rows

    def encode(self, values):
        return fastpfor.encode(values, mode=self.mode, block_rows=self.block_rows)

    def decode(self, pl):
        return fastpfor.decode(pl)

    def decode_np(self, pl):
        return fastpfor.decode_np(pl)

    def bits_per_int(self, pl):
        return fastpfor.bits_per_int(pl)


class _VarintCodec:
    mode = "d1"

    def encode(self, values):
        return varint.encode(values)

    def decode(self, vl):
        return varint.decode(vl)

    def decode_np(self, vl):
        return varint.decode(vl)

    def bits_per_int(self, vl):
        return varint.bits_per_int(vl)


def get_codec(name: str):
    name = name.lower()
    if name == "varint":
        return _VarintCodec()
    parts = name.split("-")
    fam = parts[0]
    mode = parts[1] if len(parts) > 1 else "d1"
    if mode not in MODES:
        raise ValueError(f"unknown delta mode {mode!r} in codec {name!r}")
    if fam == "bp":
        return _BPCodec(mode, integrated="ni" not in parts)
    if fam == "bp8":    # 1024-integer blocks (finer width granularity)
        return _BPCodec(mode, integrated="ni" not in parts, block_rows=8)
    if fam == "fastpfor":
        return _PForCodec(mode)
    raise ValueError(f"unknown codec {name!r}")


ALL_CODECS = (
    ["varint"]
    + [f"bp-{m}" for m in ("d1", "d2", "d4", "dm", "dv")]
    + [f"bp-{m}-ni" for m in ("d1", "d2", "d4", "dm", "dv")]
    + [f"fastpfor-{m}" for m in ("d1", "d2", "d4", "dm", "dv")]
)
