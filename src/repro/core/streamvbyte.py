"""Stream VByte coding (Lemire, Kurz & Rupp, arXiv 1709.08990), TPU-adapted.

Classic VByte interleaves continuation bits with data bytes, so a decoder
must inspect every byte before it knows where the next integer starts.
Stream VByte removes that serial dependency by *separating the streams*: a
control stream holds one 2-bit code per integer (code = byte length − 1,
lengths 1–4), and a data stream holds the raw little-endian value bytes
with no continuation bits.  All byte lengths of a group are then known
up-front, which is what makes the decode lane-parallel: control codes →
per-lane byte widths → prefix-summed byte offsets → gathered shift/mask
reconstruction (the SIMD shuffle of the paper becomes a vector gather on
TPU tile geometry).

Layout here: values are grouped into blocks of ``block_rows``×128 (default
one row — 128 integers per block, so short tail-heavy lists pay at most 127
padded deltas), delta-coded per block with the standard mode family
(``core.deltas``) and a scalar per-block seed = previous block's last value,
exactly like the bitpack layouts.  The control stream is stored as uint32
words (16 codes per word, little-endian byte order, so code *i* of a block
sits at bit ``2·(i mod 16)`` of word ``i // 16``) and the data stream as a
flat uint32 word view of the byte stream — both device-friendly 32-bit
carriers.  Per-block metadata: data-stream byte offset (``doffs``, the
scalar-prefetch operand of the Pallas decoder) and block max (seeds).

The batched device decoders live in ``kernels/svb_decode.py`` (pure-jnp
batched path + the Pallas kernel); the host encoder and a numpy reference
decode live here.  SVBList is *not* skip-capable (no packed word/width
layout), so these lists always serve through ``DecodedSource`` — group
signatures and the megakernel path are untouched by codec choice.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import deltas as deltas_lib

LANES = 128
DEFAULT_ROWS = 1           # 128-int blocks: tail padding stays negligible


@dataclasses.dataclass
class SVBList:
    """Host representation of one Stream-VByte-compressed sorted list."""
    ctrl: np.ndarray       # (K, CW) uint32 — 16 2-bit codes per word
    data: np.ndarray       # (DW,) uint32  — LE byte stream, zero-padded
    doffs: np.ndarray      # (K,) int32    — data byte offset per block
    maxes: np.ndarray      # (K,) uint32   — last value per block (seeds)
    nbytes: int            # true data-stream byte count (accounting)
    n: int
    mode: str = "d1"
    block_rows: int = DEFAULT_ROWS

    @property
    def num_blocks(self) -> int:
        return int(self.ctrl.shape[0])

    @property
    def padded_n(self) -> int:
        return self.num_blocks * self.block_rows * LANES


def _byte_lens(d: np.ndarray) -> np.ndarray:
    """Byte length (1–4) of each uint32 delta."""
    d = d.astype(np.uint32)
    return (1 + (d >= (1 << 8)).astype(np.int64)
            + (d >= (1 << 16)).astype(np.int64)
            + (d >= (1 << 24)).astype(np.int64))


def encode(values: np.ndarray, mode: str = "d1",
           block_rows: int = DEFAULT_ROWS) -> SVBList:
    """Compress a sorted 1-D array of non-negative ints (< 2**32)."""
    v = np.asarray(values, dtype=np.int64).ravel()
    n = int(v.size)
    if n == 0:
        v = np.zeros(1, dtype=np.int64)
    per = block_rows * LANES
    npad = (-len(v)) % per
    if npad:
        v = np.concatenate([v, np.full(npad, v[-1], dtype=np.int64)])
    K = len(v) // per
    blocks = v.reshape(K, block_rows, LANES)
    maxes = blocks[:, -1, -1].astype(np.uint32)
    seeds = np.concatenate([[0], maxes[:-1].astype(np.int64)])
    d = deltas_lib.encode_deltas_np(blocks, seeds, mode).reshape(-1)

    lens = _byte_lens(d)                               # (K*per,)
    # control stream: 2-bit codes, 4 per byte, LE bytes → uint32 words
    codes = (lens - 1).astype(np.uint8).reshape(-1, 4)
    ctrl_bytes = (codes[:, 0] | (codes[:, 1] << 2)
                  | (codes[:, 2] << 4) | (codes[:, 3] << 6))
    ctrl = ctrl_bytes.view(np.uint32).reshape(K, per // 16)
    # data stream: raw LE value bytes, scattered like the varint encoder
    ends = np.cumsum(lens)
    starts = ends - lens
    nbytes = int(ends[-1])
    out = np.zeros(nbytes + (-nbytes) % 4, dtype=np.uint8)
    du = d.astype(np.uint32)
    for byte_i in range(4):
        live = lens > byte_i
        out[starts[live] + byte_i] = (
            (du[live] >> np.uint32(8 * byte_i)) & np.uint32(0xFF))
    data = out.view(np.uint32)
    if data.size == 0:                                 # keep gathers in-bounds
        data = np.zeros(1, np.uint32)
    doffs = starts.reshape(K, per)[:, 0].astype(np.int32)
    return SVBList(ctrl=ctrl, data=data, doffs=doffs, maxes=maxes,
                   nbytes=nbytes, n=n, mode=mode, block_rows=block_rows)


def decode_np(sl: SVBList) -> np.ndarray:
    """Numpy reference decode, trimmed to the valid length."""
    K, per = sl.num_blocks, sl.block_rows * LANES
    i = np.arange(K * per)
    ctrl = sl.ctrl.reshape(-1)
    codes = (ctrl[i >> 4] >> (2 * (i & 15))) & 3
    lens = codes.astype(np.int64) + 1
    offs = np.cumsum(lens) - lens
    data_bytes = sl.data.view(np.uint8)
    d = np.zeros(K * per, dtype=np.uint32)
    for byte_i in range(4):
        live = lens > byte_i
        idx = np.minimum(offs[live] + byte_i, data_bytes.size - 1)
        d[live] |= data_bytes[idx].astype(np.uint32) << np.uint32(8 * byte_i)
    seeds = np.concatenate([[0], sl.maxes[:-1]]).astype(np.uint32)
    vals = np.asarray(deltas_lib.prefix_sum(
        d.reshape(K, sl.block_rows, LANES), seeds, sl.mode))
    return vals.reshape(-1)[: sl.n].astype(np.int64)


def decode(sl: SVBList):
    """Batched device decode (pow2-bucketed, jnp) → padded flat values.

    Dispatches to the kernels layer so codec decode and kernel decode share
    one implementation; callers trim to ``sl.n``.
    """
    from repro.kernels import svb_decode
    return svb_decode.decode_bucketed(sl)


def bits_per_int(sl: SVBList) -> float:
    """Storage cost: data bytes + control bytes + per-block metadata
    (4B data offset + 4B block max)."""
    ctrl_bytes = sl.num_blocks * sl.block_rows * LANES // 4
    meta_bytes = sl.num_blocks * 8
    return (sl.nbytes + ctrl_bytes + meta_bytes) * 8 / max(sl.n, 1)
