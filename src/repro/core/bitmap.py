"""Bitmap posting representation (paper §6.7, HYB+M2 substrate).

Bitmaps are uint32 word arrays; a list is stored as a bitmap when its average
gap ≤ B, i.e. len ≥ n_docs / B.  Operations map to single TPU vector ops:
AND + ``lax.population_count`` for bitmap∧bitmap, gather + bit-test for
list∧bitmap probes.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax


def build_np(values: np.ndarray, n_docs: int) -> np.ndarray:
    words = np.zeros((n_docs + 31) // 32, dtype=np.uint32)
    v = np.asarray(values, dtype=np.int64)
    np.bitwise_or.at(words, v >> 5, (np.uint32(1) << (v & 31).astype(np.uint32)))
    return words


@jax.jit
def probe(words, vals, mask):
    """mask &= bitmap[vals] for sentinel-padded int32 vals."""
    w = jnp.take(words, jnp.clip(vals >> 5, 0, words.shape[0] - 1))
    bit = (w >> (vals & 31).astype(jnp.uint32)) & 1
    return mask & (bit == 1)


@jax.jit
def bitmap_and(a, b):
    return a & b


@jax.jit
def popcount(words):
    return jnp.sum(lax.population_count(words).astype(jnp.int32))


@jax.jit
def intersect_count(a, b):
    return popcount(a & b)


def extract_np(words: np.ndarray) -> np.ndarray:
    """Host-side: bitmap -> sorted doc-id list."""
    w = np.asarray(words)
    bits = np.unpackbits(w.view(np.uint8), bitorder="little")
    return np.nonzero(bits)[0].astype(np.int32)


@jax.jit
def to_mask_over(vals, words):
    """Membership of padded vals in bitmap (no prior mask)."""
    return probe(words, vals, vals >= 0)


def bits_per_int(words: np.ndarray, n: int) -> float:
    return words.size * 32 / max(n, 1)
