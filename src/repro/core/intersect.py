"""Sorted-list intersection (paper §5), TPU-adapted.

Paper algorithms → TPU-native equivalents (DESIGN.md §2.4):

  SCALAR            → ``intersect_ref`` numpy two-pointer oracle
  V1 / V3           → ``intersect_tiled``: two-pointer merge at *tile*
                      granularity; a (TR, TF) broadcast-equality tile replaces
                      ``pcmpeqd``+``ptest``; V3's branching layers become the
                      tile-size hierarchy
  SIMD GALLOPING    → ``intersect_gallop``: all m binary searches run
                      lane-parallel (vectorized searchsorted + gather-check);
                      O(m log n) work, O(log n) depth
  (+ block skip)    → ``intersect_packed``: galloping over a *compressed* long
                      list using the stored per-block maxima as a skip index —
                      only candidate blocks are decoded
  heuristic         → ``intersect_auto``: ratio dispatch like the paper's
                      50×/1000× rule, thresholds re-derived on TPU geometry

All device functions take sentinel-padded int32 arrays with an explicit valid
count and return a match mask over ``r`` (the paper's output-to-input property
becomes: results live in a buffer of len(r), compacted with ``compact``).
"""

from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.core import bitpack, deltas as deltas_lib

SENTINEL = np.int32(2**31 - 1)

# ratio thresholds for the dispatcher (paper: V1 <50:1, V3 <1000:1, then
# galloping).  Re-derived for TPU tiles in benchmarks/bench_intersect.py;
# see EXPERIMENTS.md §Perf.
TILED_MAX_RATIO = 32.0


# --------------------------------------------------------------------------
# oracle
# --------------------------------------------------------------------------

def intersect_ref(r: np.ndarray, f: np.ndarray) -> np.ndarray:
    """Textbook SCALAR merge intersection (numpy oracle)."""
    r = np.asarray(r); f = np.asarray(f)
    out = []
    i = j = 0
    while i < len(r) and j < len(f):
        if r[i] < f[j]:
            i += 1
        elif f[j] < r[i]:
            j += 1
        else:
            out.append(r[i]); i += 1; j += 1
    return np.array(out, dtype=r.dtype)


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def pad_to(values: np.ndarray, size: int) -> np.ndarray:
    v = np.asarray(values, dtype=np.int32)
    out = np.full(size, SENTINEL, dtype=np.int32)
    out[: len(v)] = v
    return out


def pow2_bucket(n: int, floor: int = 128) -> int:
    size = floor
    while size < n:
        size *= 2
    return size


@jax.jit
def compact(vals, mask):
    """Scatter-compact matched values; returns (sorted padded vals, count)."""
    m = vals.shape[0]
    idx = jnp.cumsum(mask.astype(jnp.int32)) - 1
    pos = jnp.where(mask, idx, m)                     # out-of-bounds → dropped
    out = jnp.full((m,), SENTINEL, dtype=vals.dtype)
    out = out.at[pos].set(vals, mode="drop")
    return out, jnp.sum(mask.astype(jnp.int32))


# --------------------------------------------------------------------------
# vectorized galloping (searchsorted)
# --------------------------------------------------------------------------

@jax.jit
def intersect_gallop(r, f):
    """All-lanes-parallel binary search of r into f. Returns mask over r."""
    n = f.shape[0]
    pos = jnp.searchsorted(f, r, side="left")
    hit = jnp.take(f, jnp.clip(pos, 0, n - 1)) == r
    return hit & (r != SENTINEL)


# --------------------------------------------------------------------------
# tiled merge (V1/V3 analogue)
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("tile_r", "tile_f"))
def intersect_tiled(r, f, tile_r: int = 128, tile_f: int = 1024):
    """Tile-granular two-pointer merge. Returns mask over r.

    Each step compares a (tile_r,) window of r against a (tile_f,) window of f
    with one broadcast equality tile, then advances the window(s) whose max is
    not larger (both on ties) — the V1 walk at vreg granularity.
    """
    m, n = r.shape[0], f.shape[0]
    assert m % tile_r == 0 and n % tile_f == 0, "pad inputs to tile multiples"
    nri, nfi = m // tile_r, n // tile_f

    def cond(state):
        i, j, _ = state
        return (i < nri) & (j < nfi)

    def body(state):
        i, j, mask = state
        rt = lax.dynamic_slice(r, (i * tile_r,), (tile_r,))
        ft = lax.dynamic_slice(f, (j * tile_f,), (tile_f,))
        eq = rt[:, None] == ft[None, :]
        hit = jnp.any(eq, axis=1) & (rt != SENTINEL)
        row = lax.dynamic_slice(mask, (i * tile_r,), (tile_r,))
        mask = lax.dynamic_update_slice(mask, row | hit, (i * tile_r,))
        r_max, f_max = rt[-1], ft[-1]
        return (jnp.where(r_max <= f_max, i + 1, i),
                jnp.where(f_max <= r_max, j + 1, j), mask)

    mask0 = jnp.zeros((m,), dtype=bool)
    _, _, mask = lax.while_loop(cond, body, (jnp.int32(0), jnp.int32(0), mask0))
    return mask


# --------------------------------------------------------------------------
# batch-axis variants (index/batch.py device programs)
# --------------------------------------------------------------------------
#
# All take a leading batch axis and keep every intermediate on device; the
# SvS fold over the remaining terms of a conjunctive query batch is a single
# ``lax.scan`` so candidates never round-trip to host between terms.

compact_batch = jax.jit(jax.vmap(compact))

intersect_gallop_batch = jax.jit(jax.vmap(intersect_gallop))


@partial(jax.jit, static_argnames=("tile_r", "tile_f"))
def intersect_tiled_batch(r, f, tile_r: int = 128, tile_f: int = 1024):
    """(B, M) × (B, N) → (B, M) mask; vmapped tile-merge."""
    return jax.vmap(lambda rr, ff: intersect_tiled(
        rr, ff, tile_r=tile_r, tile_f=tile_f))(r, f)


@jax.jit
def count_valid(r):
    """(B, M) padded values → (B,) number of non-sentinel entries."""
    return jnp.sum((r != SENTINEL).astype(jnp.int32), axis=-1)


def masked_svs_scan(r, folds, fold_active, intersect_fn):
    """Compact-per-fold SvS scan body, parameterized over the intersect
    (jnp gallop/tiled, the packed partial decode, or the Pallas kernels).
    The batched engine's device programs now carry a validity *mask* over
    the original sorted seed buffer instead of compacting between folds
    (``index/batch.py::_mask_fold_scan``; compaction never shrank the
    static shapes but its cumsum+scatter dominated the program) — this
    compacting variant remains the core-layer reference for callers that
    want dense candidate buffers between folds.  ``folds`` may be a plain
    (J, B, N) value stack or any pytree of (J, ...)-leading stacked
    operands (``lax.scan`` slices pytrees), e.g. the tuple of batch-uniform
    packed layout arrays.

    fold_active: optional (J, B) bool — rows whose slot j is inactive pass
    through step j unchanged, letting queries of different term counts share
    one program (padded to the group's max arity)."""
    if fold_active is None:
        def step(rr, f):
            rr, _ = compact_batch(rr, intersect_fn(rr, f))
            return rr, None
        r, _ = lax.scan(step, r, folds)
    else:
        def step(rr, xs):
            f, act = xs
            keep = jnp.where(act[:, None], intersect_fn(rr, f),
                             rr != SENTINEL)
            rr, _ = compact_batch(rr, keep)
            return rr, None
        r, _ = lax.scan(step, r, (folds, fold_active))
    return r, count_valid(r)


@partial(jax.jit, static_argnames=("algo",))
def svs_fold_batch(r, folds, algo: str = "gallop", fold_active=None):
    """Fused SvS fold: intersect candidates ``r`` (B, M) with each of the
    stacked fold lists ``folds`` (J, B, N) in turn, compacting on device
    between terms.  Returns (compacted (B, M) candidates, (B,) counts)."""
    tile_r = min(128, r.shape[-1])
    tile_f = min(1024, folds.shape[-1])

    def intersect(rr, f):
        if algo == "tiled":
            return intersect_tiled_batch(rr, f, tile_r=tile_r, tile_f=tile_f)
        return intersect_gallop_batch(rr, f)

    return masked_svs_scan(r, folds, fold_active, intersect)


# --------------------------------------------------------------------------
# galloping over a compressed list (block-skip; Skipper idea, paper §2)
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("mode", "block_rows"))
def _packed_gallop(r, flat_words, widths, offsets, maxes, mode: str,
                   block_rows: int):
    K = widths.shape[0]
    blk = jnp.clip(jnp.searchsorted(maxes.astype(jnp.int32), r, side="left"),
                   0, K - 1)
    seeds = jnp.where(blk > 0, maxes[jnp.maximum(blk - 1, 0)], jnp.uint32(0))
    d = bitpack.unpack_deltas(flat_words, widths[blk], offsets[blk], block_rows)
    vals = deltas_lib.prefix_sum(d, seeds, mode)       # (m, R, 128)
    hit = jnp.any(vals.astype(jnp.int32) == r[:, None, None], axis=(1, 2))
    return hit & (r != SENTINEL)


def intersect_packed(r, packed_f: bitpack.PackedList):
    """Intersect padded r against a *compressed* long list: binary-search the
    block-max skip index, decode only the candidate block per element."""
    return _packed_gallop(r, packed_f.flat_words, packed_f.widths,
                          packed_f.offsets, packed_f.maxes, packed_f.mode,
                          packed_f.block_rows)


# --------------------------------------------------------------------------
# candidate-block partial decode (posting-source layer, DESIGN.md §2.6)
# --------------------------------------------------------------------------
#
# ``_packed_gallop`` above decodes one block *per candidate element* — with
# duplicates, so its decode volume grows with m, not with the number of
# distinct blocks touched.  The functions below take the deduplicated
# candidate block-id list (host-precomputed from the block-max skip index,
# ``bitpack.candidate_block_ids``) and decode each touched block exactly
# once: partial decode proportional to the *blocks hit*, which is the
# paper's §6.5 regime and what the batched engine stacks across queries.

def _packed_candidates_body(r, words, widths, offsets, maxes, blk_ids,
                            exc_pos, exc_add, mode: str, block_rows: int):
    """r: (m,) padded int32; blk_ids: (C,) sorted unique candidate block ids,
    padded with Kp (= maxes length) which decodes to all-SENTINEL slots.
    Returns a (m,) match mask.  Exceptions (FastPFOR patches) landing inside
    candidate blocks are applied before the prefix sum; exc_pos is padded
    with -1."""
    Kp = maxes.shape[0]
    C = blk_ids.shape[0]
    per = block_rows * bitpack.LANES
    pad = blk_ids >= Kp                                     # padded slots
    ids = jnp.minimum(blk_ids, Kp - 1)
    seeds = jnp.where(ids > 0, jnp.take(maxes, jnp.maximum(ids - 1, 0)),
                      jnp.uint32(0))
    d = bitpack.unpack_deltas(words, jnp.take(widths, ids),
                              jnp.take(offsets, ids), block_rows)
    if exc_pos.shape[0]:
        eb = exc_pos // per
        slot = jnp.clip(jnp.searchsorted(blk_ids, eb), 0, C - 1)
        ok = (exc_pos >= 0) & (jnp.take(blk_ids, slot) == eb)
        tgt = jnp.where(ok, slot * per + exc_pos % per, C * per)  # OOB → drop
        d = d.reshape(-1).at[tgt].add(exc_add, mode="drop").reshape(d.shape)
    vals = deltas_lib.prefix_sum(d, seeds, mode)            # (C, R, 128)
    # blocks are ascending and values within a block are sorted, so the
    # concatenation is globally sorted; padded slots become SENTINEL (max
    # int32) and stay sorted at the tail.
    flat = vals.reshape(-1).astype(jnp.int32)
    flat = jnp.where(jnp.repeat(pad, per), SENTINEL, flat)
    pos = jnp.searchsorted(flat, r, side="left")
    hit = jnp.take(flat, jnp.clip(pos, 0, C * per - 1)) == r
    return hit & (r != SENTINEL)


@partial(jax.jit, static_argnames=("mode", "block_rows"))
def intersect_packed_candidates(r, words, widths, offsets, maxes, blk_ids,
                                exc_pos, exc_add, mode: str,
                                block_rows: int = bitpack.DEFAULT_ROWS):
    """Skip-aware partial-decode intersection of padded candidates ``r``
    against one compressed list in the batch-uniform layout."""
    return _packed_candidates_body(r, words, widths, offsets, maxes, blk_ids,
                                   exc_pos, exc_add, mode, block_rows)


@partial(jax.jit, static_argnames=("mode", "block_rows"))
def intersect_packed_batch(r, words, widths, offsets, maxes, blk_ids,
                           exc_pos, exc_add, mode: str,
                           block_rows: int = bitpack.DEFAULT_ROWS):
    """Batched skip-aware partial decode: every operand carries a leading
    batch axis — r (B, M), words (B, T, 128), widths/offsets/maxes (B, K),
    blk_ids (B, C), exc_pos/exc_add (B, E) — and each row decodes only its
    own candidate blocks.  Returns a (B, M) match mask."""
    return jax.vmap(lambda *a: _packed_candidates_body(
        *a, mode=mode, block_rows=block_rows))(
            r, words, widths, offsets, maxes, blk_ids, exc_pos, exc_add)


# --------------------------------------------------------------------------
# dispatcher (paper's heuristic, §5)
# --------------------------------------------------------------------------

def intersect_auto(r, f, r_count: int, f_count: int):
    """Host-side ratio dispatch (lengths are metadata, as in the paper)."""
    ratio = max(f_count, 1) / max(r_count, 1)
    if ratio <= TILED_MAX_RATIO:
        tile_r = min(128, r.shape[0])
        tile_f = min(1024, f.shape[0])
        return intersect_tiled(r, f, tile_r=tile_r, tile_f=tile_f)
    return intersect_gallop(r, f)
