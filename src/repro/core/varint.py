"""Variable-byte coding (paper §3, VARINT baseline).

The paper treats Varint as the commonly-used *scalar* baseline and implements
it without SIMD; we keep it host-side (numpy) in the same spirit: it is the
data-pipeline / tail codec and the compression-ratio baseline in benchmarks.
Encoded form: little-endian 7-bit groups, high bit = continuation, applied to
D1 deltas of the sorted list (first value coded against 0).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class VarintList:
    data: np.ndarray   # (nbytes,) uint8
    n: int


def encode(values: np.ndarray) -> VarintList:
    v = np.asarray(values, dtype=np.int64).ravel()
    n = int(v.size)
    if n == 0:
        return VarintList(np.zeros(0, np.uint8), 0)
    d = np.empty(n, dtype=np.uint64)
    d[0] = v[0]
    d[1:] = (v[1:] - v[:-1]).astype(np.uint64)
    # vectorized byte-length per delta, then scatter 7-bit groups
    bl = np.frompyfunc(lambda x: max((int(x).bit_length() + 6) // 7, 1), 1, 1)(d)
    bl = bl.astype(np.int64)
    ends = np.cumsum(bl)
    starts = ends - bl
    out = np.zeros(int(ends[-1]), dtype=np.uint8)
    rem = d.copy()
    for byte_i in range(int(bl.max())):
        live = bl > byte_i
        pos = starts[live] + byte_i
        chunk = (rem[live] & np.uint64(0x7F)).astype(np.uint8)
        cont = (bl[live] - 1 > byte_i).astype(np.uint8) << 7
        out[pos] = chunk | cont
        rem[live] >>= np.uint64(7)
    return VarintList(out, n)


def decode(vl: VarintList) -> np.ndarray:
    out = np.empty(vl.n, dtype=np.int64)
    data = vl.data
    p = 0
    acc = 0
    for i in range(vl.n):
        val = 0
        shift = 0
        while True:
            byte = int(data[p]); p += 1
            val |= (byte & 0x7F) << shift
            if not byte & 0x80:
                break
            shift += 7
        acc += val
        out[i] = acc
    return out


def bits_per_int(vl: VarintList) -> float:
    return vl.data.size * 8 / max(vl.n, 1)
