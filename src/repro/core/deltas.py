"""Differential coding for sorted integer blocks (paper §4), TPU-adapted.

The paper's D1/D2/DM/D4 family trades delta magnitude against prefix-sum
instruction count at SIMD width 4.  Here blocks are (R, 128) tiles (R rows of
128 lanes; integer ``i`` of a block lives at ``(i // 128, i % 128)``) and the
family generalizes to stride-s deltas:

  d1   stride 1      (paper D1)        full prefix sum, smallest deltas
  d2   stride 2      (paper D2)
  d4   stride 4      (paper D4, literal)
  dm   row-max       (paper DM scaled: subtract last lane of previous row)
  dv   stride 128    (paper's D4 *insight* at TPU vreg width: one row delta)
  none                                  no differential coding

Seeds are scalar per block: the last value of the previous block (0 for the
first).  The first ``s`` elements of a block are coded relative to that scalar
seed (the paper instead carries the last s values; with s of 4096 elements the
compression difference is negligible and a scalar seed doubles as a block-max
skip-index entry — see DESIGN.md §2.1/§2.2).

Encoding runs on the host in numpy (variable-size metadata); the prefix-sum
reconstruction is pure jnp and is what the Pallas kernel fuses with unpacking.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

MODES = ("none", "d1", "d2", "d4", "dm", "dv")
_STRIDE = {"d1": 1, "d2": 2, "d4": 4}


# --------------------------------------------------------------------------
# host-side encode (numpy, int64 domain)
# --------------------------------------------------------------------------

def encode_deltas_np(blocks: np.ndarray, seeds: np.ndarray, mode: str) -> np.ndarray:
    """blocks: (K, R, 128) int64 sorted (flattened row-major per block).

    seeds: (K,) int64 scalar carry-in per block.  Returns (K, R, 128) uint32.
    """
    if mode not in MODES:
        raise ValueError(f"unknown delta mode {mode!r}")
    K, R, L = blocks.shape
    assert L == 128, "blocks must be (K, R, 128)"
    x = blocks.astype(np.int64)
    if mode == "none":
        d = x.copy()
    elif mode == "dv":
        d = np.empty_like(x)
        d[:, 0] = x[:, 0] - seeds[:, None]
        d[:, 1:] = x[:, 1:] - x[:, :-1]
    elif mode == "dm":
        d = np.empty_like(x)
        d[:, 0] = x[:, 0] - seeds[:, None]
        d[:, 1:] = x[:, 1:] - x[:, :-1, 127:128]
    else:  # stride modes d1/d2/d4
        s = _STRIDE[mode]
        flat = x.reshape(K, R * L)
        d = np.empty_like(flat)
        d[:, :s] = flat[:, :s] - seeds[:, None]
        d[:, s:] = flat[:, s:] - flat[:, :-s]
        d = d.reshape(K, R, L)
    if d.min() < 0:
        raise ValueError("input not sorted (negative delta)")
    if d.max() > 0xFFFFFFFF:
        raise ValueError("delta exceeds 32 bits")
    return d.astype(np.uint32)


# --------------------------------------------------------------------------
# device-side prefix sum (jnp, uint32 modular arithmetic)
# --------------------------------------------------------------------------

def _excl_cumsum(a, axis):
    inc = jnp.cumsum(a, axis=axis, dtype=a.dtype)
    pad = [(0, 0)] * a.ndim
    pad[axis] = (1, 0)
    sl = [slice(None)] * a.ndim
    sl[axis] = slice(None, -1)
    return jnp.pad(inc, pad)[tuple(sl)]


def _d1_block_cumsum(d, seeds):
    """d: (K, R, C) uint32, seeds: (K,) uint32 -> inclusive running sum in
    row-major order per block, seeded."""
    row_cum = jnp.cumsum(d, axis=-1, dtype=jnp.uint32)
    row_sums = row_cum[..., -1]                       # (K, R)
    carry = seeds[:, None] + _excl_cumsum(row_sums, axis=1)   # (K, R)
    return row_cum + carry[..., None]


def prefix_sum(deltas, seeds, mode: str):
    """Reconstruct original values from deltas (paper Algorithm 1, lines 10/15).

    deltas: (K, R, 128) uint32; seeds: (K,) uint32.  Returns (K, R, 128) uint32.
    """
    if mode not in MODES:
        raise ValueError(f"unknown delta mode {mode!r}")
    d = deltas.astype(jnp.uint32)
    seeds = seeds.astype(jnp.uint32)
    if mode == "none":
        return d
    if mode == "dv":
        return seeds[:, None, None] + jnp.cumsum(d, axis=1, dtype=jnp.uint32)
    if mode == "dm":
        t = d[..., 127]                               # (K, R)
        carry_prev = seeds[:, None] + _excl_cumsum(t, axis=1)
        return d + carry_prev[..., None]
    if mode == "d1":
        return _d1_block_cumsum(d, seeds)
    # d2 / d4: s independent stride-1 chains interleaved across lanes
    s = _STRIDE[mode]
    K, R, L = d.shape
    dd = d.reshape(K, R, L // s, s)
    outs = [_d1_block_cumsum(dd[..., p], seeds) for p in range(s)]
    return jnp.stack(outs, axis=-1).reshape(K, R, L)


def encode_deltas_jnp(blocks, seeds, mode: str):
    """Device-side delta computation (inverse of prefix_sum).

    blocks: (K, R, 128) uint32 sorted values; seeds: (K,) uint32.
    'Computing deltas during compression is an inexpensive operation' (paper
    §4) — all branches are vectorized diffs.
    """
    x = blocks.astype(jnp.uint32)
    seeds = seeds.astype(jnp.uint32)
    if mode == "none":
        return x
    if mode == "dv":
        first = x[:, :1] - seeds[:, None, None]
        rest = x[:, 1:] - x[:, :-1]
        return jnp.concatenate([first, rest], axis=1)
    if mode == "dm":
        first = x[:, :1] - seeds[:, None, None]
        rest = x[:, 1:] - x[:, :-1, 127:128]
        return jnp.concatenate([first, rest], axis=1)
    s = _STRIDE[mode]
    K, R, L = x.shape
    flat = x.reshape(K, R * L)
    first = flat[:, :s] - seeds[:, None]
    rest = flat[:, s:] - flat[:, :-s]
    return jnp.concatenate([first, rest], axis=1).reshape(K, R, L)


def prefix_sum_ops_per_int(mode: str, block_rows: int = 32) -> float:
    """Analytic vector-op count per integer (cf. paper Table 1, lane width 128)."""
    n = block_rows * 128
    if mode == "none":
        return 0.0
    if mode == "dv":
        return (block_rows - 1) / n
    if mode == "dm":
        return (2 * block_rows) / n
    s = _STRIDE[mode]
    # per row: Hillis-Steele over the 128/s chain positions (all s phases
    # ride in the same full-width vector op) + row-carry adds
    steps = int(np.ceil(np.log2(max(128 // s, 2))))
    return (block_rows * (steps + 2)) / n
