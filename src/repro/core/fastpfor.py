"""FastPFOR / S4-FastPFOR patched coding (paper §3), TPU-adapted.

Per block of ROWS×128 deltas we pick a base width b' ≤ b minimizing

    cost(b') = N·b' + c(b')·(b − b' + POS_BITS)            (paper's heuristic,
                                                            N = block size)

where c(b') is the number of exceptions (deltas ≥ 2**b').  The least
significant b' bits of every delta are bit-packed exactly like BP blocks; each
exception additionally stores its position and its high b−b' bits.  As in
S4-FastPFOR, high-bit arrays are accounted bit-packed per (b−b') class, padded
to multiples of 32 integers.

Device decode = unpack base → patch (scatter-add of high<<b' at exception
positions) → prefix sum.  Patching must precede the prefix sum, which is why —
exactly as the paper observes — it cannot be fused with it.

Storage accounting follows the paper's *format*; the device representation
keeps exceptions as flat (position, shifted-high-bits) arrays, which is the
TPU-operational layout (a scatter, instead of the paper's byte loop).
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
from functools import partial

from repro.core import bitpack, deltas as deltas_lib

LANES = 128
POS_BITS = 16      # exception positions within a 4096 block (paper: 8 for 128)


@dataclasses.dataclass
class PatchedList:
    flat_words: jnp.ndarray    # (T, 128) uint32 — base packed at b'
    widths: jnp.ndarray        # (K,) int32 — b' per block
    offsets: jnp.ndarray       # (K,) int32
    maxes: jnp.ndarray         # (K,) uint32
    exc_pos: jnp.ndarray       # (E,) int32 — global padded positions
    exc_add: jnp.ndarray       # (E,) uint32 — high bits already shifted by b'
    n: int
    mode: str = "d1"
    block_rows: int = bitpack.DEFAULT_ROWS
    format_bits: int = 0       # honest storage accounting (paper format)

    @property
    def num_blocks(self):
        return self.widths.shape[0]

    def tree_flatten(self):
        return ((self.flat_words, self.widths, self.offsets, self.maxes,
                 self.exc_pos, self.exc_add),
                (self.n, self.mode, self.block_rows, self.format_bits))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, n=aux[0], mode=aux[1], block_rows=aux[2],
                   format_bits=aux[3])


jax.tree_util.register_pytree_node(
    PatchedList, PatchedList.tree_flatten, PatchedList.tree_unflatten)


def _best_base_width(d_flat: np.ndarray) -> tuple[int, int]:
    """Pick b' minimizing the paper's cost heuristic. Returns (b', b)."""
    N = d_flat.size
    bl = np.zeros(N, dtype=np.int32)
    nz = d_flat > 0
    bl[nz] = np.floor(np.log2(d_flat[nz].astype(np.float64))).astype(np.int32) + 1
    b = int(bl.max()) if N else 0
    counts = np.bincount(bl, minlength=b + 1)
    ge = np.cumsum(counts[::-1])[::-1]          # ge[w] = #deltas with bl > w-1
    best_bp, best_cost = b, N * b
    for bp in range(b + 1):
        c = int(ge[bp + 1]) if bp + 1 <= b else 0   # exceptions: bl > bp
        cost = N * bp + c * (b - bp + POS_BITS)
        if cost < best_cost:
            best_cost, best_bp = cost, bp
    return best_bp, b


def encode(values: np.ndarray, mode: str = "d1",
           block_rows: int = bitpack.DEFAULT_ROWS) -> PatchedList:
    v = np.asarray(values, dtype=np.int64).ravel()
    n = int(v.size)
    if n == 0:
        v = np.zeros(1, dtype=np.int64)
    per = block_rows * LANES
    npad = (-len(v)) % per
    if npad:
        v = np.concatenate([v, np.full(npad, v[-1], dtype=np.int64)])
    K = len(v) // per
    blocks = v.reshape(K, block_rows, LANES)
    maxes = blocks[:, -1, -1].copy()
    seeds = np.concatenate([[0], maxes[:-1]])
    d = deltas_lib.encode_deltas_np(blocks, seeds, mode)

    widths = np.zeros(K, dtype=np.int32)
    packed, all_pos, all_add = [], [], []
    format_bits = 0
    exc_class_counts = np.zeros(33, dtype=np.int64)   # per (b-b') class
    for k in range(K):
        dk = d[k].reshape(-1).astype(np.uint64)
        bp, b = _best_base_width(dk)
        widths[k] = bp
        mask = np.uint64((1 << bp) - 1) if bp else np.uint64(0)
        base = (dk & mask).astype(np.uint32)
        packed.append(bitpack.pack_block_np(
            base.reshape(block_rows, LANES), bp))
        exc = np.nonzero(dk > mask)[0]
        if exc.size:
            high = (dk[exc] >> np.uint64(bp)).astype(np.uint32)
            all_pos.append(exc.astype(np.int64) + k * per)
            all_add.append((high.astype(np.uint64) << np.uint64(bp))
                           .astype(np.uint32))
            exc_class_counts[b - bp] += exc.size
        # paper format: 2 width bytes + 1 exception-count byte per block
        format_bits += per * bp + 24 + exc.size * POS_BITS
        format_bits += 8 + 32          # our per-block metadata: width byte + max
    # high-bit arrays: bit-packed per class, padded to multiples of 32 ints
    for cls in range(1, 33):
        cnt = exc_class_counts[cls]
        if cnt:
            padded = int(np.ceil(cnt / 32) * 32)
            format_bits += padded * cls

    offsets = np.concatenate([[0], np.cumsum(widths[:-1])]).astype(np.int32)
    total_rows = int(widths.sum())
    flat = (np.concatenate(packed, axis=0) if total_rows
            else np.zeros((0, LANES), dtype=np.uint32))
    if flat.shape[0] == 0:
        flat = np.zeros((1, LANES), dtype=np.uint32)
    exc_pos = (np.concatenate(all_pos) if all_pos
               else np.zeros(0, np.int64)).astype(np.int32)
    exc_add = (np.concatenate(all_add) if all_add
               else np.zeros(0, np.uint32))
    return PatchedList(
        flat_words=jnp.asarray(flat), widths=jnp.asarray(widths),
        offsets=jnp.asarray(offsets), maxes=jnp.asarray(maxes.astype(np.uint32)),
        exc_pos=jnp.asarray(exc_pos), exc_add=jnp.asarray(exc_add),
        n=n, mode=mode, block_rows=block_rows, format_bits=int(format_bits))


@partial(jax.jit, static_argnames=("mode", "block_rows"))
def decode_device(flat_words, widths, offsets, seeds, exc_pos, exc_add,
                  mode: str, block_rows: int):
    """unpack → patch → prefix sum (three stages, paper §4 last paragraph)."""
    d = bitpack.unpack_deltas(flat_words, widths, offsets, block_rows)
    K = widths.shape[0]
    dflat = d.reshape(-1)
    dflat = dflat.at[exc_pos].add(exc_add, mode="drop")
    d = dflat.reshape(K, block_rows, LANES)
    return deltas_lib.prefix_sum(d, seeds, mode)


def decode(pl: PatchedList) -> jnp.ndarray:
    seeds = jnp.concatenate([jnp.zeros((1,), jnp.uint32), pl.maxes[:-1]])
    return decode_device(pl.flat_words, pl.widths, pl.offsets, seeds,
                         pl.exc_pos, pl.exc_add, pl.mode,
                         pl.block_rows).reshape(-1)


def decode_np(pl: PatchedList) -> np.ndarray:
    return np.asarray(decode(pl))[: pl.n]


def bits_per_int(pl: PatchedList) -> float:
    return pl.format_bits / max(pl.n, 1)
