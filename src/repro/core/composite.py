"""Composite codec: bitpack for full blocks, varint for the tail.

The SNIPPETS.md §1 shape — ``CompositeCodec<FastPFor, VariableByte>`` — from
the reference C++ libraries: block codecs only compress multiples of their
block size, so a composite pairs one with a byte-oriented tail codec for
the remainder.  Here the head is the seed S4-BP128 layout
(``bitpack.PackedList`` over the longest full-block prefix, zero padding
waste by construction) and the tail is the scalar varint baseline
(``varint.VarintList`` over the < block-size remainder), so short and
odd-length lists stop paying full-block padding.

The head alone is skip-capable, but the composite payload deliberately is
*not* (no top-level ``flat_words``/``maxes``): a skip probe over the head
would silently drop tail postings.  Composite lists therefore always serve
through ``DecodedSource`` — the autotuner only picks this codec for lists
short enough that the decode policy would apply anyway.

Tail values are coded absolute (varint's D1-from-0 framing): the first tail
delta then equals the first tail value, costing ≤ 5 bytes once per list —
cheaper than threading a seed through the varint container format.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import bitpack
from repro.core import varint as varint_lib

LANES = 128
DEFAULT_ROWS = 8           # 1024-int head blocks (the bp8 geometry)


@dataclasses.dataclass
class CompositeList:
    head: bitpack.PackedList | None   # full blocks only; None when n < block
    tail: varint_lib.VarintList       # remainder (may be zero-length)
    n: int
    mode: str = "d1"
    block_rows: int = DEFAULT_ROWS

    @property
    def n_head(self) -> int:
        return 0 if self.head is None else self.head.n

    @property
    def padded_n(self) -> int:
        return self.n_head + self.tail.n


def encode(values: np.ndarray, mode: str = "d1",
           block_rows: int = DEFAULT_ROWS) -> CompositeList:
    v = np.asarray(values, dtype=np.int64).ravel()
    n = int(v.size)
    per = block_rows * LANES
    n_head = (n // per) * per
    head = (bitpack.encode(v[:n_head], mode=mode, block_rows=block_rows)
            if n_head else None)
    tail = varint_lib.encode(v[n_head:])
    return CompositeList(head=head, tail=tail, n=n, mode=mode,
                         block_rows=block_rows)


def decode_np(cl: CompositeList) -> np.ndarray:
    """Exact-length host decode: bucketed head decode + scalar tail."""
    parts = []
    if cl.head is not None:
        parts.append(np.asarray(bitpack.decode_bucketed(cl.head))
                     [: cl.head.n].astype(np.int64))
    if cl.tail.n:
        parts.append(varint_lib.decode(cl.tail))
    if not parts:
        return np.zeros(0, np.int64)
    return np.concatenate(parts)


def decode(cl: CompositeList) -> np.ndarray:
    return decode_np(cl)


def bits_per_int(cl: CompositeList) -> float:
    bits = 0.0
    if cl.head is not None:
        bits += bitpack.bits_per_int(cl.head) * cl.head.n
    bits += varint_lib.bits_per_int(cl.tail) * cl.tail.n
    return bits / max(cl.n, 1)
