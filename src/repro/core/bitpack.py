"""Block bit packing for sorted 32-bit integers (paper §3, S4-BP128 → TPU).

Layout (DESIGN.md §2.1): a block is ROWS×128 integers viewed as a (ROWS, 128)
tile; lane ``l`` packs its ROWS integers vertically into ``b`` 32-bit words, so
a block packs to a (b, 128) tile.  Blocks concatenate into one flat
(total_rows, 128) uint32 word array with per-block row offsets.

Unpacking is *width-generic*: for output row ``r`` the source word index
``(r*b)//32`` and shift ``(r*b)%32`` are computed from the (traced) width, so a
single gather-based decoder handles every bit width in one call — no per-width
dispatch (beyond-paper; DESIGN.md §2.1).

Decoding integrates the differential-coding prefix sum (paper Algorithm 1) in
the same jitted function; ``decode_ni`` is the two-pass ("-NI") variant used by
benchmarks to reproduce Fig. 1a.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import deltas as deltas_lib

LANES = 128
DEFAULT_ROWS = 32          # 4096-integer blocks; 8 → 1024-integer blocks


# --------------------------------------------------------------------------
# container
# --------------------------------------------------------------------------

@dataclasses.dataclass
class PackedList:
    """Device representation of one compressed sorted list."""
    flat_words: jnp.ndarray    # (total_rows, 128) uint32
    widths: jnp.ndarray        # (K,) int32   bit width per block
    offsets: jnp.ndarray       # (K,) int32   row offset of each block
    maxes: jnp.ndarray         # (K,) uint32  last value of each block (skip index)
    n: int                     # valid count (static)
    mode: str = "d1"           # delta mode (static)
    block_rows: int = DEFAULT_ROWS

    @property
    def num_blocks(self) -> int:
        return self.widths.shape[0]

    @property
    def padded_n(self) -> int:
        return self.num_blocks * self.block_rows * LANES

    def tree_flatten(self):
        return (self.flat_words, self.widths, self.offsets, self.maxes), (
            self.n, self.mode, self.block_rows)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, n=aux[0], mode=aux[1], block_rows=aux[2])


jax.tree_util.register_pytree_node(
    PackedList, PackedList.tree_flatten, PackedList.tree_unflatten)


# --------------------------------------------------------------------------
# batch-uniform layout (posting-source layer, DESIGN.md §2.6)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class PackedLayout:
    """Host-side (numpy) view of one compressed list, padded to shared
    bucket sizes so a batch of lists stacks into uniform device operands.

    Both ``PackedList`` and ``fastpfor.PatchedList`` project onto this one
    shape family (a plain bitpacked list simply has zero exceptions), which
    is what lets the batched scheduler treat every skip-capable codec
    through a single program signature.
    """
    words: np.ndarray      # (t_pad, 128) uint32
    widths: np.ndarray     # (k_pad,) int32   (pad blocks: width 0)
    offsets: np.ndarray    # (k_pad,) int32   (pad blocks: clamped in-range)
    maxes: np.ndarray      # (k_pad,) uint32  (edge-padded → stays monotone)
    exc_pos: np.ndarray    # (e_pad,) int32   (pad entries: -1 → dropped)
    exc_add: np.ndarray    # (e_pad,) uint32
    n: int
    mode: str
    block_rows: int


def skip_capable(payload) -> bool:
    """True when the payload carries the flat packed-block layout (and so a
    block-max skip index): PackedList and fastpfor.PatchedList both do."""
    return all(hasattr(payload, a)
               for a in ("flat_words", "widths", "offsets", "maxes"))


def layout_np(payload, k_pad: int, t_pad: int, e_pad: int) -> PackedLayout:
    """Project a skip-capable payload onto the batch-uniform layout.

    k_pad/t_pad/e_pad are the group's shared block/word-row/exception
    buckets (each ≥ the payload's own counts).
    """
    widths = np.asarray(payload.widths)
    offsets = np.asarray(payload.offsets)
    maxes = np.asarray(payload.maxes)
    words = np.asarray(payload.flat_words)
    K, T = widths.shape[0], words.shape[0]
    assert K <= k_pad and T <= t_pad, (K, k_pad, T, t_pad)
    w = np.zeros(k_pad, np.int32)
    w[:K] = widths
    o = np.full(k_pad, max(T - 1, 0), np.int32)
    o[:K] = offsets
    mx = np.zeros(k_pad, np.uint32)
    mx[:K] = maxes
    mx[K:] = maxes[-1] if K else 0          # edge pad keeps maxes monotone
    fw = np.zeros((t_pad, LANES), np.uint32)
    fw[:T] = words
    ep_src = np.asarray(getattr(payload, "exc_pos", np.zeros(0, np.int32)))
    ea_src = np.asarray(getattr(payload, "exc_add", np.zeros(0, np.uint32)))
    E = ep_src.shape[0]
    assert E <= e_pad, (E, e_pad)
    ep = np.full(e_pad, -1, np.int32)
    ep[:E] = ep_src
    ea = np.zeros(e_pad, np.uint32)
    ea[:E] = ea_src
    return PackedLayout(words=fw, widths=w, offsets=o, maxes=mx,
                        exc_pos=ep, exc_add=ea, n=payload.n,
                        mode=payload.mode, block_rows=payload.block_rows)


def self_pads(payload) -> tuple[int, int, int]:
    """A skip-capable payload's own pow2 (k_pad, t_pad, e_pad) buckets — the
    canonical pads for memoizing its PackedLayout projection.  Group buckets
    are maxima of member self-pads, so a self-padded layout zero-extends
    into any group slot that admits it (pad blocks: width 0, offsets
    in-bounds, maxes never read past the real block count)."""
    k = int(np.asarray(payload.widths).shape[0])
    t = int(np.asarray(payload.flat_words).shape[0])
    e = int(np.asarray(getattr(payload, "exc_pos",
                               np.zeros(0))).shape[0])
    return (_pow2(k), _pow2(t), _pow2(e) if e else 0)


def candidate_block_ids(maxes_np: np.ndarray, values: np.ndarray) -> np.ndarray:
    """Unique block ids whose value range may contain any of ``values``
    (host-side probe of the block-max skip index).  ``values`` are the valid
    (unpadded) candidate doc ids; since SvS candidates only shrink, ids
    computed from the initial candidate set stay a superset for every
    later fold."""
    mx = np.asarray(maxes_np).astype(np.int64)
    v = np.asarray(values, dtype=np.int64)
    if mx.size == 0 or v.size == 0:
        return np.zeros(0, np.int32)
    blk = np.searchsorted(mx, v, side="left")
    blk = np.minimum(blk, mx.size - 1)
    return np.unique(blk).astype(np.int32)


# --------------------------------------------------------------------------
# host-side pack (numpy)
# --------------------------------------------------------------------------

def pack_block_np(deltas_block: np.ndarray, width: int) -> np.ndarray:
    """deltas_block: (R, 128) uint32 with values < 2**width -> (width, 128)."""
    R, L = deltas_block.shape
    if width == 0:
        return np.zeros((0, L), dtype=np.uint32)
    d = deltas_block.astype(np.uint64)
    out = np.zeros((width, L), dtype=np.uint64)
    for r in range(R):
        start = r * width
        w, sh = divmod(start, 32)
        out[w] |= d[r] << np.uint64(sh)
        if sh + width > 32:
            out[w + 1] |= d[r] >> np.uint64(32 - sh)
    return (out & np.uint64(0xFFFFFFFF)).astype(np.uint32)


def encode(values: np.ndarray, mode: str = "d1",
           block_rows: int | None = None) -> PackedList:
    """Compress a sorted 1-D array of non-negative ints (< 2**32) on the host.

    block_rows=None picks the block size adaptively: short lists use
    1024-int blocks (8 rows) so tail padding does not dominate — measured
    37→~11 bits/int on ~1k-item posting lists (EXPERIMENTS §Perf, codec
    iteration c2); long lists use the TPU-native 4096-int blocks."""
    v = np.asarray(values, dtype=np.int64).ravel()
    n = int(v.size)
    if block_rows is None:
        block_rows = 8 if n <= 8192 else DEFAULT_ROWS
    if n == 0:
        v = np.zeros(1, dtype=np.int64)
    per = block_rows * LANES
    npad = (-len(v)) % per
    if npad:
        v = np.concatenate([v, np.full(npad, v[-1], dtype=np.int64)])
    K = len(v) // per
    blocks = v.reshape(K, block_rows, LANES)
    maxes = blocks[:, -1, -1].copy()
    seeds = np.concatenate([[0], maxes[:-1]])
    d = deltas_lib.encode_deltas_np(blocks, seeds, mode)
    widths = np.array(
        [int(d[k].max()).bit_length() for k in range(K)], dtype=np.int32)
    packed = [pack_block_np(d[k], int(widths[k])) for k in range(K)]
    offsets = np.concatenate([[0], np.cumsum(widths[:-1])]).astype(np.int32)
    total_rows = int(widths.sum())
    flat = (np.concatenate(packed, axis=0) if total_rows
            else np.zeros((0, LANES), dtype=np.uint32))
    if flat.shape[0] == 0:                      # keep gathers in-bounds
        flat = np.zeros((1, LANES), dtype=np.uint32)
    return PackedList(
        flat_words=jnp.asarray(flat),
        widths=jnp.asarray(widths),
        offsets=jnp.asarray(offsets),
        maxes=jnp.asarray(maxes.astype(np.uint32)),
        n=n, mode=mode, block_rows=block_rows)


# --------------------------------------------------------------------------
# device-side unpack + integrated prefix sum (jnp)
# --------------------------------------------------------------------------

def unpack_deltas(flat_words, widths, offsets, block_rows: int = DEFAULT_ROWS):
    """Width-generic gather-based bit unpack.

    flat_words: (T, 128) uint32; widths/offsets: (K,) int32.
    Returns (K, block_rows, 128) uint32 deltas.
    """
    T = flat_words.shape[0]
    K = widths.shape[0]
    r = jnp.arange(block_rows, dtype=jnp.int32)            # (R,)
    b = widths[:, None]                                    # (K, 1)
    start = r[None, :] * b                                 # (K, R) bit offset
    w = start >> 5
    sh = (start & 31).astype(jnp.uint32)
    idx_lo = jnp.clip(offsets[:, None] + w, 0, T - 1)
    idx_hi = jnp.clip(offsets[:, None] + w + 1, 0, T - 1)
    lo = jnp.take(flat_words, idx_lo, axis=0)              # (K, R, 128)
    hi = jnp.take(flat_words, idx_hi, axis=0)
    bu = b.astype(jnp.uint32)
    mask = jnp.where(b >= 32, jnp.uint32(0xFFFFFFFF),
                     (jnp.uint32(1) << jnp.minimum(bu, 31)) - 1)[..., None]
    spill = (sh + bu) > 32                                 # (K, R)
    val = lo >> sh[..., None]
    hi_part = hi << (((jnp.uint32(32) - sh) & 31)[..., None])
    val = jnp.where(spill[..., None], val | hi_part, val)
    return val & mask


@partial(jax.jit, static_argnames=("mode", "block_rows"))
def decode_integrated(flat_words, widths, offsets, seeds, mode: str,
                      block_rows: int = DEFAULT_ROWS):
    """One-pass unpack + prefix sum (paper's integrated Algorithm 1)."""
    d = unpack_deltas(flat_words, widths, offsets, block_rows)
    return deltas_lib.prefix_sum(d, seeds, mode)


@partial(jax.jit, static_argnames=("block_rows",))
def _unpack_only(flat_words, widths, offsets, block_rows: int = DEFAULT_ROWS):
    return unpack_deltas(flat_words, widths, offsets, block_rows)


@partial(jax.jit, static_argnames=("mode",))
def _prefix_only(d, seeds, mode: str):
    return deltas_lib.prefix_sum(d, seeds, mode)


def seeds_of(pl: PackedList) -> jnp.ndarray:
    return jnp.concatenate(
        [jnp.zeros((1,), jnp.uint32), pl.maxes[:-1]])


def decode(pl: PackedList) -> jnp.ndarray:
    """Decode a PackedList to its (padded) flat value array (padded_n,)."""
    vals = decode_integrated(pl.flat_words, pl.widths, pl.offsets, seeds_of(pl),
                             pl.mode, pl.block_rows)
    return vals.reshape(-1)


def decode_ni(pl: PackedList) -> jnp.ndarray:
    """Two-pass (-NI) decode: deltas materialized, prefix sum separate."""
    d = _unpack_only(pl.flat_words, pl.widths, pl.offsets, pl.block_rows)
    jax.block_until_ready(d)
    return _prefix_only(d, seeds_of(pl), pl.mode).reshape(-1)


def decode_np(pl: PackedList) -> np.ndarray:
    """Decode and trim to the valid length (host round-trip convenience)."""
    return np.asarray(decode(pl))[: pl.n]


def _pow2(n: int, floor: int = 1) -> int:
    size = floor
    while size < n:
        size *= 2
    return size


def decode_bucketed(pl: PackedList) -> jnp.ndarray:
    """Decode with (K, T) padded to powers of two: bounds the number of jit
    specializations in serving to O(log^2) — shape bucketing, the standard
    JAX serving pattern.  Padding blocks have width 0 and decode to the seed
    value; callers trim to pl.n as usual."""
    K = pl.num_blocks
    T = pl.flat_words.shape[0]
    Kp, Tp = _pow2(K), _pow2(T)
    widths = jnp.pad(pl.widths, (0, Kp - K))
    offsets = jnp.pad(pl.offsets, (0, Kp - K), constant_values=T - 1)
    maxes = jnp.pad(pl.maxes, (0, Kp - K), mode="edge" if K else "constant")
    flat = jnp.pad(pl.flat_words, ((0, Tp - T), (0, 0)))
    seeds = jnp.concatenate([jnp.zeros((1,), jnp.uint32), maxes[:-1]])
    vals = decode_integrated(flat, widths, offsets, seeds, pl.mode,
                             pl.block_rows)
    return vals.reshape(-1)


# --------------------------------------------------------------------------
# accounting
# --------------------------------------------------------------------------

def bits_per_int(pl: PackedList) -> float:
    """Storage cost: packed words + per-block metadata (1B width + 4B max)."""
    data_bits = int(np.asarray(pl.widths).sum()) * LANES * 32
    meta_bits = pl.num_blocks * (8 + 32)
    return (data_bits + meta_bits) / max(pl.n, 1)
