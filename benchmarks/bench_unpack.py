"""Paper Fig. 1a/1b: bit-unpacking speed, integrated vs two-pass (-NI)
differential coding, for every delta mode across bit widths.

Derived column: Gints/s and the integrated/NI speed ratio (Fig. 1a's y-axis).
The paper's claim to reproduce: integration helps most for the cheap-prefix
modes (D4/DM on SSE ↔ dv/dm here), and wider-stride modes decode faster.
"""

from __future__ import annotations

import numpy as np

from repro.core import bitpack
from benchmarks.common import emit, packed_fold_operands, timeit

N = 1 << 18                 # 64 blocks of 4096


def _list_with_width(rng, b: int, mode: str) -> bitpack.PackedList:
    """Sorted list whose per-block widths are ≈b for the given mode."""
    if b >= 30:
        gaps = rng.integers(1 << 24, 1 << 26, size=N)
    else:
        lo = max((1 << b) // 256, 1)
        gaps = rng.integers(lo, max(2 * lo, lo + 2), size=N)
    x = np.cumsum(gaps.astype(np.int64))
    x = x % (1 << 31)
    x = np.sort(np.unique(x))
    return bitpack.encode(x, mode=mode)


def fused_ab(quick: bool = False):
    """Fused-vs-staged decode A/B (ISSUE 7): staged = kernel-decode the
    WHOLE compressed list to a materialized array, then gallop-probe it;
    fused = the decode+intersect megakernel, which unpacks only the rare
    row's candidate blocks in kernel scratch.  The derived columns —
    decoded ints avoided and ns per decoded int — feed the codec
    autotuner cost table planned in ROADMAP.  Both sides run the kernel
    layer, so the comparison is mode-consistent (label = kernel_mode)."""
    import jax.numpy as jnp
    from repro.core import intersect as its
    from repro.kernels import ops

    rng = np.random.default_rng(3)
    n = 1 << 16 if quick else 1 << 18
    for mode in (["d1"] if quick else ["d1", "dv"]):
        gaps = rng.integers(1, 64, size=n)
        x = np.unique(np.cumsum(gaps.astype(np.int64)) % (1 << 30))
        plist = bitpack.encode(x, mode=mode)
        # few, clustered probes: the skip regime where partial decode wins
        m = 8 if quick else 32
        r_np = np.sort(rng.choice(x[: len(x) // 4], m,
                                  replace=False)).astype(np.int32)
        r, valid, pk, active, c_pad = packed_fold_operands(r_np, plist)
        per = plist.block_rows * 128

        def staged():
            vals = ops.decode_packed(plist).astype(jnp.int32)
            return ops.intersect_gallop(r[0], vals)

        def fused():
            return ops.intersect_packed_fold(r, valid, pk, active,
                                             mode=mode,
                                             block_rows=plist.block_rows)

        assert np.array_equal(
            np.asarray(fused()),
            np.asarray(staged()) & np.asarray(valid)), "A/B mismatch"
        t_staged = timeit(staged, reps=2)
        t_fused = timeit(fused, reps=2)
        dec_staged, dec_fused = plist.padded_n, c_pad * per
        emit(f"unpack/fused_ab/{mode}/staged", t_staged,
             f"{t_staged / dec_staged * 1e9:.2f} ns/int; "
             f"{dec_staged} decoded ints [{ops.kernel_mode()}]")
        emit(f"unpack/fused_ab/{mode}/fused", t_fused,
             f"{t_fused / dec_fused * 1e9:.2f} ns/int; "
             f"{dec_fused} decoded ints "
             f"({dec_staged - dec_fused} avoided, "
             f"{t_staged / t_fused:.1f}x) [{ops.kernel_mode()}]")


def run(quick: bool = False):
    rng = np.random.default_rng(0)
    widths = [2, 8, 16] if quick else [1, 2, 4, 8, 12, 16, 20, 24]
    for mode in ["d1", "d2", "d4", "dm", "dv"]:
        for b in widths:
            pl = _list_with_width(rng, b, mode)
            bw = float(np.asarray(pl.widths).mean())
            t_int = timeit(lambda: bitpack.decode(pl))
            t_ni = timeit(lambda: bitpack.decode_ni(pl))
            gints = pl.padded_n / t_int / 1e9
            ratio = t_ni / t_int
            emit(f"unpack/{mode}/b{b}", t_int,
                 f"{gints:.3f} Gints/s; int/NI speedup {ratio:.2f}; "
                 f"avg width {bw:.1f}")
    fused_ab(quick)


if __name__ == "__main__":
    run()
