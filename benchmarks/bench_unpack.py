"""Paper Fig. 1a/1b: bit-unpacking speed, integrated vs two-pass (-NI)
differential coding, for every delta mode across bit widths.

Derived column: Gints/s and the integrated/NI speed ratio (Fig. 1a's y-axis).
The paper's claim to reproduce: integration helps most for the cheap-prefix
modes (D4/DM on SSE ↔ dv/dm here), and wider-stride modes decode faster.
"""

from __future__ import annotations

import numpy as np

from repro.core import bitpack
from benchmarks.common import emit, timeit

N = 1 << 18                 # 64 blocks of 4096


def _list_with_width(rng, b: int, mode: str) -> bitpack.PackedList:
    """Sorted list whose per-block widths are ≈b for the given mode."""
    if b >= 30:
        gaps = rng.integers(1 << 24, 1 << 26, size=N)
    else:
        lo = max((1 << b) // 256, 1)
        gaps = rng.integers(lo, max(2 * lo, lo + 2), size=N)
    x = np.cumsum(gaps.astype(np.int64))
    x = x % (1 << 31)
    x = np.sort(np.unique(x))
    return bitpack.encode(x, mode=mode)


def run(quick: bool = False):
    rng = np.random.default_rng(0)
    widths = [2, 8, 16] if quick else [1, 2, 4, 8, 12, 16, 20, 24]
    for mode in ["d1", "d2", "d4", "dm", "dv"]:
        for b in widths:
            pl = _list_with_width(rng, b, mode)
            bw = float(np.asarray(pl.widths).mean())
            t_int = timeit(lambda: bitpack.decode(pl))
            t_ni = timeit(lambda: bitpack.decode_ni(pl))
            gints = pl.padded_n / t_int / 1e9
            ratio = t_ni / t_int
            emit(f"unpack/{mode}/b{b}", t_int,
                 f"{gints:.3f} Gints/s; int/NI speedup {ratio:.2f}; "
                 f"avg width {bw:.1f}")


if __name__ == "__main__":
    run()
