"""Paper Fig. 2a/2b: intersection speed across cardinality ratios.

Lists built exactly as §6.6: target |f| = n, |r| = n/ratio, guaranteed
intersection ≥ m/3, ClusterData distribution in [0, 2^26).  Baseline is
numpy's C merge (np.intersect1d) standing in for the paper's SCALAR.
Derived: relative speed vs SCALAR (the paper's y-axis) — used to re-derive
the V1/galloping dispatch thresholds (TILED_MAX_RATIO) on this platform.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import bitpack
from repro.core import intersect as its
from repro.data.clusterdata import paired_lists
from benchmarks.common import emit, timeit


def run(quick: bool = False):
    rng = np.random.default_rng(2)
    n = 1 << 18 if quick else 1 << 20
    ratios = [1, 16, 256] if quick else [1, 4, 16, 64, 256, 1024, 4096]
    for ratio in ratios:
        m = max(n // ratio, 4)
        r, f = paired_lists(rng, m, n)
        t_scalar = timeit(lambda: np.intersect1d(r, f), reps=2)

        M = its.pow2_bucket(len(r))
        N = its.pow2_bucket(len(f), floor=1024)
        rp = jnp.asarray(its.pad_to(r, M))
        fp = jnp.asarray(its.pad_to(f, N))
        pf = bitpack.encode(f, mode="d1")

        algos = [
            ("tiled", lambda: its.intersect_tiled(
                rp, fp, tile_r=min(128, M), tile_f=min(1024, N))),
            ("gallop", lambda: its.intersect_gallop(rp, fp)),
            ("auto", lambda: its.intersect_auto(rp, fp, len(r), len(f))),
        ]
        if ratio >= 64:
            # packed-gallop decodes one block per r element: it is the
            # high-ratio algorithm (paper's galloping regime); at low ratios
            # it does m×4096 decode work by construction — skipped, and the
            # skip is the documented behaviour of the dispatch heuristic.
            algos.insert(2, ("packed-gallop",
                             lambda: its.intersect_packed(rp, pf)))
        for name, fn in algos:
            t = timeit(fn)
            emit(f"intersect/r{ratio}/{name}", t,
                 f"{t_scalar / t:.2f}x vs scalar; m={m} n={n}")


if __name__ == "__main__":
    run()
