"""Paper Fig. 2a/2b: intersection speed across cardinality ratios.

Lists built exactly as §6.6: target |f| = n, |r| = n/ratio, guaranteed
intersection ≥ m/3, ClusterData distribution in [0, 2^26).  Baseline is
numpy's C merge (np.intersect1d) standing in for the paper's SCALAR.
Derived: relative speed vs SCALAR (the paper's y-axis) — used to re-derive
the V1/galloping dispatch thresholds (TILED_MAX_RATIO) on this platform.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import bitpack
from repro.core import intersect as its
from repro.data.clusterdata import paired_lists
from benchmarks.common import emit, packed_fold_operands, timeit


def fused_ab(quick: bool = False):
    """Fused-vs-staged intersection A/B (ISSUE 7), at the high cardinality
    ratios where the galloping regime lives: staged = kernel-decode the
    whole long list then gallop-probe the materialized array; fused = the
    decode+intersect megakernel (candidate blocks unpacked in kernel
    scratch, no materialized array).  Reports ns per rare-list int and the
    decoded ints the fused path avoids — cost-table inputs for the codec
    autotuner planned in ROADMAP."""
    from repro.kernels import ops

    rng = np.random.default_rng(9)
    n = 1 << 18 if quick else 1 << 20
    for ratio in ([1024] if quick else [256, 4096]):
        m = max(n // ratio, 4)
        r_l, f = paired_lists(rng, m, n)
        pf = bitpack.encode(f, mode="d1")
        r, valid, pk, active, c_pad = packed_fold_operands(
            np.asarray(r_l, np.int32), pf)
        per = pf.block_rows * 128

        def staged():
            vals = ops.decode_packed(pf).astype(jnp.int32)
            return ops.intersect_gallop(r[0], vals)

        def fused():
            return ops.intersect_packed_fold(r, valid, pk, active,
                                             mode="d1",
                                             block_rows=pf.block_rows)

        assert np.array_equal(
            np.asarray(fused()),
            np.asarray(staged()) & np.asarray(valid)), "A/B mismatch"
        t_staged = timeit(staged, reps=2)
        t_fused = timeit(fused, reps=2)
        avoided = pf.padded_n - c_pad * per
        emit(f"intersect/fused_ab/r{ratio}/staged", t_staged,
             f"{t_staged / m * 1e9:.0f} ns/r-int; {pf.padded_n} decoded "
             f"ints [{ops.kernel_mode()}]")
        emit(f"intersect/fused_ab/r{ratio}/fused", t_fused,
             f"{t_fused / m * 1e9:.0f} ns/r-int; {c_pad * per} decoded "
             f"ints ({avoided} avoided, {t_staged / t_fused:.1f}x) "
             f"[{ops.kernel_mode()}]")


def run(quick: bool = False):
    rng = np.random.default_rng(2)
    n = 1 << 18 if quick else 1 << 20
    ratios = [1, 16, 256] if quick else [1, 4, 16, 64, 256, 1024, 4096]
    for ratio in ratios:
        m = max(n // ratio, 4)
        r, f = paired_lists(rng, m, n)
        t_scalar = timeit(lambda: np.intersect1d(r, f), reps=2)

        M = its.pow2_bucket(len(r))
        N = its.pow2_bucket(len(f), floor=1024)
        rp = jnp.asarray(its.pad_to(r, M))
        fp = jnp.asarray(its.pad_to(f, N))
        pf = bitpack.encode(f, mode="d1")

        algos = [
            ("tiled", lambda: its.intersect_tiled(
                rp, fp, tile_r=min(128, M), tile_f=min(1024, N))),
            ("gallop", lambda: its.intersect_gallop(rp, fp)),
            ("auto", lambda: its.intersect_auto(rp, fp, len(r), len(f))),
        ]
        if ratio >= 64:
            # packed-gallop decodes one block per r element: it is the
            # high-ratio algorithm (paper's galloping regime); at low ratios
            # it does m×4096 decode work by construction — skipped, and the
            # skip is the documented behaviour of the dispatch heuristic.
            algos.insert(2, ("packed-gallop",
                             lambda: its.intersect_packed(rp, pf)))
        for name, fn in algos:
            t = timeit(fn)
            emit(f"intersect/r{ratio}/{name}", t,
                 f"{t_scalar / t:.2f}x vs scalar; m={m} n={n}")
    fused_ab(quick)


if __name__ == "__main__":
    run()
