"""Benchmark runner — one module per paper table/figure:

  bench_unpack        Fig. 1a/1b   (integrated vs -NI unpacking, all modes)
  bench_decode        Table 3      (ClusterData decode speed + bits/int)
  bench_intersect     Fig. 2a/2b   (intersection speed vs cardinality ratio)
  bench_hybrid        Tables 4/5   (HYB+M2 conjunctive queries)
  bench_engine        beyond-paper (batched vs sequential query throughput)
  bench_gradcompress  beyond-paper (codec on the DP gradient wire)

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` runs a reduced sweep.
Roofline terms (§Roofline) come from the dry-run artifacts:
  python -m repro.launch.roofline results/dryrun
"""

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated subset, e.g. unpack,decode")
    args = ap.parse_args()

    from benchmarks import (bench_decode, bench_engine, bench_gradcompress,
                            bench_hybrid, bench_intersect, bench_unpack)
    mods = {"unpack": bench_unpack, "decode": bench_decode,
            "intersect": bench_intersect, "hybrid": bench_hybrid,
            "engine": bench_engine, "gradcompress": bench_gradcompress}
    subset = args.only.split(",") if args.only else list(mods)
    print("name,us_per_call,derived")
    for name in subset:
        print(f"# --- {name} ---", file=sys.stderr, flush=True)
        mods[name].run(quick=args.quick)


if __name__ == "__main__":
    main()
