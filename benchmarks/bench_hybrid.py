"""Paper Tables 4/5: conjunctive query time (ms/query) + index size
(bits/int) for HYB+M2 with B ∈ {0, 8, 16, 32}, codecs × partitioned /
unpartitioned, on the synthetic corpus fitted to Table 2 marginals.

Two regimes, matching the paper: Table 5 decodes per query ("decode" rows);
Table 4 intersects already-decoded lists — here an LRU DecodeCache
("cached" rows), reported for B ∈ {0, 16}."""

from __future__ import annotations

import time

import numpy as np

from repro.index import builder, corpus as corpus_lib, engine
from benchmarks.common import emit


def run(quick: bool = False):
    n_docs = 1 << 16 if quick else 1 << 18
    n_q = 8 if quick else 24
    corpus = corpus_lib.synthesize(n_docs=n_docs, n_queries=n_q, seed=4)
    codec_names = (["bp-d1", "varint"] if quick
                   else ["varint", "fastpfor-d1", "bp-d1", "bp-d2", "bp-dm",
                         "bp-dv"])
    for B in [0, 8, 16, 32]:
        for parts in ([1] if quick else [1, 4]):
            for name in codec_names:
                idx = builder.build(corpus.postings, corpus.n_docs,
                                    codec_name=name, B=B, n_parts=parts)
                # warm the jit caches on the first query
                engine.query(idx, corpus.queries[0])
                t0 = time.perf_counter()
                total = 0
                for q in corpus.queries:
                    total += engine.query(idx, q).count
                dt = (time.perf_counter() - t0) / len(corpus.queries)
                st = idx.stats()
                emit(f"hybrid/B{B}/p{parts}/{name}", dt,
                     f"{dt * 1e3:.2f} ms/query; "
                     f"{st['bits_per_int']:.1f} bits/int; hits {total}")
                if B in (0, 16) and parts == 1 and name in (
                        "fastpfor-d1", "bp-d1", "varint"):
                    # Table 4 regime: SvS over cached decoded lists
                    cache = engine.DecodeCache(capacity_ints=1 << 26)
                    for q in corpus.queries:          # warm the cache
                        engine.query(idx, q, cache=cache)
                    t0 = time.perf_counter()
                    for q in corpus.queries:
                        engine.query(idx, q, cache=cache)
                    dt = (time.perf_counter() - t0) / len(corpus.queries)
                    emit(f"hybrid/B{B}/cached/{name}", dt,
                         f"{dt * 1e3:.2f} ms/query (Table-4 regime)")


if __name__ == "__main__":
    run()
