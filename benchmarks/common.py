"""Shared benchmark harness utilities.

Timing protocol: jit-warmup call excluded, then ``reps`` timed calls with
block_until_ready; report the best-of-3 mean (paper reports averages of
repeated runs).  Output rows are ``name,us_per_call,derived`` CSV (derived =
benchmark-specific figure of merit, e.g. Gints/s or bits/int).
"""

from __future__ import annotations

import time

import jax

ROWS = []


def timeit(fn, *args, reps: int = 5) -> float:
    """Best-of-3 mean seconds per call."""
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / reps)
    return best


def emit(name: str, seconds: float, derived: str = ""):
    row = f"{name},{seconds * 1e6:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)
