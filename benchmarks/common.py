"""Shared benchmark harness utilities.

Timing protocol: jit-warmup call excluded, then ``reps`` timed calls with
block_until_ready; report the best-of-3 mean (paper reports averages of
repeated runs).  Output rows are ``name,us_per_call,derived`` CSV (derived =
benchmark-specific figure of merit, e.g. Gints/s or bits/int).
"""

from __future__ import annotations

import time

import jax

ROWS = []


def timeit(fn, *args, reps: int = 5) -> float:
    """Best-of-3 mean seconds per call."""
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / reps)
    return best


def emit(name: str, seconds: float, derived: str = ""):
    row = f"{name},{seconds * 1e6:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def packed_fold_operands(r_np, plist):
    """Stack one (rare row, compressed list) pair into the (Jp=1, B=1, ...)
    operand tuple of ``kernels.ops.intersect_packed_fold`` — the megakernel
    single-slot harness shared by the fused-vs-staged A/B sections of
    bench_unpack / bench_intersect (ISSUE 7).  Returns (r, valid, pk,
    active, c_pad) with r sentinel-padded to a 128-multiple pow2 bucket."""
    import numpy as np
    import jax.numpy as jnp
    from repro.core import bitpack, intersect as its

    M = its.pow2_bucket(len(r_np))
    r = jnp.asarray(its.pad_to(np.asarray(r_np, np.int32), M))[None]
    k_pad = its.pow2_bucket(plist.widths.shape[0], floor=1)
    t_pad = its.pow2_bucket(max(plist.flat_words.shape[0], 1), floor=1)
    lay = bitpack.layout_np(plist, k_pad, t_pad, 0)
    blk = bitpack.candidate_block_ids(np.asarray(plist.maxes), r_np)
    c_pad = its.pow2_bucket(max(len(blk), 1), floor=1)
    bl = np.full(c_pad, k_pad, np.int32)
    bl[: len(blk)] = blk
    pk = (jnp.asarray(lay.words)[None, None],
          jnp.asarray(lay.widths)[None, None],
          jnp.asarray(lay.offsets)[None, None],
          jnp.asarray(lay.maxes)[None, None],
          jnp.asarray(bl)[None, None],
          jnp.full((1, 1, 0), -1, jnp.int32),
          jnp.zeros((1, 1, 0), jnp.uint32))
    valid = jnp.ones((1, M), bool)
    active = jnp.ones((1, 1), bool)
    return r, valid, pk, active, c_pad
