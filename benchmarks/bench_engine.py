"""Batched vs sequential query-engine throughput (ISSUE 1 acceptance gate).

Replays a Table-2-shaped query log (2–5 terms, skewed per-position list
lengths) through the sequential engine (one device dispatch per fold, host
round-trips between terms) and the shape-bucketed batched scheduler at
several batch sizes.  Two regimes, as in the paper:

  * cached   — Table 4: SvS over already-decoded lists (DecodeCache on both
               paths); isolates intersection + dispatch, which is what the
               batched engine accelerates.  Gate: ≥ 2× at batch ≥ 32.
  * uncached — Table 5: decode per query; both paths pay the same host-side
               decode, which dilutes the speedup.

Derived column reports queries/sec and the speedup over the sequential run
of the same regime.
"""

from __future__ import annotations

import time

from benchmarks.common import emit


def _qps(fn, n_queries: int, reps: int = 3) -> float:
    fn()                                    # warm / compile / fill cache
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return n_queries / best


def run(quick: bool = False) -> None:
    from repro.index import builder, corpus as corpus_lib, engine
    from repro.index import batch as batch_lib

    table = {k: corpus_lib.TABLE2_CLUEWEB[k] for k in (2, 3, 4, 5)}
    n_docs = 1 << 14 if quick else 1 << 16
    n_queries = 32 if quick else 128
    corpus = corpus_lib.synthesize(n_docs=n_docs, n_queries=n_queries,
                                   seed=11, table=table)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="fastpfor-d1", B=16, n_parts=2)
    queries = corpus.queries
    batch_sizes = [8, 32] if quick else [8, 32, 128]

    for regime in ["cached", "uncached"]:
        def make_cache():
            return (engine.DecodeCache(capacity_ints=1 << 26)
                    if regime == "cached" else None)

        seq_cache = make_cache()
        seq_qps = _qps(lambda: [engine.query(idx, q, cache=seq_cache)
                                for q in queries], len(queries))
        emit(f"engine/{regime}/sequential", 1.0 / seq_qps,
             f"{seq_qps:.1f} q/s")
        for bs in batch_sizes:
            bat_cache = make_cache()

            def run_batched(bs=bs, cache=bat_cache):
                out = []
                for lo in range(0, len(queries), bs):
                    out.extend(batch_lib.execute_batch(
                        idx, queries[lo: lo + bs], cache=cache))
                return out

            qps = _qps(run_batched, len(queries))
            emit(f"engine/{regime}/batched_b{bs}", 1.0 / qps,
                 f"{qps:.1f} q/s {qps / seq_qps:.2f}x")
