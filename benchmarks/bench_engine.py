"""Batched vs sequential query-engine throughput + partial-decode accounting
(ISSUE 1 + ISSUE 2 + ISSUE 3 + ISSUE 4 acceptance gates).

Replays a Table-2-shaped query log (2–5 terms, skewed per-position list
lengths) through the sequential engine (one device dispatch per fold, host
round-trips between terms) and the shape-bucketed batched scheduler at
several batch sizes.  Two regimes, as in the paper:

  * cached   — Table 4: SvS over already-decoded lists (DecodeCache on both
               paths); isolates intersection + dispatch, which is what the
               batched engine accelerates.  Gate: ≥ 2× at batch ≥ 32.
  * uncached — Table 5: no decoded-value cache.  Since ISSUE 3 the batched
               numbers measure the *serving fast path*: the device-resident
               index (``source.ResidentPool``, staged once untimed at
               build) plus pipelined dispatch — per-batch host decode /
               pow2 padding / H2D staging is gone, which is where ~70% of
               the uncached batch time went.  The sequential columns stay
               pool-less as the reference.  ``batched_b32_host_staged``
               keeps the old per-batch host-staging path as the A/B point.

Both regimes cover the pallas backend at b32, and the pipelined executor
(depth 2) is asserted byte-identical to the sequential engine on both
backends before it is timed (ISSUE 3 gate: uncached/batched_b32_qps ≥ 1.5×
the PR-2 baseline of 258.6).  Since ISSUE 7 every Pallas number carries an
explicit ``<key>_kernel_mode`` field ("compiled" | "interpret", from
``kernels.ops.kernel_mode()``): this container runs the kernels in
*interpret mode*, whose cost scales with the number of interpreted
kernel-grid invocations, so interpret timings measure the Pallas
interpreter, not the engine — ``compare`` refuses to ratio-gate a key
whose mode differs from the baseline, and ``--max-pallas-ratio`` is
advisory unless the run was compiled (DESIGN.md §2.12).  The jax-backend
columns are the load-bearing throughput gates.  The pallas backend itself
runs the fused decode+intersect megakernels (one launch per fold stack);
an interpret-mode occupancy guard (``batch.PALLAS_MIN_OCCUPANCY``)
demotes sparsely occupied fused chunks to the jax program, which is why
the interpret pallas columns track the jax ones at low occupancy.

A third section replays a *skewed-ratio* log (tiny first term, very long
second term) and reports decoded-ints/query with the posting-source skip
path off vs on (``execute_batch(skip=...)``): the ISSUE 2 gate is a ≥ 5×
drop while results stay byte-identical to the sequential engine on both
backends.  This section runs pool-less on purpose — it gates the
partial-decode machinery itself, which residency would mask.

A fourth section measures the sharded fan-out (``repro.index.shard``,
DESIGN.md §2.9) at shards ∈ {1, 2, 4} in a *device-compute-bound* regime
(mid-size seeds, several long lists → large candidate-block partial
decodes per row).  It runs in a subprocess under
``--xla_force_host_platform_device_count=4`` so four host-platform devices
exist on any machine while the parent — and every baseline above — stays
single-device.  The ISSUE 4 gate is >1.5× batched throughput at 4 shards
vs 1 in the full-size run (``sharded/speedup_s4`` in BENCH_engine.json);
the smoke variant reports the same keys but is too small to gate on —
scheduler-bound regimes measure the host, not the sharding.

A fifth section (``dispatch/``, ISSUE 5) A/Bs megagroup fusion on the
mixed-signature corpus: device dispatches per batch fused vs unfused
(gate: ≥ 4× reduction), the AOT warmup compile count, the steady-state
compile count after warmup (must be 0), and the fused/unfused throughput
delta with everything else held fixed.

A sixth section (``latency/``, ISSUE 6) measures *open-loop* serving: the
continuous-batching server (``repro.launch.server``) fed by Poisson and
bursty arrival processes at offered loads derived from the same run's
measured drain capacity (0.5× and 0.8×) — closed-loop q/s says nothing
about the p99 a user sees under arrival jitter.  Reported per load:
p50/p99/p999 end-to-end latency, p99 time-in-queue, the max queue-depth
bucket, and the shed count; the drain run doubles as the acceptance
check that a warmed steady-state server compiles nothing and returns
byte-identical results to the offline batched path.  ``--max-p99-ms``
gates ``latency/p99_ms`` (Poisson at half capacity — a same-run-derived
load, so the gate tracks the engine's latency behavior, not the absolute
speed of the runner).

A seventh section (``mutation/``, ISSUE 9) serves the segmented mutable
index (DESIGN.md §2.14) after a burst of adds/seals/deletes: steady-state
q/s vs q/s *during a background merge* (``mutation/merge_ratio`` — the
serving cost of compaction), both gated byte-identical against a
rebuild-from-scratch build, with ``mutation/steady_compiles`` asserting
the post-swap generation compiles nothing.

Derived column reports queries/sec (and decoded ints/query where that is
the figure of merit).  CLI: ``--smoke`` runs the reduced sweep standalone
(CI smoke gate), ``--json PATH`` additionally records a machine-readable
baseline (BENCH_engine.json / BENCH_engine_smoke.json), ``--compare PATH``
prints per-key deltas vs a committed baseline, ``--max-regress PCT``
turns the comparison into a CI gate: it fails if the batched-over-
sequential *speedup* at b32 (cached regime) regressed by more than PCT —
the ratio of two same-run numbers, so the gate tracks the engine, not the
absolute speed of the runner it happens to execute on.  ``--max-dispatches
N`` gates the fused dispatches-per-batch count the same way (a regression
back to per-signature dispatch fails fast), and ``--profile`` prints the
per-batch schedule / assemble / dispatch / device breakdown of the fused
resident pipeline.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from benchmarks.common import emit

RESULTS: dict[str, float | str] = {}

# the --max-regress gate compares this speedup ratio (see module docstring)
GATE_NUM = "cached/batched_b32_qps"
GATE_DEN = "cached/sequential_qps"

# the --max-pallas-ratio gate compares this same-run jax/pallas throughput
# ratio on the fused packed (skewed) family; it hard-gates only when the
# kernels ran compiled — interpret numbers are advisory (see _gate_pallas)
PALLAS_GATE = "skewed/pallas_vs_jax_ratio"
PALLAS_GATE_MODE = "skewed/batched_pallas_kernel_mode"


def _kernel_mode() -> str:
    """Execution mode of every Pallas number this run records.  Stored as
    an explicit ``<key>_kernel_mode`` field next to each Pallas entry —
    interpret-mode timings measure the Pallas interpreter, not the
    hardware, and must never be ratio-gated against compiled ones
    (``compare`` refuses; DESIGN.md §2.12)."""
    from repro.kernels import ops as kernel_ops
    return kernel_ops.kernel_mode()


def _qps(fn, n_queries: int, reps: int = 3) -> float:
    fn()                                    # warm / compile / fill cache
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return n_queries / best


def _throughput(quick: bool) -> None:
    import numpy as np
    from repro.index import builder, corpus as corpus_lib, engine, source
    from repro.index import batch as batch_lib
    from repro.index import pipeline as pipe_lib

    table = {k: corpus_lib.TABLE2_CLUEWEB[k] for k in (2, 3, 4, 5)}
    n_docs = 1 << 14 if quick else 1 << 16
    n_queries = 32 if quick else 128
    corpus = corpus_lib.synthesize(n_docs=n_docs, n_queries=n_queries,
                                   seed=11, table=table)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="fastpfor-d1", B=16, n_parts=2)
    queries = corpus.queries
    batch_sizes = [8, 32] if quick else [8, 32, 128]
    seq_res = [engine.query(idx, q) for q in queries]   # identity oracle

    def assert_identical(out):
        for a, b in zip(out, seq_res):
            assert a.count == b.count and np.array_equal(a.docs, b.docs)

    for regime in ["cached", "uncached"]:
        def make_cache():
            return (engine.DecodeCache(capacity_ints=1 << 26)
                    if regime == "cached" else None)

        seq_cache = make_cache()
        seq_qps = _qps(lambda: [engine.query(idx, q, cache=seq_cache)
                                for q in queries], len(queries))
        emit(f"engine/{regime}/sequential", 1.0 / seq_qps,
             f"{seq_qps:.1f} q/s")
        RESULTS[f"{regime}/sequential_qps"] = round(seq_qps, 1)
        # device-resident index: staged once (untimed — build-time work);
        # one sticky FusionPlan per regime so fused signatures converge
        # across batch sizes and reps (the serving-session contract)
        pool = source.ResidentPool()
        pool.warm(idx)
        plan = batch_lib.FusionPlan()
        for bs in batch_sizes:
            bat_cache = make_cache()

            def run_batched(bs=bs, cache=bat_cache, backend="jax"):
                out = []
                for lo in range(0, len(queries), bs):
                    out.extend(batch_lib.execute_batch(
                        idx, queries[lo: lo + bs], cache=cache, pool=pool,
                        backend=backend, plan=plan))
                return out

            assert_identical(run_batched())
            qps = _qps(run_batched, len(queries))
            emit(f"engine/{regime}/batched_b{bs}", 1.0 / qps,
                 f"{qps:.1f} q/s {qps / seq_qps:.2f}x")
            RESULTS[f"{regime}/batched_b{bs}_qps"] = round(qps, 1)

            def run_pipelined(bs=bs, cache=bat_cache):
                return pipe_lib.execute_pipelined(
                    idx, queries, batch_size=bs, depth=2, cache=cache,
                    pool=pool, plan=plan)

            assert_identical(run_pipelined())
            qps = _qps(run_pipelined, len(queries))
            emit(f"engine/{regime}/pipelined_b{bs}", 1.0 / qps,
                 f"{qps:.1f} q/s {qps / seq_qps:.2f}x")
            RESULTS[f"{regime}/pipelined_b{bs}_qps"] = round(qps, 1)

        # pallas backend coverage in BOTH regimes (pre-ISSUE 3 only the
        # cached regime ever touched the kernels in this table); plain
        # execute_batch so the delta vs batched_b32 isolates the backend
        pal_cache = make_cache()

        def run_pallas():
            out = []
            for lo in range(0, len(queries), 32):
                out.extend(batch_lib.execute_batch(
                    idx, queries[lo: lo + 32], cache=pal_cache, pool=pool,
                    backend="pallas", plan=plan))
            return out

        assert_identical(run_pallas())
        qps = _qps(run_pallas, len(queries))
        emit(f"engine/{regime}/batched_b32_pallas", 1.0 / qps,
             f"{qps:.1f} q/s [{_kernel_mode()}]")
        RESULTS[f"{regime}/batched_b32_pallas_qps"] = round(qps, 1)
        RESULTS[f"{regime}/batched_b32_pallas_kernel_mode"] = _kernel_mode()
        # ISSUE 3 gate: pipelined output byte-identical on the pallas
        # backend too (timed pipelined coverage is the jax column above)
        assert_identical(pipe_lib.execute_pipelined(
            idx, queries, batch_size=32, depth=2, backend="pallas",
            pool=pool, plan=plan))

    # A/B reference: the pre-ISSUE-3 uncached path (per-batch host decode,
    # pow2 padding and H2D staging; no resident pool)
    def run_host_staged():
        out = []
        for lo in range(0, len(queries), 32):
            out.extend(batch_lib.execute_batch(idx, queries[lo: lo + 32]))
        return out

    qps = _qps(run_host_staged, len(queries))
    emit("engine/uncached/batched_b32_host_staged", 1.0 / qps,
         f"{qps:.1f} q/s")
    RESULTS["uncached/batched_b32_host_staged_qps"] = round(qps, 1)


def _dispatch(quick: bool) -> None:
    """Megagroup fusion A/B (ISSUE 5 gates): dispatches per mixed batch
    fused vs unfused (gate: ≥ 4× reduction), AOT warmup compile count, and
    the fused/unfused throughput delta on the device-resident path.
    Identical batches, identical pool — only ``fuse`` flips."""
    import numpy as np
    from repro.index import builder, corpus as corpus_lib, engine, source
    from repro.index import batch as batch_lib

    table = {k: corpus_lib.TABLE2_CLUEWEB[k] for k in (2, 3, 4, 5)}
    n_docs = 1 << 14 if quick else 1 << 16
    n_queries = 32 if quick else 128
    corpus = corpus_lib.synthesize(n_docs=n_docs, n_queries=n_queries,
                                   seed=11, table=table)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="fastpfor-d1", B=16, n_parts=2)
    queries = corpus.queries
    seq = [engine.query(idx, q) for q in queries]
    pool = source.ResidentPool()
    pool.warm(idx)
    plan = batch_lib.FusionPlan()
    wu = batch_lib.warmup(idx, queries, plan=plan, batch_size=32, pool=pool)
    RESULTS["dispatch/warmup_compiles"] = wu["n_compiles"]
    RESULTS["dispatch/warmup_signatures"] = wu["n_signatures"]
    n_batches = (len(queries) + 31) // 32
    for fuse in (False, True):
        label = "fused" if fuse else "unfused"

        def run_once(fuse=fuse, stats=None):
            out = []
            for lo in range(0, len(queries), 32):
                out.extend(batch_lib.execute_batch(
                    idx, queries[lo: lo + 32], pool=pool, fuse=fuse,
                    plan=plan if fuse else None, stats=stats))
            return out

        stats: dict = {}
        out = run_once(stats=stats)
        for a, b in zip(out, seq):              # byte-identical gate
            assert a.count == b.count and np.array_equal(a.docs, b.docs)
        per_batch = stats["n_dispatches"] / n_batches
        RESULTS[f"dispatch/per_batch_{label}"] = round(per_batch, 2)
        qps = _qps(run_once, len(queries))
        RESULTS[f"dispatch/batched_b32_{label}_qps"] = round(qps, 1)
        emit(f"engine/dispatch/batched_b32_{label}", 1.0 / qps,
             f"{qps:.1f} q/s {per_batch:.1f} dispatches/batch")
    RESULTS["dispatch/reduction"] = round(
        RESULTS["dispatch/per_batch_unfused"]
        / max(RESULTS["dispatch/per_batch_fused"], 1e-9), 1)
    # after warmup + the loops above, steady-state fused serving must not
    # compile anything new
    run_stats: dict = {}
    for lo in range(0, len(queries), 32):
        batch_lib.execute_batch(idx, queries[lo: lo + 32], pool=pool,
                                plan=plan, stats=run_stats)
    RESULTS["dispatch/steady_compiles"] = run_stats.get("n_compiles", 0)
    emit("engine/dispatch/reduction", 0.0,
         f"{RESULTS['dispatch/reduction']:.1f}x fewer dispatches, "
         f"{RESULTS['dispatch/steady_compiles']} steady-state compiles")


def _profile(quick: bool) -> None:
    """--profile: per-batch schedule / assemble / dispatch / device-block
    breakdown of the resident fused pipeline, so the next PR can see where
    the next bottleneck sits without re-instrumenting."""
    from repro.index import builder, corpus as corpus_lib, source
    from repro.index import batch as batch_lib
    from repro.index import pipeline as pipe_lib

    table = {k: corpus_lib.TABLE2_CLUEWEB[k] for k in (2, 3, 4, 5)}
    n_docs = 1 << 14 if quick else 1 << 16
    n_queries = 32 if quick else 128
    corpus = corpus_lib.synthesize(n_docs=n_docs, n_queries=n_queries,
                                   seed=11, table=table)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="fastpfor-d1", B=16, n_parts=2)
    queries = corpus.queries
    pool = source.ResidentPool()
    pool.warm(idx)
    plan = batch_lib.FusionPlan()
    batch_lib.warmup(idx, queries, plan=plan, batch_size=32, pool=pool)
    for fuse in (True, False):
        tm = pipe_lib.StageTimings()
        pipe_lib.execute_pipelined(idx, queries, batch_size=32, depth=2,
                                   pool=pool, fuse=fuse,
                                   plan=plan if fuse else None, timings=tm)
        per = 1e3 / max(tm.batches, 1)
        tot = max(tm.stage + tm.assemble + tm.dispatch + tm.block, 1e-9)
        print(f"# profile {'fused' if fuse else 'unfused'} "
              f"(per batch of 32): "
              f"schedule {tm.stage * per:.2f}ms ({tm.stage / tot:.0%}), "
              f"assemble {tm.assemble * per:.2f}ms "
              f"({tm.assemble / tot:.0%}), "
              f"dispatch {tm.dispatch * per:.2f}ms "
              f"({tm.dispatch / tot:.0%}), "
              f"device/block {tm.block * per:.2f}ms ({tm.block / tot:.0%})")


def _skewed(quick: bool) -> None:
    """Decoded-ints/query with the skip path off vs on (ISSUE 2 gate)."""
    from repro.index import builder, corpus as corpus_lib, engine
    from repro.index import batch as batch_lib
    import numpy as np

    # tiny first term, very long second term: the regime where galloping
    # over the block-max index beats decoding (paper §6.5); 1024-int blocks
    # (bp8) give the skip index enough granularity to prune
    n_docs = 1 << 17 if quick else 1 << 18
    n_queries = 8 if quick else 16
    table = {2: (100.0, [0.8 * (1 << 18) / n_docs,
                         38000.0 * (1 << 18) / n_docs])}
    corpus = corpus_lib.synthesize(n_docs=n_docs, n_queries=n_queries,
                                   seed=7, table=table)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="bp8-d1", B=0, n_parts=1)
    queries = corpus.queries
    seq = [engine.query(idx, q) for q in queries]

    decoded = {}
    for skip in (False, True):
        label = "skip_on" if skip else "skip_off"
        stats: dict = {}
        out = batch_lib.execute_batch(idx, queries, skip=skip, stats=stats)
        for a, b in zip(out, seq):              # byte-identical gate
            assert a.count == b.count and np.array_equal(a.docs, b.docs)
        dt = _qps(lambda s=skip: batch_lib.execute_batch(
            idx, queries, skip=s), len(queries))
        per_q = stats["decoded_ints"] / len(queries)
        decoded[label] = per_q
        emit(f"engine/skewed/batched_{label}", 1.0 / dt,
             f"{dt:.1f} q/s {per_q:.0f} decoded ints/q")
        RESULTS[f"skewed/batched_{label}_qps"] = round(dt, 1)
        RESULTS[f"skewed/batched_{label}_decoded_ints_per_query"] = \
            round(per_q)
    ratio = decoded["skip_off"] / max(decoded["skip_on"], 1)
    emit("engine/skewed/partial_decode_ratio", 0.0, f"{ratio:.1f}x fewer")
    RESULTS["skewed/partial_decode_ratio"] = round(ratio, 1)

    # pallas backend: identical results, decoded inside the fused
    # decode+intersect megakernel (DESIGN.md §2.12)
    outp = batch_lib.execute_batch(idx, queries, backend="pallas")
    for a, b in zip(outp, seq):
        assert a.count == b.count and np.array_equal(a.docs, b.docs)
    dt = _qps(lambda: batch_lib.execute_batch(
        idx, queries, backend="pallas"), len(queries))
    emit("engine/skewed/batched_pallas", 1.0 / dt,
         f"{dt:.1f} q/s [{_kernel_mode()}]")
    RESULTS["skewed/batched_pallas_qps"] = round(dt, 1)
    RESULTS["skewed/batched_pallas_kernel_mode"] = _kernel_mode()
    # same-run jax-over-pallas throughput ratio on this fused packed
    # family — the --max-pallas-ratio gate key (1.0 = parity, lower =
    # pallas wins); hard-gated only in compiled mode
    RESULTS["skewed/pallas_vs_jax_ratio"] = round(
        RESULTS["skewed/batched_skip_on_qps"] / max(dt, 1e-9), 2)


def _sharded_worker(quick: bool) -> None:
    """Child-process body for the sharded section: measures batched
    uncached throughput at shards ∈ {1, 2, 4} and prints one JSON line.
    Runs under --xla_force_host_platform_device_count=4 (set by the
    parent) so 4 host-platform devices exist regardless of machine."""
    import time
    import jax
    from repro.index import builder, corpus as corpus_lib, engine, shard

    # device-compute-heavy regime (mid-size seed, two long lists → large
    # candidate-block partial decodes): the regime where the fan-out's
    # SPMD row-split pays; host-bound regimes measure the scheduler, not
    # the sharding
    n_docs = 1 << 17 if quick else 1 << 18
    n_queries = 32 if quick else 96
    scale = n_docs / (1 << 18)
    table = {4: (100.0, [4000.0 * scale, 60000.0 * scale,
                         90000.0 * scale, 130000.0 * scale])}
    corpus = corpus_lib.synthesize(n_docs=n_docs, n_queries=n_queries,
                                   seed=11, table=table)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="bp-d1", B=0, n_parts=4)
    queries = corpus.queries
    seq = [engine.query(idx, q) for q in queries]
    results = {"sharded/devices": len(jax.devices())}
    for n_shards in (1, 2, 4):
        sharded = shard.shard_index(idx, n_shards)

        def run_once():
            return shard.execute_sharded(sharded, queries, batch_size=32,
                                         depth=2)

        out = run_once()
        for a, b in zip(out, seq):              # byte-identical gate
            assert a.count == b.count
            import numpy as np
            assert np.array_equal(a.docs, b.docs)
        # warm to the signature fixed point before timing: arena growth
        # and residency staging settle over the first passes
        from repro.index import batch as batch_lib
        batch_lib.warm_to_fixed_point(
            lambda s: shard.execute_sharded(sharded, queries, batch_size=32,
                                            depth=2, stats=s))
        qps = _qps(run_once, len(queries), reps=5)
        results[f"sharded/batched_b32_s{n_shards}_qps"] = round(qps, 1)
    results["sharded/speedup_s4"] = round(
        results["sharded/batched_b32_s4_qps"]
        / results["sharded/batched_b32_s1_qps"], 2)
    print("SHARDED_JSON " + json.dumps(results))


def _sharded(quick: bool) -> None:
    """Sharded fan-out scaling (ISSUE 4 gate: >1.5× batched throughput at
    4 shards vs 1, uncached, on host-platform devices).  Runs in a
    subprocess with forced host device count so the parent process's
    single-device state — and every baseline above — is undisturbed."""
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    cmd = [sys.executable, os.path.abspath(__file__), "--sharded-worker"]
    if quick:
        cmd.append("--smoke")
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=1800)
    if out.returncode != 0:
        print(f"# sharded section FAILED: {out.stderr[-2000:]}")
        raise SystemExit(2)
    line = [ln for ln in out.stdout.splitlines()
            if ln.startswith("SHARDED_JSON ")][-1]
    results = json.loads(line[len("SHARDED_JSON "):])
    RESULTS.update(results)
    for n_shards in (1, 2, 4):
        qps = results[f"sharded/batched_b32_s{n_shards}_qps"]
        emit(f"engine/sharded/batched_b32_s{n_shards}", 1.0 / qps,
             f"{qps:.1f} q/s "
             f"{qps / results['sharded/batched_b32_s1_qps']:.2f}x")
    emit("engine/sharded/speedup_s4", 0.0,
         f"{results['sharded/speedup_s4']:.2f}x on "
         f"{results['sharded/devices']} host devices")


def _latency(quick: bool) -> None:
    """Open-loop serving latency (ISSUE 6): the continuous-batching server
    under Poisson / bursty arrivals at offered loads derived from this
    run's measured drain capacity.  The drain run is also the acceptance
    check: warmed steady state compiles nothing and serves byte-identical
    results."""
    import numpy as np
    from repro.index import builder, corpus as corpus_lib, engine, source
    from repro.index import batch as batch_lib
    from repro.launch import server as server_lib

    table = {k: corpus_lib.TABLE2_CLUEWEB[k] for k in (2, 3, 4, 5)}
    n_docs = 1 << 14 if quick else 1 << 16
    n_queries = 64 if quick else 256
    corpus = corpus_lib.synthesize(n_docs=n_docs, n_queries=n_queries,
                                   seed=11, table=table)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="fastpfor-d1", B=16, n_parts=2)
    queries = corpus.queries
    seq = [engine.query(idx, q) for q in queries]
    pool = source.ResidentPool()
    pool.warm(idx)
    plan = batch_lib.FusionPlan()

    # drain run: measures capacity AND gates the steady-state claims
    results, srv = server_lib.serve_open_loop(
        idx, queries, qps=0.0, warmup=True, pool=pool, plan=plan,
        max_batch=32, max_queue=max(n_queries, 64))
    for a, b in zip(results, seq):              # byte-identical gate
        assert a.count == b.count and np.array_equal(a.docs, b.docs)
    s = srv.metrics.summary()
    drain_qps = s["qps"]
    RESULTS["latency/drain_qps"] = round(drain_qps, 1)
    RESULTS["latency/steady_compiles"] = srv.stats.get("n_compiles", 0)
    RESULTS["latency/warmup_converged"] = int(srv.warm_report["converged"])
    emit("engine/latency/drain", 1.0 / max(drain_qps, 1e-9),
         f"{drain_qps:.1f} q/s {RESULTS['latency/steady_compiles']} "
         f"steady-state compiles")

    for pattern in ("poisson", "bursty"):
        for frac, tag in ((0.5, "50"), (0.8, "80")):
            offered = max(drain_qps * frac, 1.0)
            out, srv = server_lib.serve_open_loop(
                idx, queries, qps=offered, pattern=pattern, seed=17,
                pool=pool, plan=plan, max_batch=32, max_wait_ms=2.0,
                max_queue=max(n_queries, 64))
            s = srv.metrics.summary()
            key = f"latency/{pattern}{tag}"
            RESULTS[f"{key}_p50_ms"] = round(s["p50_ms"], 2)
            RESULTS[f"{key}_p99_ms"] = round(s["p99_ms"], 2)
            RESULTS[f"{key}_p999_ms"] = round(s["p999_ms"], 2)
            RESULTS[f"{key}_wait_p99_ms"] = round(s["wait_p99_ms"], 2)
            RESULTS[f"{key}_shed"] = s["n_shed"]
            RESULTS[f"{key}_queue_depth_max"] = max(
                (int(k) for k, v in s["queue_depth_hist"].items() if v),
                default=0)
            emit(f"engine/{key}", s["p99_ms"] * 1e-3,
                 f"{s['qps']:.1f} q/s @{offered:.0f} offered, p50 "
                 f"{s['p50_ms']:.1f} / p99 {s['p99_ms']:.1f} / p99.9 "
                 f"{s['p999_ms']:.1f} ms, {s['n_shed']} shed")
    # the --max-p99-ms gate key: Poisson at half capacity (see docstring)
    RESULTS["latency/p99_ms"] = RESULTS["latency/poisson50_p99_ms"]


def _mutation(quick: bool) -> None:
    """Live-mutation serving (ISSUE 9): the segmented mutable index
    (DESIGN.md §2.14) after a burst of adds/seals/deletes, measured in a
    steady state ("frozen" — no merge running) and then *during* a
    background merge, same serving path and batch size — the ratio is the
    serving cost of compaction, which the generation design keeps near
    1.0 (merges stage off-lock and swap one reference).  Both windows are
    gated byte-identical against a rebuild-from-scratch index, and the
    post-swap batches must compile nothing (the candidate generation
    pre-warms through the shared sticky plan)."""
    import numpy as np
    from repro.index import builder, corpus as corpus_lib, engine, segments
    from repro.index import batch as batch_lib

    table = {k: corpus_lib.TABLE2_CLUEWEB[k] for k in (2, 3, 4, 5)}
    n_docs = 1 << 14 if quick else 1 << 16
    n_queries = 32 if quick else 128
    corpus = corpus_lib.synthesize(n_docs=n_docs, n_queries=n_queries,
                                   seed=11, table=table)
    mi = segments.MutableIndex.from_postings(
        corpus.postings, corpus.n_docs, codec_name="fastpfor-d1", B=16,
        n_parts=2)
    queries = corpus.queries
    rng = np.random.default_rng(5)
    term_pool = sorted({t for q in queries for t in q})
    n_mut = 200 if quick else 1000
    for i in range(n_mut):
        k = int(rng.integers(1, 4))
        mi.add(sorted(rng.choice(term_pool, size=k,
                                 replace=False).tolist()))
        if i == n_mut // 2:
            mi.seal()
    for d in rng.choice(mi.next_doc_id, size=n_mut // 10, replace=False):
        mi.delete(int(d))

    def run_once(stats=None):
        out = []
        for lo in range(0, len(queries), 32):
            out.extend(mi.execute_batch(queries[lo: lo + 32],
                                        stats=stats))
        return out

    def assert_identical(out):
        idx = builder.build(mi.live_postings(), max(mi.next_doc_id, 1),
                            codec_name="fastpfor-d1", B=16, n_parts=2)
        for q, a in zip(queries, out):
            b = engine.query(idx, q)
            assert a.count == b.count and np.array_equal(a.docs, b.docs)

    batch_lib.warm_to_fixed_point(lambda s: run_once(stats=s))
    assert_identical(run_once())
    qps_frozen = _qps(run_once, len(queries))
    emit("engine/mutation/frozen", 1.0 / qps_frozen,
         f"{qps_frozen:.1f} q/s ({mi.counters()['n_segments']} segments, "
         f"{mi.counters()['tombstones']} tombstones)")
    RESULTS["mutation/frozen_qps"] = round(qps_frozen, 1)

    # the timed window runs WHILE the background merge decodes, rebuilds
    # and stages the candidate generation
    merge_thread = mi.merge_async(warm_queries=queries)
    loops, t0 = 0, time.perf_counter()
    while loops == 0 or (merge_thread.is_alive() and loops < 64):
        out = run_once()
        loops += 1
    dt = time.perf_counter() - t0
    merge_thread.join()
    assert mi.counters()["n_merges"] == 1
    qps_merge = loops * len(queries) / dt
    ratio = qps_merge / max(qps_frozen, 1e-9)
    emit("engine/mutation/during_merge", 1.0 / qps_merge,
         f"{qps_merge:.1f} q/s {ratio:.2f}x of frozen over {loops} loops")
    RESULTS["mutation/during_merge_qps"] = round(qps_merge, 1)
    RESULTS["mutation/merge_ratio"] = round(ratio, 2)
    RESULTS["mutation/merge_loops"] = loops

    # post-swap: byte-identical to a fresh rebuild, zero compiles
    stats: dict = {}
    assert_identical(run_once(stats=stats))
    RESULTS["mutation/steady_compiles"] = stats.get("n_compiles", 0)
    emit("engine/mutation/post_merge", 0.0,
         f"generation {mi.generation}, "
         f"{RESULTS['mutation/steady_compiles']} post-swap compiles")


def _compression(quick: bool) -> None:
    """Storage autotuner A/B (ISSUE 8): the ``codec_name="auto"`` build vs
    the all-bitpack reference (``bp-d1`` with the varint tail rule off) on
    a Table-2-shaped corpus, whose skewed query-log list lengths leave most
    lists short.  Reports bytes/int and per-codec list counts for both
    builds, asserts the autotuned index byte-identical to the reference on
    both backends, and measures the short-list (< 1024 ints) decode wall
    clock per build — the dispatch-cost term the autotuner's CostModel
    scores on (DESIGN.md §2.13).  ``compression/auto_bytes_per_int`` is
    the ``--max-bytes-per-int`` gate key."""
    import time

    import jax
    import numpy as np
    from repro.core import codecs as codec_lib
    from repro.index import builder, corpus as corpus_lib
    from repro.index import batch as batch_lib

    n_docs = 1 << 15 if quick else 1 << 16
    n_queries = 24 if quick else 40
    corpus = corpus_lib.synthesize(n_docs=n_docs, n_queries=n_queries,
                                   seed=3)
    builds = {
        "auto": builder.build(corpus.postings, corpus.n_docs,
                              codec_name="auto", B=16, n_parts=2),
        "bp": builder.build(corpus.postings, corpus.n_docs,
                            codec_name="bp-d1", B=16, n_parts=2,
                            varint_tail_below=0),
    }
    queries = corpus.queries
    oracle = None
    for label, idx in builds.items():
        st = idx.stats()
        counts = " ".join(f"{k}:{v}" for k, v in
                          sorted(st["codec_counts"].items()))
        emit(f"engine/compression/{label}_bytes_per_int", 0.0,
             f"{st['bytes_per_int']:.2f} B/int [{counts}]")
        RESULTS[f"compression/{label}_bytes_per_int"] = round(
            st["bytes_per_int"], 3)
        for fam, cnt in sorted(st["codec_counts"].items()):
            RESULTS[f"compression/{label}_lists_{fam}"] = cnt
        for backend in ("jax", "pallas"):
            out = batch_lib.execute_batch(idx, queries, backend=backend)
            if oracle is None:
                oracle = out                      # the reference build's
            for a, b in zip(out, oracle):         # results, jax backend
                assert a.count == b.count and np.array_equal(a.docs, b.docs)
        dt = _qps(lambda idx=idx: batch_lib.execute_batch(idx, queries),
                  len(queries))
        emit(f"engine/compression/{label}_batched", 1.0 / dt,
             f"{dt:.1f} q/s")
        RESULTS[f"compression/{label}_qps"] = round(dt, 1)
        # short-list decode wall clock: every "list" payload under 1024
        # ints, decoded through the per-payload registry — the term the
        # autotuner's dispatch-cost model targets
        shorts = [tp.payload for part in idx.parts
                  for tp in part.terms.values()
                  if tp.kind == "list" and tp.n < 1024]
        def decode_all(shorts=shorts):
            for p in shorts:
                jax.block_until_ready(codec_lib.codec_for(p).decode(p))
        decode_all()                              # warm jit caches
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            decode_all()
            best = min(best, time.perf_counter() - t0)
        us = best * 1e6 / max(len(shorts), 1)
        emit(f"engine/compression/{label}_short_decode", us * 1e-6,
             f"{us:.0f} us/list over {len(shorts)} short lists")
        RESULTS[f"compression/{label}_short_decode_us"] = round(us, 1)
    win = (RESULTS["compression/bp_short_decode_us"]
           / max(RESULTS["compression/auto_short_decode_us"], 1e-9))
    emit("engine/compression/short_decode_win", 0.0, f"{win:.1f}x")
    RESULTS["compression/short_decode_win"] = round(win, 2)


def _resilience(quick: bool) -> None:
    """Fault-injected serving + recovery wall clock (DESIGN.md §2.15).

    One open-loop Poisson window clean, one with injected transient
    faults on the first three launches: the faulted window must lose
    ZERO requests
    (every submission resolves ``done``) and answer byte-identically —
    the q/s and p99 deltas are the measured cost of the bounded-backoff
    retry path.  Then a WAL-journaled mutable index takes a mutation
    burst and is recovered from disk, timing the snapshot-load + WAL
    replay path that a post-crash restart pays."""
    import tempfile

    import numpy as np
    from repro.index import builder, corpus as corpus_lib, segments
    from repro.launch import faults as faults_lib
    from repro.launch import server as server_lib

    n_docs = 1 << 14 if quick else 1 << 16
    n_queries = 64 if quick else 256
    corpus = corpus_lib.synthesize(n_docs=n_docs, n_queries=n_queries,
                                   seed=17)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="fastpfor-d1", B=16, n_parts=2)

    def window(injector=None):
        results, srv = server_lib.serve_open_loop(
            idx, corpus.queries, qps=2000.0, pattern="poisson", seed=2,
            warmup=True, max_batch=8, max_queue=4096, injector=injector,
            max_retries=6, retry_backoff_ms=0.5)
        assert srv.outcomes() == ["done"] * n_queries   # zero lost requests
        return results, srv.metrics.summary()

    clean, s_clean = window()
    # counted rule, not probabilistic: the smoke window only flushes a
    # handful of batches, so a 1%-per-launch rule would usually fire
    # zero times and the "faulted" figures would measure nothing
    inj = faults_lib.FaultInjector("transient@launch:3", seed=12)
    faulted, s_fault = window(injector=inj)
    for a, b in zip(clean, faulted):                    # byte-identical
        assert a.count == b.count and np.array_equal(a.docs, b.docs)
    RESULTS["resilience/clean_qps"] = round(s_clean["qps"], 1)
    RESULTS["resilience/clean_p99_ms"] = round(s_clean["p99_ms"], 2)
    RESULTS["resilience/faulted_qps"] = round(s_fault["qps"], 1)
    RESULTS["resilience/faulted_p99_ms"] = round(s_fault["p99_ms"], 2)
    RESULTS["resilience/faults"] = s_fault["n_faults"]
    RESULTS["resilience/retries"] = s_fault["n_retries"]
    emit("engine/resilience/clean", 1.0 / max(s_clean["qps"], 1e-9),
         f"{s_clean['qps']:.1f} q/s p99 {s_clean['p99_ms']:.2f} ms")
    emit("engine/resilience/faulted", 1.0 / max(s_fault["qps"], 1e-9),
         f"{s_fault['qps']:.1f} q/s p99 {s_fault['p99_ms']:.2f} ms "
         f"({s_fault['n_faults']} faults, {s_fault['n_retries']} retries, "
         f"0 lost)")

    # recovery wall clock: snapshot load + WAL-tail replay after a burst
    rng = np.random.default_rng(9)
    term_pool = sorted({t for q in corpus.queries for t in q})
    n_mut = 200 if quick else 1000
    with tempfile.TemporaryDirectory() as wal_dir:
        from repro.index import durability
        mi = segments.MutableIndex.from_postings(
            corpus.postings, corpus.n_docs, codec_name="fastpfor-d1",
            B=16, n_parts=2, wal=durability.DurableLog(wal_dir))
        for i in range(n_mut):
            k = int(rng.integers(1, 4))
            mi.add(sorted(rng.choice(term_pool, size=k,
                                     replace=False).tolist()))
            if i == n_mut // 2:
                mi.seal()
        for d in rng.choice(mi.next_doc_id, size=n_mut // 10,
                            replace=False):
            mi.delete(int(d))
        t0 = time.perf_counter()
        rec = segments.MutableIndex.recover(wal_dir)
        dt = time.perf_counter() - t0
        live = mi.execute_batch(corpus.queries)
        back = rec.execute_batch(corpus.queries)
        for a, b in zip(live, back):                    # byte-identical
            assert a.count == b.count and np.array_equal(a.docs, b.docs)
        RESULTS["resilience/recovery_s"] = round(dt, 3)
        RESULTS["resilience/recovery_replayed"] = rec._wal_replayed
        emit("engine/resilience/recovery", dt,
             f"{dt * 1e3:.0f} ms to recover ({rec._wal_replayed} WAL "
             f"records replayed, {rec.counters()['n_segments']} segments)")


def run(quick: bool = False) -> None:
    _throughput(quick)
    _dispatch(quick)
    _skewed(quick)
    _compression(quick)
    _sharded(quick)
    _latency(quick)
    _mutation(quick)
    _resilience(quick)


def _mode_mismatch(key: str, bres: dict) -> bool:
    """True when ``key`` is a Pallas entry whose kernel_mode differs between
    baseline and this run — such pairs must never be ratio-gated (an
    interpret number measures the interpreter, not the engine)."""
    mk = key + "_kernel_mode"
    if mk not in bres and mk not in RESULTS:
        return False
    return bres.get(mk) != RESULTS.get(mk)


def compare(baseline_path: str, max_regress: float | None) -> int:
    """Print per-key deltas vs a committed baseline; with ``max_regress``
    also gate on the b32 batched-over-sequential speedup (see module
    docstring for why the gate is a same-run ratio).  Pallas keys carry a
    ``_kernel_mode`` sibling: when it differs between baseline and run the
    delta is printed as NOT COMPARABLE and any gate over such a key is
    refused rather than evaluated across modes."""
    with open(baseline_path) as fh:
        base = json.load(fh)
    bres = base.get("results", {})
    print(f"# compare vs {baseline_path} (baseline quick={base.get('quick')})")
    for key in sorted(set(bres) | set(RESULTS)):
        old, new = bres.get(key), RESULTS.get(key)
        if old is None:
            print(f"#   {key}: (new key) {new}")
        elif new is None:
            print(f"#   {key}: (missing in this run) baseline {old}")
        elif isinstance(old, str) or isinstance(new, str):
            tag = "" if old == new else "  (MODE CHANGED)"
            print(f"#   {key}: {old} -> {new}{tag}")
        elif _mode_mismatch(key, bres):
            print(f"#   {key}: {old} -> {new} "
                  f"(kernel-mode changed: NOT COMPARABLE)")
        else:
            pct = (new - old) / old * 100 if old else float("inf")
            print(f"#   {key}: {old} -> {new} ({pct:+.1f}%)")
    if max_regress is None:
        return 0
    if _mode_mismatch(GATE_NUM, bres) or _mode_mismatch(GATE_DEN, bres):
        print(f"# GATE REFUSED: {GATE_NUM}/{GATE_DEN} kernel mode differs "
              f"from the baseline — interpret vs compiled Pallas numbers "
              f"cannot be ratio-gated; regenerate the baseline in the "
              f"current mode")
        return 2
    try:
        new_ratio = RESULTS[GATE_NUM] / RESULTS[GATE_DEN]
        old_ratio = bres[GATE_NUM] / bres[GATE_DEN]
    except (KeyError, ZeroDivisionError) as exc:
        print(f"# GATE ERROR: missing gate keys ({exc})")
        return 2
    regress = (1.0 - new_ratio / old_ratio) * 100
    print(f"# gate {GATE_NUM}/{GATE_DEN}: baseline {old_ratio:.2f}x, "
          f"now {new_ratio:.2f}x "
          f"({regress:+.1f}% regression; fails above {max_regress:.0f}%)")
    if regress > max_regress:
        print("# GATE FAILED")
        return 2
    print("# gate passed")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep (CI smoke gate)")
    ap.add_argument("--json", type=str, default=None,
                    help="also write the measured baseline to this path")
    ap.add_argument("--compare", type=str, default=None, metavar="PATH",
                    help="print per-key deltas vs a committed baseline JSON")
    ap.add_argument("--max-regress", type=float, default=None, metavar="PCT",
                    help="with --compare: fail (exit 2) if the b32 batched "
                         "speedup regressed more than PCT percent")
    ap.add_argument("--max-dispatches", type=float, default=None,
                    metavar="N",
                    help="fail (exit 2) if the fused engine issues more "
                         "than N device dispatches per mixed batch "
                         "(dispatch/per_batch_fused) — guards against a "
                         "regression back to per-signature dispatch")
    ap.add_argument("--max-pallas-ratio", type=float, default=None,
                    metavar="R",
                    help="fail (exit 2) if the jax backend is more than R "
                         "times faster than the pallas backend on the "
                         "fused packed family (skewed/pallas_vs_jax_ratio, "
                         "a same-run ratio) — ENFORCED only when the "
                         "kernels ran compiled; in interpret mode the "
                         "check is advisory (printed, never failing), "
                         "because interpret timings measure the Pallas "
                         "interpreter, not the engine")
    ap.add_argument("--max-bytes-per-int", type=float, default=None,
                    metavar="B",
                    help="fail (exit 2) if the autotuned build stores more "
                         "than B bytes per posting int "
                         "(compression/auto_bytes_per_int) — guards the "
                         "storage autotuner's compression win")
    ap.add_argument("--max-p99-ms", type=float, default=None, metavar="MS",
                    help="fail (exit 2) if open-loop p99 latency at half "
                         "the measured drain capacity (latency/p99_ms) "
                         "exceeds MS milliseconds — the JSON artifact is "
                         "still written on failure")
    ap.add_argument("--profile", action="store_true",
                    help="print the per-batch schedule/assemble/dispatch/"
                         "device breakdown of the fused resident pipeline "
                         "and exit")
    ap.add_argument("--sharded-worker", action="store_true",
                    help=argparse.SUPPRESS)    # child of the sharded section
    args = ap.parse_args()
    if args.sharded_worker:
        _sharded_worker(args.smoke)
        return
    if args.profile:
        _profile(args.smoke)
        return
    print("name,us_per_call,derived")
    run(quick=args.smoke)
    # evaluate the dispatch gate but keep going: the JSON artifact and the
    # --compare report must land even on a failure — they are exactly the
    # data needed to debug it
    rc = 0
    if args.max_dispatches is not None:
        per_batch = RESULTS.get("dispatch/per_batch_fused")
        if per_batch is None or per_batch > args.max_dispatches:
            print(f"# DISPATCH GATE FAILED: {per_batch} fused dispatches "
                  f"per batch (ceiling {args.max_dispatches})")
            rc = 2
        else:
            print(f"# dispatch gate passed: {per_batch} per batch "
                  f"(ceiling {args.max_dispatches})")
    if args.max_pallas_ratio is not None:
        ratio = RESULTS.get(PALLAS_GATE)
        kmode = RESULTS.get(PALLAS_GATE_MODE, "interpret")
        if kmode != "compiled":
            print(f"# pallas ratio gate ADVISORY (kernel_mode={kmode}): "
                  f"jax/pallas = {ratio}x, target <= "
                  f"{args.max_pallas_ratio}x — interpret-mode numbers are "
                  f"never hard-gated; the gate enforces once the kernels "
                  f"run compiled")
        elif ratio is None or ratio > args.max_pallas_ratio:
            print(f"# PALLAS RATIO GATE FAILED: jax/pallas = {ratio}x on "
                  f"the fused packed family (ceiling "
                  f"{args.max_pallas_ratio}x, compiled mode)")
            rc = 2
        else:
            print(f"# pallas ratio gate passed: jax/pallas = {ratio}x "
                  f"(ceiling {args.max_pallas_ratio}x, compiled mode)")
    if args.max_bytes_per_int is not None:
        bpi = RESULTS.get("compression/auto_bytes_per_int")
        ref = RESULTS.get("compression/bp_bytes_per_int")
        if bpi is None or bpi > args.max_bytes_per_int:
            print(f"# BYTES/INT GATE FAILED: autotuned build stores {bpi} "
                  f"B/int (ceiling {args.max_bytes_per_int}; all-bitpack "
                  f"reference {ref})")
            rc = 2
        else:
            print(f"# bytes/int gate passed: autotuned {bpi} B/int "
                  f"(ceiling {args.max_bytes_per_int}; all-bitpack "
                  f"reference {ref})")
    if args.max_p99_ms is not None:
        p99 = RESULTS.get("latency/p99_ms")
        if p99 is None or p99 > args.max_p99_ms:
            print(f"# P99 GATE FAILED: {p99} ms open-loop p99 at half "
                  f"capacity (ceiling {args.max_p99_ms} ms)")
            rc = 2
        else:
            print(f"# p99 gate passed: {p99} ms (ceiling "
                  f"{args.max_p99_ms} ms)")
    if args.json:
        payload = {
            "bench": "bench_engine",
            "quick": bool(args.smoke),
            "results": RESULTS,
        }
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"# wrote {args.json}")
    if args.compare:
        rc = max(rc, compare(args.compare, args.max_regress))
    if rc:
        sys.exit(rc)


if __name__ == "__main__":
    main()
