"""Paper Table 3: decoding speed + bits/int on ClusterData, dense
(2^16 ints in [0, 2^19)) and sparse (2^16 ints in [0, 2^30)), for every
codec, plus the delta entropy and a memcpy reference row.

``--json PATH`` additionally writes the machine-readable cost table the
build-time storage autotuner consumes (builder.CostModel; DESIGN.md
§2.13): per-codec ``decode_ns_per_int`` (mean of the dense/sparse
profiles), a measured ``gallop_ns_per_probe`` (vectorized searchsorted
over a 2^16-int list), and the ``kernel_mode`` the numbers were taken
under — interpret-mode Pallas timings are not comparable to compiled
ones, so the mode is part of the table's provenance.  Paste the fields
into ``configs/paper_index.DEFAULT_COST_TABLE`` to refresh the shipped
defaults, or pass the path straight to ``builder.build(cost_table=...)``.
"""

from __future__ import annotations

import argparse
import json

import numpy as np
import jax.numpy as jnp

from repro.core import codecs, intersect as its
from repro.data.clusterdata import clusterdata, delta_entropy
from repro.kernels import ops
from benchmarks.common import emit, timeit

# codec names the cost table keys on (builder.CostModel.decode_ns covers
# family fallbacks, so one -d1 entry per family is enough)
COST_CODECS = ("bp-d1", "bp8-d1", "fastpfor-d1", "streamvbyte-d1",
               "composite-d1", "varint")


def _measure_dispatch(rng, slopes: dict[str, float]) -> dict[str, float]:
    """Fixed per-decode overhead (ns) per codec: time a 128-int decode and
    subtract the linear term.  This is the term that decides short lists —
    a device decode pays its dispatch before the first int lands, a host
    (varint/composite-tail) decode does not."""
    n = 128
    x = np.sort(rng.choice(1 << 18, n, replace=False)).astype(np.int64)
    out = {}
    for name in COST_CODECS:
        if name == "composite-d1":
            continue                       # derived from bp8 + varint parts
        c = codecs.get_codec(name)
        enc = c.encode(x)
        t = timeit(lambda c=c, enc=enc: c.decode(enc))
        out[name] = max(t * 1e9 - n * slopes.get(name, 0.0), 0.0)
    return out


def _measure_gallop(rng) -> float:
    """ns per probe of the vectorized gallop (searchsorted) over a
    2^16-int sorted list — the skip path's unit cost."""
    n = 1 << 16
    f = jnp.asarray(np.sort(rng.choice(1 << 30, n, replace=False))
                    .astype(np.int32))
    r = jnp.asarray(np.sort(rng.choice(1 << 30, 4096, replace=False))
                    .astype(np.int32))
    t = timeit(lambda: its.intersect_gallop(r, f))
    return t * 1e9 / 4096


def run(quick: bool = False, json_path: str | None = None):
    rng = np.random.default_rng(1)
    n = 1 << 16
    names = (["bp-d1", "bp-dv", "fastpfor-d1", "varint"] if quick
             else codecs.ALL_CODECS)
    # decode ns/int per codec per profile, for the --json cost table
    ns_per_int: dict[str, dict[str, float]] = {}
    for label, bits in (("dense", 19), ("sparse", 30)):
        x = clusterdata(rng, n, bits)
        emit(f"decode/{label}/entropy", 0.0,
             f"{delta_entropy(x):.1f} bits/int delta entropy")
        xd = jnp.asarray(x.astype(np.int32))
        t = timeit(lambda: xd.copy())
        emit(f"decode/{label}/copy", t, f"{n / t / 1e9:.2f} Gints/s")
        cost_names = [c for c in COST_CODECS if c not in names]
        for name in names + cost_names:
            c = codecs.get_codec(name)
            enc = c.encode(x)
            if name == "varint":           # scalar host decode (paper's
                t = timeit(lambda: c.decode(enc), reps=1)   # scalar baseline)
            else:
                t = timeit(lambda: c.decode(enc))
            ns_per_int.setdefault(name, {})[label] = t * 1e9 / n
            if name in names:
                emit(f"decode/{label}/{name}", t,
                     f"{n / t / 1e9:.3f} Gints/s; {c.bits_per_int(enc):.1f} "
                     f"bits/int")
    if json_path is None:
        return
    gallop_ns = _measure_gallop(rng)
    emit("decode/gallop", gallop_ns * 1e-9, f"{gallop_ns:.1f} ns/probe")
    slopes = {name: sum(prof.values()) / len(prof)
              for name, prof in ns_per_int.items()}
    dispatch = _measure_dispatch(rng, slopes)
    for name, ns in sorted(dispatch.items()):
        emit(f"decode/dispatch/{name}", ns * 1e-9, f"{ns / 1e3:.0f} us/list")
    table = {
        "decode_ns_per_int": {
            name: round(sum(prof.values()) / len(prof), 3)
            for name, prof in ns_per_int.items()
        },
        "dispatch_ns_per_list": {
            name: round(ns, 1) for name, ns in dispatch.items()
        },
        "decode_ns_per_int_by_profile": {
            name: {k: round(v, 3) for k, v in prof.items()}
            for name, prof in ns_per_int.items()
        },
        "gallop_ns_per_probe": round(gallop_ns, 1),
        "space_ns_per_byte": 2.0,
        "kernel_mode": ops.kernel_mode(),
    }
    with open(json_path, "w") as fh:
        json.dump(table, fh, indent=2)
        fh.write("\n")
    print(f"cost table -> {json_path}", flush=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the autotuner cost table (CostModel JSON)")
    args = ap.parse_args()
    run(quick=args.quick, json_path=args.json)
