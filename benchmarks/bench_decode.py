"""Paper Table 3: decoding speed + bits/int on ClusterData, dense
(2^16 ints in [0, 2^19)) and sparse (2^16 ints in [0, 2^30)), for every
codec, plus the delta entropy and a memcpy reference row."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import codecs
from repro.data.clusterdata import clusterdata, delta_entropy
from benchmarks.common import emit, timeit


def run(quick: bool = False):
    rng = np.random.default_rng(1)
    n = 1 << 16
    names = (["bp-d1", "bp-dv", "fastpfor-d1", "varint"] if quick
             else codecs.ALL_CODECS)
    for label, bits in (("dense", 19), ("sparse", 30)):
        x = clusterdata(rng, n, bits)
        emit(f"decode/{label}/entropy", 0.0,
             f"{delta_entropy(x):.1f} bits/int delta entropy")
        xd = jnp.asarray(x.astype(np.int32))
        t = timeit(lambda: xd.copy())
        emit(f"decode/{label}/copy", t, f"{n / t / 1e9:.2f} Gints/s")
        for name in names:
            c = codecs.get_codec(name)
            enc = c.encode(x)
            if name == "varint":           # scalar host decode (paper's
                t = timeit(lambda: c.decode(enc), reps=1)   # scalar baseline)
            else:
                t = timeit(lambda: c.decode(enc))
            emit(f"decode/{label}/{name}", t,
                 f"{n / t / 1e9:.3f} Gints/s; {c.bits_per_int(enc):.1f} "
                 f"bits/int")


if __name__ == "__main__":
    run()
