"""Beyond-paper benchmark: the paper's codec on the DP gradient wire
(DESIGN.md §3.2) — compression ratio vs dense f32 all-reduce and index
bits/coordinate across top-k densities."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.distributed import grad_compress as gc
from benchmarks.common import emit, timeit


def run(quick: bool = False):
    rng = np.random.default_rng(5)
    n = 1 << 20 if not quick else 1 << 16
    g = rng.normal(size=n).astype(np.float32) * \
        (rng.random(n) < 0.3)                     # realistic sparsity pattern
    res = jnp.zeros(n)
    for frac in ([0.01] if quick else [0.001, 0.01, 0.05]):
        k = max(int(n * frac), 1)
        t = timeit(lambda: gc.sparsify(jnp.asarray(g), res, k))
        idx, vals, _ = gc.sparsify(jnp.asarray(g), res, k)
        packed, _ = gc.encode_wire(np.asarray(idx), np.asarray(vals))
        emit(f"gradcompress/top{frac}", t,
             f"{gc.compress_ratio(n, k, packed):.0f}x vs dense f32; "
             f"{gc.wire_bits_per_coord(packed):.1f} bits/coord")


if __name__ == "__main__":
    run()
