"""GraphSAGE minibatch training over a *compressed* adjacency: CSR neighbor
lists are sorted integer lists, stored with the paper's codec; the neighbor
sampler runs inside the jitted train step.

    PYTHONPATH=src python examples/gnn_sampling.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.data import graph_data
from repro.models import gnn
from repro.optim import adamw
from repro.train.steps import make_gnn_train_step

N, DEG = 20_000, 16
g = graph_data.synthetic_graph(N, DEG, seed=0, d_feat=64, n_classes=16)
print(f"graph: {N} nodes, {len(g['indices'])} edges")

# adjacency compressed with the paper's codec (bp-d1 over row-offset stream)
cc = graph_data.CompressedCSR.compress(g["indptr"], g["indices"], N)
print(f"adjacency: {cc.bits_per_edge():.2f} bits/edge (vs 32 raw) — "
      f"{32 / cc.bits_per_edge():.1f}x compression")
indices = cc.decompress()                      # pipeline decodes per epoch
assert np.array_equal(indices, g["indices"])

cfg = gnn.GNNConfig(name="sage-demo", d_feat=64, n_classes=16, d_hidden=64)
params = gnn.init_params(jax.random.PRNGKey(0), cfg)
opt_cfg = adamw.AdamWConfig(lr=1e-2, weight_decay=0.0)
step = jax.jit(make_gnn_train_step(cfg, "minibatch", opt_cfg,
                                   fanout=(10, 5)))
opt = adamw.init(params, opt_cfg)

feats = jnp.asarray(g["x"])
indptr = jnp.asarray(g["indptr"])
indices_j = jnp.asarray(indices)
labels = jnp.asarray(g["labels"])
rng = jax.random.PRNGKey(1)
for i in range(60):
    rng, k1, k2 = jax.random.split(rng, 3)
    seeds = jax.random.randint(k1, (256,), 0, N)
    batch = {"feats": feats, "indptr": indptr, "indices": indices_j,
             "seeds": seeds, "labels": labels[seeds]}
    params, opt, m = step(params, opt, batch, k2)
    if i % 15 == 0 or i == 59:
        print(f"step {i:3d} loss {float(m['loss']):.4f}")
print("sampled GraphSAGE training over compressed adjacency — done")
