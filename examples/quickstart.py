"""Quickstart: compress a sorted integer list, decode it (library + Pallas
kernel paths), intersect two lists — the paper's §3–§5 in 40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import bitpack, codecs
from repro.core import intersect as its
from repro.data.clusterdata import clusterdata, paired_lists
from repro.kernels import ops

rng = np.random.default_rng(0)

# --- compress / decompress (paper §3-4) -----------------------------------
docs = clusterdata(rng, 100_000, universe_bits=24)
for name in ["bp-d1", "bp-dv", "fastpfor-d1", "varint"]:
    codec = codecs.get_codec(name)
    enc = codec.encode(docs)
    out = codec.decode_np(enc)
    assert np.array_equal(out, docs)
    print(f"{name:14s} {codec.bits_per_int(enc):5.2f} bits/int "
          f"(raw 32.00) — round-trip OK")

# the same decode through the Pallas TPU kernel (interpret mode on CPU)
plist = bitpack.encode(docs, mode="d1")
vals = np.asarray(ops.decode_packed(plist))[: plist.n]
assert np.array_equal(vals, docs)
print("Pallas integrated unpack+prefix-sum kernel — round-trip OK")

# --- intersect (paper §5) ---------------------------------------------------
r, f = paired_lists(rng, 2_000, 500_000, universe_bits=24)
expect = np.intersect1d(r, f)

rp = jnp.asarray(its.pad_to(r, its.pow2_bucket(len(r))))
fp = jnp.asarray(its.pad_to(f, its.pow2_bucket(len(f), floor=1024)))
mask = its.intersect_auto(rp, fp, len(r), len(f))       # ratio-dispatched
vals, cnt = its.compact(rp, mask)
assert np.array_equal(np.asarray(vals)[: int(cnt)], expect)
print(f"intersect_auto: |r|={len(r)} |f|={len(f)} → {int(cnt)} matches OK")

# galloping over the *compressed* long list (block-max skip index)
pf = bitpack.encode(f, mode="d1")
mask = its.intersect_packed(rp, pf)
vals, cnt = its.compact(rp, mask)
assert np.array_equal(np.asarray(vals)[: int(cnt)], expect)
print(f"packed-gallop (skip index, no full decode) → {int(cnt)} matches OK")
