"""END-TO-END DRIVER (the paper's kind is serving): build an inverted index
over a synthetic corpus fitted to the paper's Table 2 query-log marginals,
compress posting lists with S4-BP128-style codecs + HYB+M2 bitmaps, and serve
batched conjunctive queries — results verified against a brute-force oracle.

    PYTHONPATH=src python examples/search_engine.py
"""

import time

import numpy as np

from repro.index import builder, corpus as corpus_lib, engine

N_DOCS = 1 << 17
N_QUERIES = 40

print(f"synthesizing corpus: {N_DOCS} docs, {N_QUERIES} queries "
      "(Table 2 marginals)...")
corpus = corpus_lib.synthesize(n_docs=N_DOCS, n_queries=N_QUERIES, seed=11)
sizes = [len(p) for p in corpus.postings]
print(f"  {corpus.n_terms} terms, posting lengths "
      f"p50={int(np.median(sizes))} max={max(sizes)}")

for codec, B in [("fastpfor-d1", 0), ("bp-d1", 16), ("fastpfor-d1", 16)]:
    idx = builder.build(corpus.postings, corpus.n_docs, codec_name=codec,
                        B=B, n_parts=2)
    st = idx.stats()
    engine.query(idx, corpus.queries[0])        # warm jit buckets
    t0 = time.perf_counter()
    hits = 0
    for q in corpus.queries:
        res = engine.query(idx, q)
        hits += res.count
    dt = (time.perf_counter() - t0) / len(corpus.queries)
    # verify against the oracle
    for q in corpus.queries[:10]:
        assert engine.query(idx, q).count == \
            len(engine.brute_force(corpus.postings, q))
    print(f"codec={codec:12s} B={B:2d}: {st['bits_per_int']:5.2f} bits/int, "
          f"{dt * 1e3:7.2f} ms/query, {hits} total hits — verified ✓")

print("\nServed and verified — HYB+M2 over compressed lists (paper §6.7).")
