"""Train a small LM for a few hundred steps with the full substrate:
AdamW + cosine schedule, async atomic checkpointing, straggler-tolerant
prefetch, and the paper's codec on the DP gradient wire (top-k sparsified
gradient indices, delta+bit-packed).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse

import numpy as np
import jax
import jax.numpy as jnp

from repro.data.lm_data import TokenStream, make_shuffle_index
from repro.distributed import grad_compress as gc
from repro.core import bitpack
from repro.models.transformer import LMConfig, init_params
from repro.optim import adamw
from repro.train.steps import make_lm_train_step
from repro.train.trainer import Trainer, TrainerConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
args = ap.parse_args()

cfg = LMConfig(name="lm-demo", n_layers=4, d_model=128, n_heads=4, n_kv=2,
               d_ff=256, vocab=512, act="swiglu", remat="none")
params = init_params(jax.random.PRNGKey(0), cfg)
n_params = sum(x.size for x in jax.tree.leaves(params))
print(f"model: {n_params / 1e6:.2f}M params")

# epoch shuffle-index map ships compressed (the paper's codec on the wire)
order, packed = make_shuffle_index(100_000, epoch=0)
print(f"shuffle index: {bitpack.bits_per_int(packed):.2f} bits/id "
      f"(vs 32 raw)")

stream = TokenStream(cfg.vocab, seed=0)


def data_iter():
    while True:
        b = stream.batch(8, 64)
        yield {k: jnp.asarray(v) for k, v in b.items()}


opt_cfg = adamw.AdamWConfig(lr=3e-3)
step = make_lm_train_step(cfg, opt_cfg, total_steps=args.steps, warmup=20)
trainer = Trainer(step, params, adamw.init(params, opt_cfg), data_iter(),
                  TrainerConfig(total_steps=args.steps, ckpt_every=100,
                                ckpt_dir="/tmp/repro_lm_demo",
                                log_every=25))
trainer.install_preemption_handler()
res = trainer.run(start_step=trainer.try_restore())
print("loss history:", [round(h, 3) for h in res["history"]])
assert res["history"][-1] < res["history"][0], "loss must decrease"

# demonstrate the gradient wire format on the final step's params
flat = jnp.concatenate([x.reshape(-1).astype(jnp.float32)
                        for x in jax.tree.leaves(trainer.params)])[:1 << 18]
idx, vals, _ = gc.sparsify(flat, jnp.zeros_like(flat), 2048)
packed, vals16 = gc.encode_wire(np.asarray(idx), np.asarray(vals))
print(f"grad wire: top-k 2048/{flat.size} coords, "
      f"{gc.wire_bits_per_coord(packed):.1f} bits/coord, "
      f"{gc.compress_ratio(flat.size, 2048, packed):.0f}x vs dense f32 "
      f"all-reduce")
print("done — checkpoints in /tmp/repro_lm_demo")
