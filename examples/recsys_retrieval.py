"""Recsys retrieval with inverted-index candidate generation: user attributes
→ compressed posting lists → SvS intersection (the paper's engine) → dense
scoring with MIND multi-interest embeddings → top-k.

    PYTHONPATH=src python examples/recsys_retrieval.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bitpack
from repro.core import intersect as its
from repro.data import recsys_data
from repro.models import recsys

rng = np.random.default_rng(7)
N_ITEMS = 1 << 16

# --- offline: per-attribute posting lists (item ids are sorted ints) -------
# e.g. "category=c" and "brand=b" each map to a sorted item-id list
cate_of = rng.integers(0, 64, size=N_ITEMS)
brand_of = rng.integers(0, 128, size=N_ITEMS)
cate_lists = {c: np.nonzero(cate_of == c)[0].astype(np.int64)
              for c in range(64)}
brand_lists = {b: np.nonzero(brand_of == b)[0].astype(np.int64)
               for b in range(128)}
packed_cate = {c: bitpack.encode(v, mode="d1") for c, v in cate_lists.items()}
bits = np.mean([bitpack.bits_per_int(p) for p in packed_cate.values()])
print(f"attribute posting lists compressed at {bits:.2f} bits/item")

# --- online: candidate generation by intersection ---------------------------
user_cate, user_brand = 3, 17
r = cate_lists[user_cate]
f = brand_lists[user_brand]
expect = np.intersect1d(r, f)
rp = jnp.asarray(its.pad_to(r, its.pow2_bucket(len(r))))
fp = jnp.asarray(its.pad_to(f, its.pow2_bucket(len(f), floor=1024)))
mask = its.intersect_auto(rp, fp, len(r), len(f))
cands, cnt = its.compact(rp, mask)
cands = np.asarray(cands)[: int(cnt)]
assert np.array_equal(cands, expect)
print(f"candidate generation: |cate|={len(r)} ∩ |brand|={len(f)} → "
      f"{len(cands)} candidates (verified)")

# --- dense scoring: MIND multi-interest --------------------------------------
cfg = recsys.RecsysConfig(name="mind-demo", arch="mind", n_items=N_ITEMS,
                          embed_dim=32, seq_len=32, n_neg=15)
params = recsys.INIT["mind"](jax.random.PRNGKey(0), cfg)
batch = recsys_data.retrieval_batch(rng, cfg, len(cands))
batch["cand_items"] = cands.astype(np.int32)
batch = {k: jnp.asarray(v) for k, v in batch.items()}
scores = recsys.RETRIEVAL["mind"](params, batch, cfg)
top_vals, top_idx = jax.lax.top_k(scores, min(10, len(cands)))
print("top-10 item ids:", np.asarray(cands)[np.asarray(top_idx)].tolist())
print("retrieval pipeline (intersection → multi-interest scoring) — done")
