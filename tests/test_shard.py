"""Differential coverage for the sharded query fan-out (ISSUE 4;
DESIGN.md §2.5/§2.9).

Sharded execution must be *byte-identical* to the sequential engine at
every shard count, on both intersect backends, for both corpus shapes —
sharding changes where rows live and which device intersects them, never
what any row computes.  These tests run on whatever devices the host
offers: with one device all shards share it (the dataflow is identical);
CI additionally runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so the placement
tests see real multi-device meshes.

Layers:
  * sharded == sequential on {jax, pallas} × {uniform, skewed} at
    shards ∈ {1, 2, 4},
  * empty-part / single-part / empty-batch edges,
  * placement-map accounting: contiguous part→shard cover, device-pinned
    pools, per-shard resident staging, more-shards-than-devices folding.
"""

import numpy as np
import pytest

import jax

from repro.index import builder, corpus as corpus_lib, engine, shard, source

pytestmark = pytest.mark.shard

SHARD_COUNTS = (1, 2, 4)


# --------------------------------------------------------------------------
# fixtures
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def uniform():
    """Table-2-shaped corpus with bitmaps and 4 parts (1:1 at 4 shards)."""
    corpus = corpus_lib.synthesize(n_docs=1 << 14, n_queries=10, seed=33)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="fastpfor-d1", B=16, n_parts=4)
    seq = [engine.query(idx, q) for q in corpus.queries]
    return idx, corpus.queries, seq


@pytest.fixture(scope="module")
def skewed():
    """Tiny seed + very long second term: packed (skip-aware partial
    decode) folds flow through the sharded assembly."""
    n_docs = 1 << 16
    table = {2: (100.0, [0.8 * (1 << 18) / n_docs,
                         38000.0 * (1 << 18) / n_docs])}
    corpus = corpus_lib.synthesize(n_docs=n_docs, n_queries=4, seed=7,
                                   table=table)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="bp8-d1", B=0, n_parts=4)
    seq = [engine.query(idx, q) for q in corpus.queries]
    return idx, corpus.queries, seq


def _assert_identical(results, seq):
    assert len(results) == len(seq)
    for got, want in zip(results, seq):
        assert got.count == want.count
        assert got.docs.dtype == want.docs.dtype
        assert np.array_equal(got.docs, want.docs)      # byte-identical


# --------------------------------------------------------------------------
# sharded == sequential differential matrix
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_sharded_matches_sequential_uniform(uniform, n_shards, backend):
    idx, queries, seq = uniform
    sharded = shard.shard_index(idx, n_shards)
    out = shard.execute_sharded(sharded, queries, batch_size=4, depth=2,
                                backend=backend)
    _assert_identical(out, seq)


@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_sharded_matches_sequential_skewed(skewed, n_shards, backend):
    idx, queries, seq = skewed
    sharded = shard.shard_index(idx, n_shards)
    out = shard.execute_sharded(sharded, queries, batch_size=2, depth=2,
                                backend=backend)
    _assert_identical(out, seq)


def test_sharded_matches_at_depth_one(uniform):
    """depth=1 (strictly serial pipeline) — same results, fewer overlaps."""
    idx, queries, seq = uniform
    sharded = shard.shard_index(idx, 4)
    out = shard.execute_sharded(sharded, queries, batch_size=4, depth=1)
    _assert_identical(out, seq)


def test_shards_4_match_shards_1(uniform):
    """The serve.py --shards acceptance shape: 4-shard output equals
    1-shard output element for element (both equal the engine)."""
    idx, queries, _ = uniform
    one = shard.execute_sharded(shard.shard_index(idx, 1), queries,
                                batch_size=4)
    four = shard.execute_sharded(shard.shard_index(idx, 4), queries,
                                 batch_size=4)
    _assert_identical(four, one)


# --------------------------------------------------------------------------
# edges
# --------------------------------------------------------------------------

def test_sharded_empty_batch(uniform):
    idx, _, _ = uniform
    sharded = shard.shard_index(idx, 2)
    assert shard.execute_sharded(sharded, [], batch_size=8) == []


def test_sharded_single_query(uniform):
    idx, queries, seq = uniform
    sharded = shard.shard_index(idx, 4)
    out = shard.execute_sharded(sharded, [queries[0]], batch_size=8)
    _assert_identical(out, seq[:1])


def test_single_part_many_shards():
    """n_parts < n_shards: trailing shards own no parts and contribute
    all-inactive rows; results still byte-identical."""
    corpus = corpus_lib.synthesize(n_docs=1 << 13, n_queries=6, seed=5)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="fastpfor-d1", B=16, n_parts=1)
    seq = [engine.query(idx, q) for q in corpus.queries]
    sharded = shard.shard_index(idx, 4)
    owners = {s for s in sharded.part_shard}
    assert owners == {0}                     # the single part lives on shard 0
    out = shard.execute_sharded(sharded, corpus.queries, batch_size=4)
    _assert_identical(out, seq)


def test_empty_part_term():
    """A term with no postings in some doc range yields an 'empty' posting
    in that part; queries touching it skip the part on every shard —
    exactly like the sequential engine."""
    rng = np.random.default_rng(3)
    n_docs = 1 << 13
    lo_only = np.sort(rng.choice(n_docs // 4, 300, replace=False))   # part 0
    spread = np.sort(rng.choice(n_docs, 2000, replace=False))
    idx = builder.build([lo_only, spread], n_docs,
                        codec_name="fastpfor-d1", B=0, n_parts=4)
    q = [0, 1]
    seq = engine.query(idx, q)
    sharded = shard.shard_index(idx, 4)
    out = shard.execute_sharded(sharded, [q], batch_size=2)
    _assert_identical(out, [seq])


# --------------------------------------------------------------------------
# placement-map accounting
# --------------------------------------------------------------------------

def test_placement_map_contiguous_cover(uniform):
    idx, _, _ = uniform
    for n_shards in SHARD_COUNTS:
        sharded = shard.shard_index(idx, n_shards, warm=False)
        ps = sharded.part_shard
        assert len(ps) == len(idx.parts)
        assert ps == sorted(ps)                          # contiguous ranges
        assert set(ps) <= set(range(n_shards))
        assert ps[0] == 0 and ps[-1] == n_shards - 1 or n_shards == 1


def test_pools_pinned_to_placement(uniform):
    idx, _, _ = uniform
    sharded = shard.shard_index(idx, 4, warm=False)
    assert len(sharded.pools) == 4
    for pool, dev in zip(sharded.pools, sharded.placement):
        assert isinstance(pool, source.ResidentPool)
        assert pool.device is dev
    # shards fold contiguously onto however many devices exist
    ndev = len(sharded.devices)
    assert 4 % ndev == 0
    per = 4 // ndev
    for s, dev in enumerate(sharded.placement):
        assert dev is sharded.devices[s // per]


def test_warm_stages_per_shard(uniform):
    idx, queries, seq = uniform
    sharded = shard.shard_index(idx, 4)          # warm=True default
    st = sharded.stats()
    assert st["n_shards"] == 4
    assert [s["parts"] for s in st["shards"]] == [[0], [1], [2], [3]]
    for s in st["shards"]:
        assert s["resident_lists"] > 0           # every shard staged its part
        assert s["resident_ints"] > 0
    # staged buffers really live on the placement device
    pool = sharded.pools[-1]
    key = next(iter(pool._store))
    assert sharded.placement[-1] in pool._store[key]["dev"].devices()
    # steady state: a second pass decodes nothing
    shard.execute_sharded(sharded, queries, batch_size=4)
    stats: dict = {}
    out = shard.execute_sharded(sharded, queries, batch_size=4, stats=stats)
    _assert_identical(out, seq)
    assert stats.get("decoded_lists", 0) == 0


def test_sharded_skip_folds_still_fire(skewed):
    """Long skip-capable lists stay compressed per shard: the packed
    partial-decode path runs inside the sharded program too."""
    idx, queries, seq = skewed
    sharded = shard.shard_index(idx, 2)
    stats: dict = {}
    out = shard.execute_sharded(sharded, queries, batch_size=2, stats=stats)
    _assert_identical(out, seq)
    assert stats.get("skip_folds", 0) > 0
