"""Codec-breadth + storage-autotuner coverage (ISSUE 8).

Layers:
  * property-style roundtrip sweep — empty / single / dense-run / 32-bit
    extreme inputs across every codec family and delta mode, host decode
    and device decode both,
  * StreamVByte Pallas kernel vs host reference differential,
  * cost-model autotuner unit behavior (short → host-decoded varint,
    long → skip-capable bitpack; a zero-dispatch table — a compiled-TPU
    profile — flips mid lists to composite, showing the table is the
    platform knob),
  * autotuned-vs-all-bitpack byte-identity over {jax, pallas} × {uniform,
    skewed} × shards {1, 2}, fused and unfused.
"""

import numpy as np
import pytest

from repro.core import codecs, composite, streamvbyte
from repro.core.deltas import MODES
from repro.index import batch as batch_lib
from repro.index import builder, corpus as corpus_lib, engine

pytestmark = pytest.mark.codec

FAMILIES = ["bp", "bp8", "fastpfor", "streamvbyte", "composite"]
DELTA_MODES = [m for m in MODES if m != "none"]


def _cases(rng):
    """Adversarial value sets: the block/tail/width boundaries every codec
    layout has to get right."""
    yield "empty", np.zeros(0, np.int64)
    yield "single", np.array([7], np.int64)
    yield "single_zero", np.array([0], np.int64)
    yield "dense_run", np.arange(1000, dtype=np.int64)
    yield "block_exact", np.arange(0, 2048, 2, dtype=np.int64)  # 1024 ints
    yield "block_plus_one", np.arange(0, 2050, 2, dtype=np.int64)
    yield "lane_tail", np.sort(rng.choice(1 << 20, 129, replace=False))
    yield ("extremes_32bit",
           np.array([0, 1, 2**31 - 1, 2**32 - 2, 2**32 - 1], np.int64))
    yield ("wide_gaps",
           np.cumsum(rng.integers(1, 1 << 24, 300)).astype(np.int64))


@pytest.mark.parametrize("fam", FAMILIES)
@pytest.mark.parametrize("mode", DELTA_MODES)
def test_roundtrip_sweep(fam, mode):
    if fam == "composite" and mode != "d1":
        pytest.skip("composite registered for d1 only")
    c = codecs.get_codec(f"{fam}-{mode}")
    rng = np.random.default_rng(5)
    for label, vals in _cases(rng):
        enc = c.encode(vals)
        got = np.asarray(c.decode_np(enc))[: len(vals)]
        np.testing.assert_array_equal(
            got.astype(np.int64), vals, err_msg=f"{fam}-{mode}/{label}")


@pytest.mark.parametrize("fam", FAMILIES + ["varint"])
def test_device_decode_matches_host(fam):
    c = codecs.get_codec("varint" if fam == "varint" else f"{fam}-d1")
    rng = np.random.default_rng(9)
    for label, vals in _cases(rng):
        enc = c.encode(vals)
        host = np.asarray(c.decode_np(enc))[: len(vals)]
        dev = np.asarray(c.decode(enc))[: len(vals)]
        np.testing.assert_array_equal(dev.astype(np.int64),
                                      host.astype(np.int64),
                                      err_msg=f"{fam}/{label}")


def test_streamvbyte_control_stream_layout():
    # 1/2/3/4-byte values land in the advertised 2-bit control codes
    vals = np.array([3, 300, 70000, 2**25], np.int64)
    sl = streamvbyte.encode(vals, mode="none")
    codes = [(int(sl.ctrl[0, 0]) >> (2 * i)) & 3 for i in range(4)]
    assert codes == [0, 1, 2, 3]
    np.testing.assert_array_equal(streamvbyte.decode_np(sl)[:4], vals)


@pytest.mark.parametrize("mode", DELTA_MODES)
def test_streamvbyte_pallas_kernel_matches_host(mode):
    from repro.kernels import svb_decode
    rng = np.random.default_rng(11)
    for n in (1, 300, 1024, 4096):
        vals = np.sort(rng.choice(1 << 28, n, replace=False)).astype(np.int64)
        sl = streamvbyte.encode(vals, mode=mode)
        host = streamvbyte.decode_np(sl)
        dev = np.asarray(svb_decode.decode_bucketed(sl))[: sl.n]
        np.testing.assert_array_equal(dev.astype(np.int64), host)


def test_composite_head_tail_split():
    per = composite.DEFAULT_ROWS * 128
    rng = np.random.default_rng(3)
    for n in (per - 1, per, per + 1, 3 * per + 17):
        vals = np.sort(rng.choice(1 << 26, n, replace=False)).astype(np.int64)
        cl = composite.encode(vals)
        assert cl.n_head == (n // per) * per
        assert cl.tail.n == n - cl.n_head
        np.testing.assert_array_equal(composite.decode_np(cl), vals)


# --------------------------------------------------------------------------
# autotuner unit behavior
# --------------------------------------------------------------------------

def test_autotune_dispatch_cost_drives_choice():
    cm = builder.CostModel.resolve(None)
    rng = np.random.default_rng(0)
    short = np.sort(rng.choice(1 << 18, 100, replace=False))
    long = np.sort(rng.choice(1 << 22, 50000, replace=False))
    name_s, skip_s = builder.autotune_choice(short, 1 << 18, cm)
    name_l, skip_l = builder.autotune_choice(long, 1 << 22, cm)
    # this container: device dispatch ~200 us/list hands short lists to the
    # host-decoded byte codecs; long lists stay skip-capable bitpack
    assert name_s in ("varint", "composite-d1") and not skip_s
    assert name_l == "bp-d1" and skip_l


def test_autotune_zero_dispatch_table_prefers_composite():
    # a compiled-TPU-shaped table (dispatch ~free, space dominant) flips
    # mid-length lists to the bitpack-head + varint-tail composite — the
    # cost table is the per-platform knob, not a hardcoded policy
    cm = builder.CostModel.resolve({
        "decode_ns_per_int": {"bp-d1": 1.0, "bp8-d1": 1.0,
                              "streamvbyte-d1": 1.1, "varint": 3.0},
        "dispatch_ns_per_list": {},
        "gallop_ns_per_probe": 10.0,
        "space_ns_per_byte": 50.0,
    })
    rng = np.random.default_rng(1)
    n = 1100                    # one full 1024-int head block + short tail
    seg = np.sort(rng.choice(1 << 22, n, replace=False))
    name, skip_ok = builder.autotune_choice(seg, 1 << 22, cm)
    assert name == "composite-d1" and not skip_ok


def test_cost_model_resolve_sources(tmp_path):
    import json
    table = {"decode_ns_per_int": {"bp-d1": 2.0},
             "dispatch_ns_per_list": {"bp-d1": 5.0},
             "gallop_ns_per_probe": 7.0}
    p = tmp_path / "cost.json"
    p.write_text(json.dumps(table))
    for cm in (builder.CostModel.resolve(table),
               builder.CostModel.resolve(str(p))):
        assert cm.decode_ns("bp") == 2.0
        assert cm.dispatch_ns("bp") == 5.0
        assert cm.gallop_ns_per_probe == 7.0
    assert builder.CostModel.resolve(None).decode_ns_per_int  # shipped table


def test_skip_ok_false_forces_decoded_path():
    corpus = corpus_lib.synthesize(n_docs=1 << 14, n_queries=6, seed=21)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="bp8-d1", B=0, n_parts=1)
    seq = [engine.query(idx, q) for q in corpus.queries]
    for part in idx.parts:            # flip every list off the skip path
        for tp in part.terms.values():
            tp.skip_ok = False
    stats: dict = {}
    out = batch_lib.execute_batch(idx, corpus.queries, skip=True, stats=stats)
    for a, b in zip(out, seq):
        assert a.count == b.count and np.array_equal(a.docs, b.docs)
    assert stats.get("skip_folds", 0) == 0


# --------------------------------------------------------------------------
# autotuned vs all-bitpack differential matrix
# --------------------------------------------------------------------------

def _corpora():
    uniform = corpus_lib.synthesize(n_docs=1 << 14, n_queries=8, seed=33)
    table = {2: (100.0, [0.8, 1500.0])}     # tiny rare + long frequent term
    skewed = corpus_lib.synthesize(n_docs=1 << 14, n_queries=8, seed=7,
                                   table=table)
    return {"uniform": uniform, "skewed": skewed}


@pytest.mark.parametrize("profile", ["uniform", "skewed"])
@pytest.mark.parametrize("n_parts", [1, 2])
def test_autotuned_matches_all_bitpack(profile, n_parts):
    corpus = _corpora()[profile]
    auto = builder.build(corpus.postings, corpus.n_docs, codec_name="auto",
                         B=16, n_parts=n_parts)
    bp = builder.build(corpus.postings, corpus.n_docs, codec_name="bp-d1",
                       B=16, n_parts=n_parts, varint_tail_below=0)
    sa, sb = auto.stats(), bp.stats()
    assert sa["bytes_per_int"] <= sb["bytes_per_int"]
    seq = [engine.query(bp, q) for q in corpus.queries]
    for backend in ("jax", "pallas"):
        for fuse in (True, False):
            out = batch_lib.execute_batch(
                auto, corpus.queries, backend=backend,
                plan=batch_lib.FusionPlan() if fuse else None, fuse=fuse)
            for a, b in zip(out, seq):
                assert a.count == b.count
                assert np.array_equal(np.asarray(a.docs), np.asarray(b.docs))


@pytest.mark.slow
@pytest.mark.parametrize("profile", ["uniform", "skewed"])
def test_autotuned_matches_all_bitpack_sharded(profile):
    from repro.index import shard as shard_lib
    corpus = _corpora()[profile]
    seq = None
    for codec_kw in (dict(codec_name="auto"),
                     dict(codec_name="bp-d1", varint_tail_below=0)):
        sharded = builder.build_sharded(
            corpus.postings, corpus.n_docs, n_shards=2, B=16, **codec_kw)
        out = shard_lib.execute_sharded(sharded, corpus.queries)
        if seq is None:
            seq = out
            continue
        for a, b in zip(out, seq):
            assert a.count == b.count
            assert np.array_equal(np.asarray(a.docs), np.asarray(b.docs))
