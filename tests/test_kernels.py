"""Pallas kernels vs pure-jnp oracles (interpret mode), swept over shapes,
bit widths and delta modes — the per-kernel allclose requirement."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import bitpack, deltas as deltas_lib
from repro.core import intersect as its
from repro.kernels import ops, ref
from repro.kernels import bitunpack as kb


MODES = ["none", "d1", "d2", "d4", "dm", "dv"]


def _make_block_with_width(rng, b: int):
    """One (32,128) block whose deltas need exactly width b."""
    if b == 0:
        d = np.zeros((1, 32, 128), np.uint32)
    else:
        d = rng.integers(0, 1 << b, size=(1, 32, 128)).astype(np.uint32)
        d[0, 0, 0] = (1 << b) - 1            # force the max
    return d


@pytest.mark.parametrize("b", list(range(0, 33)))
def test_unpack_kernel_all_widths(b, rng):
    """Width sweep: pack on host, unpack via kernel vs jnp oracle."""
    d = _make_block_with_width(rng, b)
    packed = bitpack.pack_block_np(d[0], b)
    padded = np.zeros((1, 32, 128), np.uint32)
    padded[0, : packed.shape[0]] = packed
    widths = jnp.asarray([b], jnp.int32)
    seeds = jnp.asarray([0], jnp.uint32)
    got = ops.unpack_blocks(jnp.asarray(padded), widths, seeds, mode="none")
    want = ref.unpack_blocks_ref(jnp.asarray(padded), widths, seeds,
                                 mode="none")
    assert np.array_equal(np.asarray(got), np.asarray(want))
    assert np.array_equal(np.asarray(got), d)


@pytest.mark.parametrize("mode", MODES)
def test_unpack_kernel_integrated_prefix(mode, rng):
    """Integrated unpack+prefix-sum (Algorithm 1) vs library decode."""
    x = np.cumsum(rng.integers(1, 2000, size=5 * 4096 + 123))
    pl = bitpack.encode(x, mode=mode)
    got = np.asarray(ops.decode_packed(pl))[: pl.n]
    assert np.array_equal(got, x)
    got_ni = np.asarray(ops.decode_packed_ni(pl))[: pl.n]
    assert np.array_equal(got_ni, x)


@pytest.mark.parametrize("mode", ["d1", "d4", "dm", "dv"])
def test_pack_kernel_roundtrip(mode, rng):
    x = np.cumsum(rng.integers(1, 300, size=4 * 4096)).astype(np.int64)
    blocks = x.reshape(4, 32, 128)
    maxes = blocks[:, -1, -1]
    seeds_np = np.concatenate([[0], maxes[:-1]]).astype(np.int64)
    d = deltas_lib.encode_deltas_np(blocks, seeds_np, mode)
    widths = jnp.asarray(
        [int(d[k].max()).bit_length() for k in range(4)], jnp.int32)
    seeds = jnp.asarray(seeds_np.astype(np.uint32))
    packed_k = ops.pack_blocks(jnp.asarray(blocks.astype(np.uint32)),
                               seeds, widths, mode=mode)
    packed_r = ref.pack_blocks_ref(jnp.asarray(d), widths)
    assert np.array_equal(np.asarray(packed_k), np.asarray(packed_r))
    vals = ops.unpack_blocks(packed_k, widths, seeds, mode=mode)
    assert np.array_equal(np.asarray(vals), blocks.astype(np.uint32))


@pytest.mark.parametrize("m,n", [(64, 1024), (500, 65536), (128, 1 << 18)])
def test_intersect_kernel_sweep(m, n, rng):
    inter = np.sort(rng.choice(2**25, size=m // 3, replace=False))
    r = np.union1d(inter, rng.choice(2**25, size=m, replace=False))
    f = np.union1d(inter, rng.choice(2**25, size=n, replace=False))
    expect = its.intersect_ref(r, f)
    mask_k = ops.intersect_gallop(jnp.asarray(r, jnp.int32),
                                  jnp.asarray(f, jnp.int32))
    rp = jnp.asarray(r, jnp.int32)
    vals, cnt = its.compact(rp, mask_k)
    assert np.array_equal(np.asarray(vals)[: int(cnt)], expect)
    # oracle agreement
    M = its.pow2_bucket(len(r))
    N = its.pow2_bucket(len(f), floor=1024)
    mask_o = ref.intersect_gallop_ref(jnp.asarray(its.pad_to(r, M)),
                                      jnp.asarray(its.pad_to(f, N)))
    assert np.array_equal(np.asarray(mask_k), np.asarray(mask_o)[: len(r)])


def test_kernel_vmem_budget():
    """BlockSpec working sets stay under TPU v5e VMEM (16 MiB)."""
    unpack_ws = 2 * 32 * 128 * 4                   # in+out tiles
    assert unpack_ws < 16 * 2**20
    gallop_ws = ops.GALLOP_VMEM_CAP * 4 + 2 * kb.LANES * 4
    assert gallop_ws <= 8 * 2**20                  # f table + r tile


def test_pad_packed_empty_payload():
    """Regression (ISSUE 5): with T == 0 flat words the old
    clip(..., 0, T-1) produced index -1 and jnp.take silently wrapped;
    the empty case must return all-zero blocks of the right shape."""
    flat = jnp.zeros((0, kb.LANES), jnp.uint32)
    for K in (0, 3):
        out = ops.pad_packed(flat, jnp.zeros((K,), jnp.int32))
        assert out.shape == (K, ops.ROWS, kb.LANES)
        assert not np.asarray(out).any()
    # non-empty payloads are untouched by the guard
    flat = jnp.arange(2 * kb.LANES, dtype=jnp.uint32).reshape(2, kb.LANES)
    out = ops.pad_packed(flat, jnp.zeros((1,), jnp.int32))
    assert np.array_equal(np.asarray(out[0, :2]), np.asarray(flat))
    assert np.array_equal(np.asarray(out[0, 2:]),
                          np.broadcast_to(np.asarray(flat[1]),
                                          (ops.ROWS - 2, kb.LANES)))
