"""Skip-path coverage (ISSUE 2): the packed-gallop family against the
scalar oracle across delta modes, plus the posting-source layer.

Layers:
  * ``intersect_packed`` / ``intersect_packed_candidates`` /
    ``intersect_packed_batch`` vs ``intersect_ref`` across all delta modes,
    empty results, all-match, and sentinel-padded candidate buffers,
  * the FastPFOR exception patch inside the candidate-block decode,
  * the fused Pallas packed-gallop kernel (interpret mode),
  * engine-level composition: skip path + DecodeCache coexist, batched
    skip on/off/backends return byte-identical results while decoding
    ≥ 5× fewer ints on skewed-ratio queries,
  * DecodeCache LRU order + hit counters, shared-vocab query logs.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import bitpack, fastpfor
from repro.core import intersect as its
from repro.index import batch as batch_lib
from repro.index import builder, corpus as corpus_lib, engine, source

MODES = ["d1", "d2", "d4", "dm", "dv"]


def _pair(rng, m, n, overlap=0.3, universe=2**22):
    inter = np.sort(rng.choice(universe, size=max(int(m * overlap), 1),
                               replace=False))
    r = np.union1d(inter, rng.choice(universe, size=m, replace=False))
    f = np.union1d(inter, rng.choice(universe, size=n, replace=False))
    return r.astype(np.int64), f.astype(np.int64)


def _layout_args(payload, r_values, c_floor=source.CAND_FLOOR):
    """Host-side prep mirroring the source layer: buckets + candidate ids."""
    k_pad = its.pow2_bucket(int(payload.widths.shape[0]), floor=1)
    t_pad = its.pow2_bucket(int(payload.flat_words.shape[0]), floor=1)
    E = int(getattr(payload, "exc_pos", np.zeros(0)).shape[0])
    e_pad = its.pow2_bucket(E, floor=1) if E else 0
    lay = bitpack.layout_np(payload, k_pad, t_pad, e_pad)
    blk = bitpack.candidate_block_ids(np.asarray(payload.maxes), r_values)
    c_pad = its.pow2_bucket(len(blk), floor=c_floor)
    blk_p = source.pad_block_ids(blk, c_pad, k_pad)
    return (jnp.asarray(lay.words), jnp.asarray(lay.widths),
            jnp.asarray(lay.offsets), jnp.asarray(lay.maxes),
            jnp.asarray(blk_p), jnp.asarray(lay.exc_pos),
            jnp.asarray(lay.exc_add))


def _run_candidates(r, payload, mode):
    rp = jnp.asarray(its.pad_to(r, its.pow2_bucket(len(r))))
    args = _layout_args(payload, r)
    mask = its.intersect_packed_candidates(rp, *args, mode=mode,
                                           block_rows=payload.block_rows)
    vals, cnt = its.compact(rp, mask)
    return np.asarray(vals)[: int(cnt)]


@pytest.mark.parametrize("mode", MODES)
def test_packed_paths_match_oracle_all_modes(mode, rng):
    r, f = _pair(rng, 250, 120000)
    expect = its.intersect_ref(r, f)
    pf = bitpack.encode(f, mode=mode)
    rp = jnp.asarray(its.pad_to(r, its.pow2_bucket(len(r))))
    mask = its.intersect_packed(rp, pf)                 # per-element gallop
    vals, cnt = its.compact(rp, mask)
    assert np.array_equal(np.asarray(vals)[: int(cnt)], expect)
    assert np.array_equal(_run_candidates(r, pf, mode), expect)


@pytest.mark.parametrize("mode", ["d1", "dv"])
def test_packed_candidates_empty_and_all_match(mode, rng):
    f = np.sort(rng.choice(2**22, size=60000, replace=False)).astype(np.int64)
    pf = bitpack.encode(f, mode=mode)
    # disjoint: odd values vs an even-only list
    evens = 2 * np.sort(rng.choice(2**20, size=50000, replace=False))
    pe = bitpack.encode(evens.astype(np.int64), mode=mode)
    odd = evens[:300] + 1
    assert _run_candidates(odd, pe, mode).size == 0
    # all-match: candidates drawn from the list itself
    sub = np.sort(rng.choice(f, size=200, replace=False))
    assert np.array_equal(_run_candidates(sub, pf, mode), sub)


def test_packed_candidates_sentinel_padding(rng):
    """Sentinel-padded rows in the candidate buffer must never match, even
    though padded layout slots also decode to SENTINEL."""
    r, f = _pair(rng, 100, 80000)
    pf = bitpack.encode(f, mode="d1")
    rp = jnp.asarray(its.pad_to(r, 1024))               # heavy sentinel tail
    args = _layout_args(pf, r)
    mask = np.asarray(its.intersect_packed_candidates(
        rp, *args, mode="d1", block_rows=pf.block_rows))
    assert not mask[len(r):].any()
    got = np.asarray(rp)[mask]
    assert np.array_equal(np.sort(got), its.intersect_ref(r, f))


def test_packed_candidates_fastpfor_exceptions(rng):
    """Patched (exception-carrying) blocks decode correctly inside the
    candidate-block gather."""
    r, f = _pair(rng, 150, 150000, universe=2**26)
    pf = fastpfor.encode(f, mode="d1")
    assert int(pf.exc_pos.shape[0]) > 0                 # exceptions present
    assert np.array_equal(_run_candidates(r, pf, "d1"),
                          its.intersect_ref(r, f))


@pytest.mark.parametrize("mode", ["d1", "dm"])
def test_packed_batch_matches_oracle(mode, rng):
    B = 4
    f0 = np.sort(rng.choice(2**22, size=100000,
                            replace=False)).astype(np.int64)
    pf0 = bitpack.encode(f0, mode=mode)
    k_pad = its.pow2_bucket(pf0.num_blocks, floor=1)
    t_pad = its.pow2_bucket(int(pf0.flat_words.shape[0]), floor=1)
    rows, args_rows, expects = [], [], []
    for _ in range(B):
        r, f = _pair(rng, 120, 100000)
        pf = bitpack.encode(f, mode=mode)
        lay = bitpack.layout_np(pf, k_pad, t_pad, 0)
        blk = bitpack.candidate_block_ids(np.asarray(pf.maxes), r)
        blk_p = source.pad_block_ids(blk, 256, k_pad)
        rows.append(its.pad_to(r, 256))
        args_rows.append((lay.words, lay.widths, lay.offsets, lay.maxes,
                          blk_p, lay.exc_pos, lay.exc_add))
        expects.append(its.intersect_ref(r, f))
    R = jnp.asarray(np.stack(rows))
    stacked = [jnp.asarray(np.stack([a[i] for a in args_rows]))
               for i in range(7)]
    mask = its.intersect_packed_batch(R, *stacked, mode=mode,
                                      block_rows=pf0.block_rows)
    vals, cnt = its.compact_batch(R, mask)
    for b in range(B):
        assert np.array_equal(np.asarray(vals)[b, : int(cnt[b])],
                              expects[b])
    # fused Pallas kernel (interpret mode): same mask
    from repro.kernels import ops as kernel_ops
    kmask = kernel_ops.intersect_packed_batch(
        R, *stacked, mode=mode, block_rows=pf0.block_rows)
    assert np.array_equal(np.asarray(kmask), np.asarray(mask))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31), st.integers(10, 500), st.integers(20000, 200000))
def test_property_packed_candidates(seed, m, n):
    rng = np.random.default_rng(seed)
    r, f = _pair(rng, m, n)
    pf = bitpack.encode(f, mode="d1")
    assert np.array_equal(_run_candidates(r, pf, "d1"),
                          np.intersect1d(r, f))


# --------------------------------------------------------------------------
# posting-source layer + engines
# --------------------------------------------------------------------------

def _skewed_corpus(seed=7, n_docs=1 << 17):
    table = {2: (100.0, [1.6, 76000.0])}
    return corpus_lib.synthesize(n_docs=n_docs, n_queries=6, seed=seed,
                                 table=table)


def test_engine_skip_composes_with_cache():
    """Skip path and DecodeCache are no longer mutually exclusive: short
    lists are cached, long lists are skip-probed (never cached), and the
    results match the uncached/no-skip paths exactly."""
    corpus = _skewed_corpus()
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="bp8-d1", B=0, n_parts=1)
    cache = engine.DecodeCache(capacity_ints=1 << 26)
    baseline = [engine.query(idx, q, skip=False) for q in corpus.queries]
    for _ in range(2):
        stats: dict = {}
        got = [engine.query(idx, q, cache=cache, stats=stats)
               for q in corpus.queries]
        for a, b in zip(got, baseline):
            assert a.count == b.count
            assert np.array_equal(a.docs, b.docs)
        assert stats.get("skip_folds", 0) > 0
    # long lists never entered the cache: every entry is a short list
    for vals, _ in cache._store.values():
        assert vals.shape[0] <= 1024
    assert cache.hits > 0


def test_batched_skip_decodes_less_and_matches():
    """ISSUE 2 acceptance: ≥5× fewer decoded ints on skewed-ratio queries,
    batched results byte-identical to sequential on both backends."""
    corpus = _skewed_corpus()
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="bp8-d1", B=0, n_parts=1)
    seq = [engine.query(idx, q) for q in corpus.queries]
    on, off = {}, {}
    res_on = batch_lib.execute_batch(idx, corpus.queries, skip=True,
                                     stats=on)
    res_off = batch_lib.execute_batch(idx, corpus.queries, skip=False,
                                      stats=off)
    res_pl = batch_lib.execute_batch(idx, corpus.queries, backend="pallas")
    for a, b, c, d in zip(res_on, res_off, res_pl, seq):
        assert a.count == b.count == c.count == d.count
        assert np.array_equal(a.docs, d.docs)
        assert np.array_equal(b.docs, d.docs)
        assert np.array_equal(c.docs, d.docs)
    assert on["skip_folds"] > 0
    assert off["decoded_ints"] >= 5 * on["decoded_ints"]


def test_sequential_kernel_packed_path_matches():
    corpus = _skewed_corpus(seed=9)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="bp8-d1", B=0, n_parts=1)
    baseline = [engine.query(idx, q) for q in corpus.queries[:3]]
    engine.USE_KERNELS = True
    try:
        kerneled = [engine.query(idx, q) for q in corpus.queries[:3]]
    finally:
        engine.USE_KERNELS = False
    for a, b in zip(baseline, kerneled):
        assert a.count == b.count
        assert np.array_equal(a.docs, b.docs)


def test_batched_mixed_decoded_and_packed_folds():
    """Queries whose folds straddle the skip threshold exercise the
    decoded-scan → packed-scan → probe composition in one program."""
    table = {3: (100.0, [1.6, 40.0, 76000.0])}
    corpus = corpus_lib.synthesize(n_docs=1 << 17, n_queries=6, seed=13,
                                   table=table)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="bp8-d1", B=64, n_parts=2)
    res = batch_lib.execute_batch(idx, corpus.queries)
    for q, br in zip(corpus.queries, res):
        sr = engine.query(idx, q)
        assert sr.count == br.count
        assert np.array_equal(sr.docs, br.docs)
        expect = engine.brute_force(corpus.postings, q)
        assert sr.count == len(expect)


# --------------------------------------------------------------------------
# DecodeCache LRU + shared-vocab query logs
# --------------------------------------------------------------------------

def test_decode_cache_lru_eviction_order():
    cache = engine.DecodeCache(capacity_ints=1000)
    a, b, c = (jnp.zeros((400,), jnp.int32) for _ in range(3))
    cache.put("a", a, 1)
    cache.put("b", b, 1)
    assert cache.get("a") is not None       # a is now most-recent
    cache.put("c", c, 1)                    # evicts b (LRU), not a
    assert "a" in cache and "c" in cache
    assert "b" not in cache
    assert cache.hits == 1 and cache.misses == 0


def test_decode_cache_hit_counters():
    cache = engine.DecodeCache()
    v = jnp.zeros((64,), jnp.int32)
    assert cache.get("x") is None
    cache.put("x", v, 64)
    assert cache.get("x") is not None
    assert (cache.hits, cache.misses) == (1, 1)
    assert cache.hit_rate == 0.5
    assert "x" in cache                     # __contains__ leaves counters
    assert (cache.hits, cache.misses) == (1, 1)


def test_shared_vocab_reuses_terms():
    plain = corpus_lib.synthesize(n_docs=1 << 12, n_queries=30, seed=3)
    shared = corpus_lib.synthesize(n_docs=1 << 12, n_queries=30, seed=3,
                                   shared_vocab=True)
    assert len(plain.postings) == sum(len(q) for q in plain.queries)
    n_slots = sum(len(q) for q in shared.queries)
    assert len(shared.postings) < n_slots          # vocabulary is shared
    seen, reused = set(), 0
    for q in shared.queries:
        assert len(set(q)) == len(q)               # no dupes inside a query
        reused += sum(t in seen for t in q)
        seen.update(q)
    assert reused > 0


def test_shared_vocab_engine_correct_and_cache_hits():
    corpus = corpus_lib.synthesize(n_docs=1 << 13, n_queries=12, seed=5,
                                   shared_vocab=True)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="fastpfor-d1", B=16, n_parts=2)
    cache = engine.DecodeCache(capacity_ints=1 << 24)
    for q in corpus.queries:
        got = engine.query(idx, q, cache=cache)
        expect = engine.brute_force(corpus.postings, q)
        assert got.count == len(expect)
        assert np.array_equal(np.sort(got.docs), expect[: len(got.docs)])
    assert cache.hits > 0                   # reuse within one pass
