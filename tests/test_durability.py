"""Durability coverage for the mutable segmented index (DESIGN.md §2.15).

Layers:
  * WAL framing: append/read roundtrip, and every torn-tail corruption
    mode (short frame, bad magic, bad CRC, truncated payload) yields the
    good prefix — never a propagated bad record,
  * atomic snapshots: manifest-last commit, pruning keeps a bounded
    number of epochs, referenced segment files survive pruning,
  * the crash matrix: for EVERY registered crash point (WAL appends, the
    two snapshot steps, all six merge stages) an injected crash followed
    by ``MutableIndex.recover`` lands byte-identical to a
    rebuild-from-scratch oracle of exactly the acknowledged operations,
  * torn final records at every WAL append point: recovery truncates the
    partial frame and replays only whole records,
  * chained crashes (crash → recover → crash → recover) and damaged-
    manifest fallback to the previous epoch.
"""

import json
import os
import struct

import numpy as np
import pytest

from repro.index import builder, durability, engine, segments
from repro.launch import faults

pytestmark = [pytest.mark.segments, pytest.mark.faults]

V = 8                       # term universe
CODEC = "bp-d1"
B = 16

PROBES = [[t] for t in range(0, V, 2)] + [[0, 1], [2, 3], [1, 4, 5]]


def _base_model(n_docs=40, seed=3):
    """A small corpus as an explicit {doc: terms} model + postings."""
    rng = np.random.default_rng(seed)
    model = {d: set(map(int, rng.choice(V, size=2, replace=False)))
             for d in range(n_docs)}
    post = [np.asarray(sorted(d for d, ts in model.items() if t in ts),
                       dtype=np.int64) for t in range(V)]
    return model, post


def _boot(directory, injector=None, n_docs=40):
    model, post = _base_model(n_docs)
    log = durability.DurableLog(directory, injector=injector)
    mi = segments.MutableIndex.from_postings(
        post, n_docs, codec_name=CODEC, B=B, n_parts=2, wal=log)
    return mi, model


def _assert_matches_model(mi, model, *, backend="jax", fuse=True):
    """The recovered index answers exactly like a rebuild of the model."""
    idx = builder.build(
        [np.asarray(sorted(d for d, ts in model.items() if t in ts),
                    dtype=np.int64) for t in range(V)],
        max(mi.next_doc_id, 1), codec_name=CODEC, B=B, n_parts=2)
    got = mi.execute_batch([list(q) for q in PROBES], backend=backend,
                           fuse=fuse)
    for q, g in zip(PROBES, got):
        w = engine.query(idx, list(q))
        assert g.count == w.count, (q, g.count, w.count)
        assert np.array_equal(g.docs, w.docs), (q, g.docs, w.docs)


def _drive(mi, model, injector=None, n=24):
    """A scripted add/seal/delete/merge stream that touches every crash
    point at least once; the model records only *acknowledged* ops (an
    injected crash propagates before the model updates — exactly the
    contract recovery must honour)."""
    rng = np.random.default_rng(11)
    for i in range(n):
        terms = sorted(map(int, rng.choice(V, size=2, replace=False)))
        d = mi.add(terms)
        model[d] = set(terms)
        if i % 8 == 5:
            live = sorted(model)
            victim = live[i % len(live)]
            mi.delete(victim)
            del model[victim]
        if i % 7 == 6:
            mi.seal()
    hook = injector.merge_hook() if injector is not None else None
    mi.merge(hook=hook)


# --------------------------------------------------------------------------
# WAL framing
# --------------------------------------------------------------------------

def test_wal_append_read_roundtrip(tmp_path):
    log = durability.DurableLog(str(tmp_path))
    log.start_fresh()
    log._attach(0)                               # open epoch 0 sans manifest
    recs = [("add", {"terms": [1, 2]}), ("delete", {"doc": 7}),
            ("seal", {}), ("add", {"terms": [0]})]
    for rtype, payload in recs:
        log.append(rtype, payload)
    log.close()
    got, good, torn = durability.read_wal(log.wal_path(0))
    assert not torn
    assert good == os.path.getsize(log.wal_path(0))
    assert got == recs


@pytest.mark.parametrize("damage", ["short_header", "short_payload",
                                    "bad_magic", "bad_crc", "garbage"])
def test_wal_torn_tail_truncates_not_propagates(tmp_path, damage):
    log = durability.DurableLog(str(tmp_path))
    log.start_fresh()
    log._attach(0)
    recs = [("add", {"terms": [i]}) for i in range(5)]
    for rtype, payload in recs:
        log.append(rtype, payload)
    log.close()
    path = log.wal_path(0)
    clean = os.path.getsize(path)
    with open(path, "r+b") as f:
        if damage == "short_header":
            f.seek(0, os.SEEK_END)
            f.write(b"WA\x01")                   # header cut mid-field
        elif damage == "short_payload":
            frame = struct.pack("<2sBII", b"WA", 1, 100, 0)
            f.seek(0, os.SEEK_END)
            f.write(frame + b"{}")               # promises 100, delivers 2
        elif damage == "bad_magic":
            f.seek(0, os.SEEK_END)
            f.write(b"XX" + b"\x00" * 20)
        elif damage == "bad_crc":
            body = json.dumps({"terms": [9]}).encode()
            frame = struct.pack("<2sBII", b"WA", 1, len(body), 12345) + body
            f.seek(0, os.SEEK_END)
            f.write(frame)
        else:
            f.seek(0, os.SEEK_END)
            f.write(os.urandom(17))
    got, good, torn = durability.read_wal(path)
    assert torn and good == clean
    assert got == recs                           # the good prefix, exactly


def test_start_fresh_refuses_nonempty_directory(tmp_path):
    log = durability.DurableLog(str(tmp_path))
    log.start_fresh()
    log.checkpoint({"config": {}, "segments": [], "mseg_base": 0,
                    "mseg_n_docs": 0, "mseg_postings": {}, "dead_ids": [],
                    "next_doc_id": 0, "vocab": 0, "counters": {}})
    log.close()
    with pytest.raises(durability.WalError):
        durability.DurableLog(str(tmp_path)).start_fresh()


# --------------------------------------------------------------------------
# snapshots: pruning + recovery on clean shutdown
# --------------------------------------------------------------------------

def test_clean_recover_is_byte_identical(tmp_path):
    mi, model = _boot(str(tmp_path))
    _drive(mi, model)
    rec = segments.MutableIndex.recover(str(tmp_path))
    _assert_matches_model(rec, model)
    got = mi.execute_batch([list(q) for q in PROBES])
    rgt = rec.execute_batch([list(q) for q in PROBES])
    for g, r in zip(got, rgt):
        assert g.count == r.count and np.array_equal(g.docs, r.docs)
    c, rc = mi.counters(), rec.counters()
    assert rc["next_doc_id"] == c["next_doc_id"]
    assert rc["tombstones"] == c["tombstones"]
    assert rc["vocab"] == c["vocab"]


def test_recover_twice_is_idempotent(tmp_path):
    mi, model = _boot(str(tmp_path))
    _drive(mi, model, n=12)
    r1 = segments.MutableIndex.recover(str(tmp_path))
    r2 = segments.MutableIndex.recover(str(tmp_path))
    a = r1.execute_batch([list(q) for q in PROBES])
    b = r2.execute_batch([list(q) for q in PROBES])
    for g, r in zip(a, b):
        assert g.count == r.count and np.array_equal(g.docs, r.docs)
    assert r1.counters()["next_doc_id"] == r2.counters()["next_doc_id"]


def test_prune_keeps_bounded_epochs_and_referenced_segments(tmp_path):
    mi, model = _boot(str(tmp_path))
    for r in range(5):                          # 5 checkpoint-bearing seals
        d = mi.add([r % V])
        model[d] = {r % V}
        mi.seal()
    seqs = durability.manifest_seqs(str(tmp_path))
    assert len(seqs) == 2                       # keep=2 epochs survive
    man = durability._load_manifest(str(tmp_path), max(seqs))
    for entry in man["segments"]:               # every referenced file exists
        assert os.path.exists(os.path.join(str(tmp_path), "segments",
                                           entry["file"]))
    assert not [f for f in os.listdir(str(tmp_path)) if f.endswith(".tmp")]
    _assert_matches_model(segments.MutableIndex.recover(str(tmp_path)),
                          model)


# --------------------------------------------------------------------------
# the crash matrix: every registered point, crash → recover → differential
# --------------------------------------------------------------------------

@pytest.mark.parametrize("point", faults.CRASH_POINTS)
def test_crash_recover_differential(tmp_path, point):
    inj = faults.FaultInjector(seed=1)
    mi, model = _boot(str(tmp_path), injector=inj)
    inj.arm("crash", point, 1)                  # counted from arm time:
    with pytest.raises(faults.InjectedCrash):   # next hit is the crash
        _drive(mi, model, injector=inj)
    assert inj.fired
    inj.disarm_all()
    rec = segments.MutableIndex.recover(str(tmp_path))
    _assert_matches_model(rec, model)


@pytest.mark.parametrize("point", faults.TEAR_POINTS)
def test_torn_record_recover_differential(tmp_path, point):
    """A torn final record (partial frame on disk) must be truncated by
    recovery, and the acknowledged prefix must replay exactly."""
    inj = faults.FaultInjector(seed=2)
    mi, model = _boot(str(tmp_path), injector=inj)
    inj.arm("torn", point, 1)
    with pytest.raises(faults.InjectedCrash):
        _drive(mi, model, injector=inj)
    inj.disarm_all()
    # the torn bytes really are on disk before recovery truncates them
    wal = max(f for f in os.listdir(str(tmp_path)) if f.startswith("wal-"))
    _, good, torn = durability.read_wal(os.path.join(str(tmp_path), wal))
    assert torn
    rec = segments.MutableIndex.recover(str(tmp_path))
    _assert_matches_model(rec, model)


@pytest.mark.parametrize("backend,fuse", [("jax", False), ("pallas", True),
                                          ("pallas", False)])
def test_crash_recover_differential_backends(tmp_path, backend, fuse):
    """The recovered state answers identically across the backend ×
    fusion matrix (the primary jax-fused cell runs per-point above)."""
    inj = faults.FaultInjector(seed=3)
    mi, model = _boot(str(tmp_path), injector=inj)
    inj.arm("crash", "wal.append.add", 3)
    with pytest.raises(faults.InjectedCrash):
        _drive(mi, model, injector=inj)
    inj.disarm_all()
    rec = segments.MutableIndex.recover(str(tmp_path))
    _assert_matches_model(rec, model, backend=backend, fuse=fuse)


def test_crash_recover_crash_chain(tmp_path):
    """Two process deaths with recovery between them: the second recovery
    must still land on exactly the acknowledged state."""
    inj = faults.FaultInjector(seed=4)
    mi, model = _boot(str(tmp_path), injector=inj)
    inj.arm("crash", "wal.append.add", 4)
    with pytest.raises(faults.InjectedCrash):
        _drive(mi, model, injector=inj)
    inj.disarm_all()
    mi = segments.MutableIndex.recover(str(tmp_path), injector=inj)
    inj.arm("crash", "snapshot.rename", 1)
    with pytest.raises(faults.InjectedCrash):
        _drive(mi, model, injector=inj)
    inj.disarm_all()
    rec = segments.MutableIndex.recover(str(tmp_path))
    _assert_matches_model(rec, model)
    assert rec._wal_replayed >= 0


def test_damaged_manifest_falls_back_to_previous_epoch(tmp_path):
    """Garbage in the newest manifest (a crash the rename should prevent,
    or disk rot) must not strand the directory: recovery falls back to
    the previous epoch and replays forward through the chained WALs."""
    mi, model = _boot(str(tmp_path))
    _drive(mi, model, n=16)
    seqs = durability.manifest_seqs(str(tmp_path))
    assert len(seqs) >= 2
    newest = os.path.join(str(tmp_path), f"manifest-{max(seqs)}.json")
    with open(newest, "w") as f:
        f.write("{ not json")
    rec = segments.MutableIndex.recover(str(tmp_path))
    _assert_matches_model(rec, model)


def test_recovered_index_keeps_serving_and_checkpointing(tmp_path):
    """Recovery is not a terminal state: the recovered index accepts new
    mutations, seals, merges, and survives another recovery."""
    inj = faults.FaultInjector(seed=5)
    mi, model = _boot(str(tmp_path), injector=inj)
    inj.arm("crash", "merge.swap", 1)
    with pytest.raises(faults.InjectedCrash):
        _drive(mi, model, injector=inj)
    inj.disarm_all()
    mi = segments.MutableIndex.recover(str(tmp_path))
    _drive(mi, model, n=10)                     # keep mutating post-recovery
    _assert_matches_model(mi, model)
    _assert_matches_model(segments.MutableIndex.recover(str(tmp_path)),
                          model)
