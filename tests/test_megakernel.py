"""Fused decode+intersect megakernel differential suite (ISSUE 7).

The megakernels (``kernels/megakernel.py``) fold a whole (J, B) stack of
decoded or packed lists into the per-row validity mask in ONE Pallas
launch, decoding candidate blocks inside the kernel.  Every test here is
differential against the staged reference — per-fold
``core/intersect.intersect_packed_batch`` (or gallop) masks ANDed exactly
as ``batch._mask_fold_scan`` does — plus the scalar ``intersect_ref``
oracle where the payload permits.  Coverage: delta modes d1–dv, FastPFOR
exception patching, sentinel padding, incoming-valid masking, inactive
fold slots, empty/single-block edges, and fused-family ceiling shapes
(k/t/c pads and B/Jp arities raised far past the payload).  Interpret
mode everywhere; the same parametrized bodies also run compiled when a
TPU backend is present (``_COMPILED``).

Also pinned here: the kernel-mode probe/override resolution of
``kernels.ops`` and the interpret-mode occupancy guard crossover of
``batch._effective_backend`` (the PR-5 fused-ceiling regression fix).
"""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import bitpack, fastpfor
from repro.core import intersect as its
from repro.index import batch as batch_lib
from repro.index import builder, corpus as corpus_lib, engine, source
from repro.kernels import ops as kernel_ops
from repro.kernels import megakernel

pytestmark = pytest.mark.megakernel

MODES = ["d1", "d2", "d4", "dm", "dv"]
_COMPILED = jax.default_backend() == "tpu"


def _pair(rng, m, n, overlap=0.3, universe=2**22):
    inter = np.sort(rng.choice(universe, size=max(int(m * overlap), 1),
                               replace=False))
    r = np.union1d(inter, rng.choice(universe, size=m, replace=False))
    f = np.union1d(inter, rng.choice(universe, size=n, replace=False))
    return r.astype(np.int64), f.astype(np.int64)


def _stack_payloads(grid, r_rows, *, M=256, k_pad=None, t_pad=None,
                    c_pad=None, e_pad=None, bp=None):
    """Stack a (Jp, B) grid of optional payloads into the megakernel
    operand tuple, mirroring ``batch._stack_packed``: shared pow2 pads
    (overridable to model fused-family ceilings), pad candidate ids =
    k_pad, -1-padded exception slots, inactive grid cells all-pad.
    Returns (R, pk, active) with R (Bp, M) sentinel-padded."""
    Jp, B = len(grid), len(grid[0])
    real = [p for row in grid for p in row if p is not None]
    k_pad = k_pad or its.pow2_bucket(
        max(p.widths.shape[0] for p in real), floor=1)
    t_pad = t_pad or its.pow2_bucket(
        max(int(p.flat_words.shape[0]) for p in real), floor=1)
    E = max(int(getattr(p, "exc_pos", np.zeros(0)).shape[0]) for p in real)
    if e_pad is None:
        e_pad = its.pow2_bucket(E, floor=1) if E else 0
    cands = [bitpack.candidate_block_ids(np.asarray(p.maxes), r_rows[b])
             for j, row in enumerate(grid)
             for b, p in enumerate(row) if p is not None]
    c_pad = c_pad or its.pow2_bucket(
        max(len(c) for c in cands), floor=source.CAND_FLOOR)
    Bp = bp or B
    PW = np.zeros((Jp, Bp, t_pad, 128), np.uint32)
    PWid = np.zeros((Jp, Bp, k_pad), np.int32)
    POf = np.zeros((Jp, Bp, k_pad), np.int32)
    PMx = np.zeros((Jp, Bp, k_pad), np.uint32)
    PBk = np.full((Jp, Bp, c_pad), k_pad, np.int32)
    PEp = np.full((Jp, Bp, max(e_pad, 1)), -1, np.int32)
    PEa = np.zeros((Jp, Bp, max(e_pad, 1)), np.uint32)
    active = np.zeros((Jp, Bp), bool)
    for j, row in enumerate(grid):
        for b, p in enumerate(row):
            if p is None:
                continue
            lay = bitpack.layout_np(p, k_pad, t_pad, e_pad)
            T, K = lay.words.shape[0], lay.widths.shape[0]
            PW[j, b, :T] = lay.words
            PWid[j, b, :K] = lay.widths
            POf[j, b, :K] = lay.offsets
            PMx[j, b, :K] = lay.maxes
            blk = bitpack.candidate_block_ids(np.asarray(p.maxes),
                                              r_rows[b])
            PBk[j, b] = source.pad_block_ids(blk, c_pad, k_pad)
            if e_pad:
                ne = lay.exc_pos.shape[0]
                PEp[j, b, :ne] = lay.exc_pos
                PEa[j, b, :ne] = lay.exc_add
            active[j, b] = True
    Rnp = np.full((Bp, M), its.SENTINEL, np.int32)
    for b, r in enumerate(r_rows):
        Rnp[b, : len(r)] = r
    pk = (jnp.asarray(PW), jnp.asarray(PWid), jnp.asarray(POf),
          jnp.asarray(PMx), jnp.asarray(PBk),
          jnp.asarray(PEp if e_pad else PEp[:, :, :0]),
          jnp.asarray(PEa if e_pad else PEa[:, :, :0]))
    return jnp.asarray(Rnp), pk, jnp.asarray(active)


def _staged_packed_fold(R, valid, pk, active, mode, block_rows):
    """Reference: per-fold core intersect_packed_batch masks ANDed as
    ``batch._mask_fold_scan`` does — the staged packed path."""
    out = valid
    for j in range(pk[0].shape[0]):
        hit = its.intersect_packed_batch(R, *(op[j] for op in pk),
                                         mode=mode, block_rows=block_rows)
        out = out & jnp.where(active[j][:, None], hit, True)
    return out


# --------------------------------------------------------------------------
# packed megakernel vs staged core path
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
def test_packed_fold_matches_staged_all_modes(mode, rng):
    B, Jp = 3, 2
    r_rows, grid, expects = [], [[None] * B for _ in range(Jp)], []
    for b in range(B):
        r, f0 = _pair(rng, 150, 90000)
        _, f1 = _pair(rng, 150, 60000)
        r = r[:200]
        grid[0][b] = bitpack.encode(f0, mode=mode)
        grid[1][b] = bitpack.encode(f1, mode=mode)
        r_rows.append(r)
        expects.append(np.intersect1d(np.intersect1d(r, f0), f1))
    R, pk, active = _stack_payloads(grid, r_rows)
    valid = R != its.SENTINEL
    rows = grid[0][0].block_rows
    got = kernel_ops.intersect_packed_fold(R, valid, pk, active,
                                           mode=mode, block_rows=rows)
    ref = _staged_packed_fold(R, valid, pk, active, mode, rows)
    assert np.array_equal(np.asarray(got), np.asarray(ref))
    vals, cnt = its.compact_batch(R, got)
    for b in range(B):
        assert np.array_equal(np.asarray(vals)[b, : int(cnt[b])],
                              expects[b])


def test_packed_fold_fastpfor_exceptions(rng):
    """Exception-carrying FastPFOR blocks patch correctly inside the
    megakernel's scratch decode."""
    B = 2
    r_rows, grid = [], [[None] * B]
    for b in range(B):
        r, f = _pair(rng, 150, 150000, universe=2**26)
        pf = fastpfor.encode(f, mode="d1")
        assert int(pf.exc_pos.shape[0]) > 0
        grid[0][b] = pf
        r_rows.append(r[:200])
    R, pk, active = _stack_payloads(grid, r_rows)
    valid = R != its.SENTINEL
    rows = grid[0][0].block_rows
    got = kernel_ops.intersect_packed_fold(R, valid, pk, active,
                                           mode="d1", block_rows=rows)
    ref = _staged_packed_fold(R, valid, pk, active, "d1", rows)
    assert np.array_equal(np.asarray(got), np.asarray(ref))


def test_packed_fold_sentinel_padding_and_incoming_valid(rng):
    """Sentinel-padded seed slots never match; rows the incoming validity
    mask already killed stay dead (the megakernel ANDs, never revives)."""
    r, f = _pair(rng, 80, 60000)
    pf = bitpack.encode(f, mode="d1")
    R, pk, active = _stack_payloads([[pf]], [r], M=1024)  # heavy tail
    valid = (R != its.SENTINEL) & (R % 2 == 0)            # pre-killed odds
    got = np.asarray(kernel_ops.intersect_packed_fold(
        R, valid, pk, active, mode="d1", block_rows=pf.block_rows))
    assert not got[0, len(r):].any()
    assert not got[0][np.asarray(R)[0] % 2 == 1].any()
    ref = _staged_packed_fold(R, valid, pk, active, "d1", pf.block_rows)
    assert np.array_equal(got, np.asarray(ref))


def test_packed_fold_inactive_and_empty_edges(rng):
    """Inactive (j, b) slots are mask identities; a single-block list and
    an empty intersection both round-trip; an all-pad Jp slot (fused
    arity ceiling above the row's real fold count) changes nothing."""
    r, f = _pair(rng, 60, 30000)
    pf = bitpack.encode(f, mode="d1")
    evens = 2 * np.sort(rng.choice(2**20, size=3000, replace=False))
    podd = bitpack.encode(evens.astype(np.int64), mode="d1")
    tiny = np.sort(rng.choice(2**12, size=500, replace=False))
    ptiny = bitpack.encode(tiny.astype(np.int64), mode="d1")  # 1 block
    assert ptiny.num_blocks == 1
    rows = [r, evens[:64] + 1, np.asarray(tiny[:64])]
    grid = [[pf, podd, ptiny],
            [None, None, None]]                    # all-pad second slot
    R, pk, active = _stack_payloads(grid, rows)
    assert not np.asarray(active)[1].any()
    valid = R != its.SENTINEL
    brows = pf.block_rows
    got = kernel_ops.intersect_packed_fold(R, valid, pk, active,
                                           mode="d1", block_rows=brows)
    ref = _staged_packed_fold(R, valid, pk, active, "d1", brows)
    assert np.array_equal(np.asarray(got), np.asarray(ref))
    vals, cnt = its.compact_batch(R, got)
    assert np.array_equal(np.asarray(vals)[0, : int(cnt[0])],
                          np.intersect1d(r, f))
    assert int(cnt[1]) == 0                        # disjoint: empty result
    assert np.array_equal(np.asarray(vals)[2, : int(cnt[2])], tiny[:64])


def test_packed_fold_family_ceiling_shapes(rng):
    """Fused-family ceilings: k/t/c pads and B/Jp arities raised far past
    the payload must be byte-identical to the tight-pad stack — pad
    blocks decode to SENTINEL, pad rows stay sentinel, inactive slots are
    identities (the DESIGN.md §2.12 static-geometry contract)."""
    r, f = _pair(rng, 100, 50000)
    pf = bitpack.encode(f, mode="dm")
    rows = pf.block_rows
    R1, pk1, a1 = _stack_payloads([[pf]], [r])
    tight = kernel_ops.intersect_packed_fold(
        R1, R1 != its.SENTINEL, pk1, a1, mode="dm", block_rows=rows)
    k_pad = 4 * its.pow2_bucket(pf.widths.shape[0], floor=1)
    t_pad = 2 * its.pow2_bucket(int(pf.flat_words.shape[0]), floor=1)
    grid = [[pf, None, None, None], [None] * 4, [None] * 4, [None] * 4]
    R4, pk4, a4 = _stack_payloads(
        grid, [r], M=256, k_pad=k_pad, t_pad=t_pad, c_pad=256, e_pad=8,
        bp=4)
    assert pk4[0].shape[:2] == (4, 4)
    got = kernel_ops.intersect_packed_fold(
        R4, R4 != its.SENTINEL, pk4, a4, mode="dm", block_rows=rows)
    assert np.array_equal(np.asarray(got)[0], np.asarray(tight)[0])
    assert not np.asarray(got)[1:].any() or np.array_equal(
        np.asarray(got)[1:], np.asarray(R4[1:] != its.SENTINEL))


# --------------------------------------------------------------------------
# decoded-fold megakernel
# --------------------------------------------------------------------------

def test_decoded_fold_matches_scan(rng):
    B, M, N, J = 4, 256, 1024, 3
    r = np.sort(rng.choice(1 << 20, (B, M), replace=False),
                axis=1).astype(np.int32)
    folds = np.sort(rng.choice(1 << 20, (J, B, N)), axis=-1).astype(np.int32)
    folds[0, :, :50] = r[:, 10:60]
    folds = np.sort(folds, axis=-1)
    act = rng.random((J, B)) < 0.7
    act[0, 0] = act[1, 0] = True
    valid = r % 3 != 0
    got = kernel_ops.intersect_fold_batch(
        jnp.asarray(r), jnp.asarray(valid), jnp.asarray(folds),
        jnp.asarray(act))
    ref = jnp.asarray(valid)
    for j in range(J):
        hit = its.intersect_gallop_batch(jnp.asarray(r),
                                         jnp.asarray(folds[j]))
        ref = ref & jnp.where(jnp.asarray(act[j])[:, None], hit, True)
    assert np.array_equal(np.asarray(got), np.asarray(ref))


def test_decoded_fold_empty_stack_is_identity(rng):
    r = jnp.asarray(np.sort(rng.choice(1 << 16, (2, 128),
                                       replace=False), axis=1))
    valid = r % 2 == 0
    got = kernel_ops.intersect_fold_batch(
        r, valid, jnp.zeros((0, 2, 128), jnp.int32),
        jnp.zeros((0, 2), bool))
    assert np.array_equal(np.asarray(got), np.asarray(valid))


@pytest.mark.skipif(not _COMPILED, reason="no TPU backend: compiled-mode "
                    "Mosaic lowering unavailable (interpret covered above)")
def test_compiled_mode_matches_interpret(rng):
    r, f = _pair(rng, 100, 60000)
    pf = bitpack.encode(f, mode="d1")
    R, pk, active = _stack_payloads([[pf]], [r])
    valid = R != its.SENTINEL
    out = {}
    for interp in (True, False):
        out[interp] = np.asarray(megakernel.packed_fold_batched(
            R, valid, *pk, active, mode="d1", block_rows=pf.block_rows,
            interpret=interp))
    assert np.array_equal(out[True], out[False])


# --------------------------------------------------------------------------
# engine-level differential: megakernel path == sequential engine
# --------------------------------------------------------------------------

@pytest.mark.parametrize("fuse", [True, False])
def test_engine_pallas_megakernel_matches_sequential(fuse):
    """The full pallas program (decoded megakernel + packed megakernel +
    bitmap probes) must stay byte-identical to the sequential engine on a
    skewed corpus that exercises the skip/packed path, fused and unfused."""
    table = {2: (100.0, [1.6, 76000.0])}
    corpus = corpus_lib.synthesize(n_docs=1 << 17, n_queries=6, seed=7,
                                   table=table)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="bp8-d1", B=0, n_parts=1)
    seq = [engine.query(idx, q) for q in corpus.queries]
    got = batch_lib.execute_batch(idx, corpus.queries, backend="pallas",
                                  fuse=fuse)
    for a, b in zip(got, seq):
        assert a.count == b.count and np.array_equal(a.docs, b.docs)


# --------------------------------------------------------------------------
# occupancy guard (PR-5 fused-ceiling interpret regression fix)
# --------------------------------------------------------------------------

def _fake_items(n, folds_each, psrc_each):
    return [batch_lib._Item(qi=i, pi=0, doc_lo=0, r=None, rsrc=None,
                            folds=[object()] * folds_each,
                            psrc=[object()] * psrc_each)
            for i in range(n)]


def test_occupancy_guard_crossover():
    """Pins the guard's crossover: a fully occupied unfused chunk stays on
    pallas; a sparse chunk under a fused family ceiling (the PR-5
    regression shape) demotes to jax in interpret mode — and only in
    interpret mode."""
    dense_key = batch_lib.GroupKey("svs", 256, 512, 0, "gallop")
    dense = _fake_items(4, folds_each=2, psrc_each=0)
    # Bp = _bucket_rows(4) = 4; slots 4·(1+2) = 12; real 4 + 8 = 12
    assert batch_lib.pallas_occupancy(dense_key, dense) == 1.0
    ceil_key = batch_lib.GroupKey(
        "svs", 256, 512, 0, "gallop",
        packed=(8, 64, 8, 0, 32, "d1"), fused=(4, 0, 4))
    sparse = _fake_items(2, folds_each=1, psrc_each=1)
    occ = batch_lib.pallas_occupancy(ceil_key, sparse)
    # Bp(2)=2 → slots 2·(1+4+4)=18, real 2+2+2=6
    assert occ == pytest.approx(6 / 18)
    assert occ < batch_lib.PALLAS_MIN_OCCUPANCY
    prev = kernel_ops.INTERPRET
    try:
        kernel_ops.INTERPRET = True
        stats: dict = {}
        assert batch_lib._effective_backend(dense_key, dense, "pallas",
                                            stats) == "pallas"
        assert batch_lib._effective_backend(ceil_key, sparse, "pallas",
                                            stats) == "jax"
        assert stats["pallas_lowocc_fallbacks"] == 1
        # jax chunks pass through untouched
        assert batch_lib._effective_backend(ceil_key, sparse, "jax",
                                            stats) == "jax"
        # compiled mode never demotes: dead TPU grid steps are cheap
        kernel_ops.INTERPRET = False
        assert batch_lib._effective_backend(ceil_key, sparse, "pallas",
                                            stats) == "pallas"
        assert stats["pallas_lowocc_fallbacks"] == 1
    finally:
        kernel_ops.INTERPRET = prev


def test_occupancy_fallback_results_identical():
    """A batch whose chunks straddle the guard threshold returns results
    byte-identical to the jax backend — the guard only reroutes engines."""
    corpus = corpus_lib.synthesize(n_docs=1 << 14, n_queries=8, seed=3)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="fastpfor-d1", B=16, n_parts=2)
    stats: dict = {}
    got = batch_lib.execute_batch(idx, corpus.queries, backend="pallas",
                                  stats=stats)
    ref = batch_lib.execute_batch(idx, corpus.queries, backend="jax")
    for a, b in zip(got, ref):
        assert a.count == b.count and np.array_equal(a.docs, b.docs)


# --------------------------------------------------------------------------
# kernel-mode probe / override resolution
# --------------------------------------------------------------------------

def test_kernel_mode_resolution():
    prev_env = os.environ.get("REPRO_PALLAS_INTERPRET")
    prev = kernel_ops.INTERPRET
    try:
        os.environ.pop("REPRO_PALLAS_INTERPRET", None)
        probed = kernel_ops.probe_kernel_mode()
        assert probed == ("compiled" if _COMPILED else "interpret")
        assert kernel_ops.resolve_kernel_mode("auto") == probed
        os.environ["REPRO_PALLAS_INTERPRET"] = "0"
        assert kernel_ops.resolve_kernel_mode("auto") == "compiled"
        os.environ["REPRO_PALLAS_INTERPRET"] = "1"
        assert kernel_ops.resolve_kernel_mode("auto") == "interpret"
        # explicit modes win over the env either way
        assert kernel_ops.resolve_kernel_mode("compiled") == "compiled"
        assert kernel_ops.resolve_kernel_mode("interpret") == "interpret"
        with pytest.raises(ValueError):
            kernel_ops.resolve_kernel_mode("fast")
        assert kernel_ops.set_kernel_mode("interpret") == "interpret"
        assert kernel_ops.INTERPRET and \
            kernel_ops.kernel_mode() == "interpret"
        assert kernel_ops.set_kernel_mode("compiled") == "compiled"
        assert not kernel_ops.INTERPRET
    finally:
        if prev_env is None:
            os.environ.pop("REPRO_PALLAS_INTERPRET", None)
        else:
            os.environ["REPRO_PALLAS_INTERPRET"] = prev_env
        kernel_ops.INTERPRET = prev
