"""Fault-injection seam + server-resilience coverage (DESIGN.md §2.15).

Layers:
  * the injector itself: spec-string parsing, the point registry (crash
    and torn refused at server seams, torn refused off the WAL), counted
    rules counting from arm time, seeded-probability determinism, the
    merge-hook adapter,
  * the degradation ladder state machine: threshold-gated step-downs, the
    cooldown gate, one promotion per quiet period,
  * server end-to-end: transient faults retry to success with ZERO lost
    requests and byte-identical answers; persistent errors resolve every
    admitted request (never hang the loop); collect-seam faults resolve
    as errors; after the breaker degrades and re-promotes, steady-state
    serving compiles nothing.
"""

import asyncio

import numpy as np
import pytest

from repro.index import batch as batch_lib
from repro.index import builder, corpus as corpus_lib, engine
from repro.launch import faults
from repro.launch import server as server_lib

pytestmark = [pytest.mark.server, pytest.mark.faults]


@pytest.fixture(scope="module")
def uniform():
    corpus = corpus_lib.synthesize(n_docs=1 << 14, n_queries=12, seed=33)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="fastpfor-d1", B=16, n_parts=2)
    seq = [engine.query(idx, q) for q in corpus.queries]
    return idx, corpus.queries, seq


def _assert_identical(results, seq):
    assert len(results) == len(seq)
    for got, want in zip(results, seq):
        assert got.count == want.count
        assert np.array_equal(got.docs, want.docs)


# --------------------------------------------------------------------------
# the injector
# --------------------------------------------------------------------------

def test_spec_parsing_arms_rules():
    inj = faults.FaultInjector(
        "crash@wal.append.add:3, transient@launch:0.5,delay@collect:2")
    assert inj.armed == 3
    assert inj.counts() == {}


@pytest.mark.parametrize("spec", [
    "explode@launch",                   # unknown kind
    "crash@nowhere",                    # unknown point
    "crash@launch",                     # crash at a server seam
    "torn@snapshot.write",              # torn off the WAL
    "crash-wal.append.add",             # malformed clause
])
def test_bad_specs_rejected(spec):
    with pytest.raises(ValueError):
        faults.FaultInjector(spec)


def test_counted_rule_counts_from_arm_time():
    inj = faults.FaultInjector()
    inj.fire("wal.append.add")          # pre-arm traffic must not count
    inj.arm("crash", "wal.append.add", 3)
    inj.fire("wal.append.add")
    inj.fire("wal.append.add")
    with pytest.raises(faults.InjectedCrash):
        inj.fire("wal.append.add")
    assert inj.armed == 0               # one-shot: consumed on firing
    inj.fire("wal.append.add")          # and quiet afterwards
    assert inj.counts() == {"crash@wal.append.add": 1}
    assert inj.hits["wal.append.add"] == 5


def test_transient_first_n_hits_then_clean():
    inj = faults.FaultInjector("transient@launch:2")
    for _ in range(2):
        with pytest.raises(faults.TransientFault):
            inj.fire("launch")
    inj.fire("launch")                  # exhausted
    assert inj.counts() == {"transient@launch": 2}


def test_probability_rule_is_seed_deterministic():
    def run(seed):
        inj = faults.FaultInjector("transient@launch:0.3", seed=seed)
        out = []
        for _ in range(64):
            try:
                inj.fire("launch")
                out.append(0)
            except faults.TransientFault:
                out.append(1)
        return out
    a, b = run(7), run(7)
    assert a == b and 0 < sum(a) < 64   # same schedule, actually mixed
    assert run(8) != a                  # a different seed reschedules


def test_merge_hook_adapter_chains_inner():
    inj = faults.FaultInjector()
    inj.arm("crash", "merge.build", 1)
    seen = []
    hook = inj.merge_hook(inner=seen.append)
    hook("snapshot")
    hook("decode")
    with pytest.raises(faults.InjectedCrash):
        hook("build")
    assert seen == ["snapshot", "decode", "build"]   # inner always runs


# --------------------------------------------------------------------------
# the degradation ladder
# --------------------------------------------------------------------------

def test_degradation_ladder_state_machine():
    t = [0.0]
    lad = server_lib.DegradationLadder("pallas", True, threshold=2,
                                       cooldown_s=1.0, clock=lambda: t[0])
    assert lad.levels == [("pallas", True), ("pallas", False),
                          ("jax", False)]
    lad.on_failure()
    assert lad.level == 0               # below threshold: hold the rung
    lad.on_failure()
    assert lad.level == 1 and lad.n_degradations == 1
    lad.on_failure()
    lad.on_failure()
    assert lad.level == 2
    lad.on_failure()
    lad.on_failure()
    assert lad.level == 2               # already at the bottom rung
    lad.on_success()
    assert lad.level == 2               # cooldown not yet quiet
    t[0] += 1.5
    lad.on_success()
    assert lad.level == 1 and lad.n_promotions == 1
    lad.on_success()
    assert lad.level == 1               # one promotion per cooldown
    t[0] += 1.5
    lad.on_success()
    assert lad.level == 0 and lad.current == ("pallas", True)


def test_ladder_failure_rearms_cooldown():
    t = [0.0]
    lad = server_lib.DegradationLadder("jax", True, threshold=1,
                                       cooldown_s=1.0, clock=lambda: t[0])
    lad.on_failure()
    assert lad.level == 1
    t[0] += 0.9
    lad.on_failure()                    # at the bottom, but quiet restarts
    t[0] += 0.9                         # 1.8 since degrade, 0.9 since fail
    lad.on_success()
    assert lad.level == 1               # the new cooldown is not over
    t[0] += 0.2
    lad.on_success()
    assert lad.level == 0


# --------------------------------------------------------------------------
# server end-to-end resilience
# --------------------------------------------------------------------------

def test_server_transient_faults_retry_zero_lost(uniform):
    idx, queries, seq = uniform
    inj = faults.FaultInjector("transient@launch:3", seed=0)
    srv = server_lib.ContinuousBatchingServer(
        idx, max_batch=4, max_queue=1024, injector=inj, max_retries=6,
        retry_backoff_ms=0.1)
    results = asyncio.run(srv.run(queries, [0.0] * len(queries)))
    m = srv.metrics
    assert m.n_faults == 3 and m.n_retries == 3
    assert m.n_errors == 0 and m.n_shed == 0
    outs = srv.outcomes()
    assert outs == ["done"] * len(queries)       # zero lost requests
    _assert_identical(results, seq)              # and byte-identical


def test_server_retry_exhaustion_resolves_as_errors(uniform):
    idx, queries, _ = uniform
    inj = faults.FaultInjector("transient@launch:1000000", seed=0)
    srv = server_lib.ContinuousBatchingServer(
        idx, max_batch=4, max_queue=1024, injector=inj, max_retries=2,
        retry_backoff_ms=0.1)
    results = asyncio.run(srv.run(queries, [0.0] * len(queries)))
    assert all(r is None for r in results)
    outs = srv.outcomes()
    assert set(outs) == {"error"} and len(outs) == len(queries)
    assert srv.metrics.n_errors == len(queries)
    assert srv.metrics.n_retries > 0


def test_server_persistent_error_never_hangs(uniform):
    """A non-retryable fault resolves the whole flush as errors — the
    batcher survives and later flushes still run."""
    idx, queries, _ = uniform
    inj = faults.FaultInjector("error@launch:1000000", seed=0)
    srv = server_lib.ContinuousBatchingServer(
        idx, max_batch=4, max_queue=1024, injector=inj)
    results = asyncio.run(srv.run(queries, [0.0] * len(queries)))
    assert all(r is None for r in results)
    assert srv.outcomes() == ["error"] * len(queries)
    assert srv.metrics.n_flushes >= 2            # the loop kept flushing


def test_server_collect_seam_fault_resolves_as_errors(uniform):
    idx, queries, _ = uniform
    inj = faults.FaultInjector("error@collect:1", seed=0)
    srv = server_lib.ContinuousBatchingServer(
        idx, max_batch=4, max_queue=1024, injector=inj)
    results = asyncio.run(srv.run(queries, [0.0] * len(queries)))
    outs = srv.outcomes()
    assert "pending" not in outs
    assert outs.count("error") == 4              # exactly one failed flush
    assert outs.count("done") == len(queries) - 4
    done = [(q, r, s) for q, r, s in zip(queries, results,
                                         srv.outcomes()) if s == "done"]
    for q, r, _ in done:
        assert r is not None


def test_server_degrades_and_repromotes_to_zero_compiles(uniform):
    """The breaker walks down the ladder under a fault burst, promotes
    back after the cooldown, and — the acceptance bar — steady-state
    serving after re-promotion compiles nothing."""
    idx, queries, seq = uniform
    inj = faults.FaultInjector("transient@launch:4", seed=0)
    stats: dict = {}
    srv = server_lib.ContinuousBatchingServer(
        idx, max_batch=4, max_queue=1024, injector=inj, max_retries=8,
        retry_backoff_ms=0.1, breaker_threshold=2, cooldown_ms=0.0,
        stats=stats)
    server_lib.warm_server(srv, queries)
    results = asyncio.run(srv.run(queries, [0.0] * len(queries)))
    m = srv.metrics
    assert m.n_faults == 4 and m.n_retries == 4
    assert srv.ladder.n_degradations >= 1        # the burst walked it down
    assert srv.ladder.n_promotions >= 1
    assert srv.ladder.level == 0                 # and it walked back up
    assert m.degraded_flushes >= 1
    assert srv.outcomes() == ["done"] * len(queries)
    _assert_identical(results, seq)              # degraded answers identical
    # steady state after re-promotion: the same stream compiles nothing
    if getattr(batch_lib._svs_program, "_cache_size", None) is None:
        pytest.skip("this jax does not expose jit _cache_size — compile "
                    "accounting unavailable (would pass vacuously)")
    stats.pop("n_compiles", None)
    results2 = asyncio.run(srv.run(queries, [0.0] * len(queries)))
    assert stats.get("n_compiles", 0) == 0
    assert srv.outcomes() == ["done"] * len(queries)
    _assert_identical(results2, seq)


def test_server_timeout_outcomes_counted(uniform):
    """Per-request deadlines: an expired request resolves as ``timeout``
    with its ``done`` event set — never served, never hung."""
    idx, queries, _ = uniform
    srv = server_lib.ContinuousBatchingServer(
        idx, max_batch=4, max_queue=1024, timeout_ms=1e-4)
    results = asyncio.run(srv.run(queries, [0.0] * len(queries)))
    assert all(r is None for r in results)
    assert srv.outcomes() == ["timeout"] * len(queries)
    assert srv.metrics.n_timeout == len(queries)
    s = srv.metrics.summary()
    assert s["n_timeout"] == len(queries) and s["n_done"] == 0
