"""Unit tests for the mutable segmented index (DESIGN.md §2.14): segment
lifecycle, tombstone filtering, generation-tagged residency, merge fault
injection, and serving-during-background-merge.  The generative
op-sequence coverage lives in ``test_segments_prop.py``; these tests pin
the individual mechanisms that harness exercises in aggregate."""

import threading

import numpy as np
import pytest

from repro.index import batch as batch_lib
from repro.index import builder, engine, segments

pytestmark = pytest.mark.segments

V = 8
CODEC = "bp-d1"
B = 16


def _seed_corpus(n_docs=400, seed=3):
    """A mixed-density corpus: terms 0..3 are dense (sealed as bitmaps),
    terms 4..7 sparse (sealed as packed lists under B=16)."""
    rng = np.random.default_rng(seed)
    post = []
    for t in range(V):
        p = 0.5 / (1 + t) if t < 4 else 0.015
        keep = rng.random(n_docs) < p
        post.append(np.flatnonzero(keep).astype(np.int64))
    return post


def _model_from(postings):
    model = {}
    for t, docs in enumerate(postings):
        for d in docs.tolist():
            model.setdefault(int(d), set()).add(t)
    return model


def _oracle(model, n_docs):
    post = [np.asarray(sorted(d for d, ts in model.items() if t in ts),
                       dtype=np.int64) for t in range(V)]
    return builder.build(post, max(n_docs, 1), codec_name=CODEC, B=B,
                         n_parts=2)


QUERIES = [[t] for t in range(V)] + [[0, 1], [2, 5], [1, 3, 6], [0, 4, 7]]


def _assert_identical(mi, model, *, backend="jax", fuse=True, stats=None):
    got = mi.execute_batch([list(q) for q in QUERIES], backend=backend,
                           fuse=fuse, stats=stats)
    idx = _oracle(model, mi.next_doc_id)
    for q, g in zip(QUERIES, got):
        w = engine.query(idx, list(q))
        assert g.count == w.count, (q, g.count, w.count)
        assert np.array_equal(g.docs, w.docs)
        assert g.docs.dtype == np.int64


def _mutated_index(n_shards=0):
    """Seed corpus -> adds -> seal -> more adds -> deletes: two sealed
    segments plus a live mutable segment plus tombstones in both."""
    post = _seed_corpus()
    model = _model_from(post)
    mi = segments.MutableIndex.from_postings(
        post, 400, codec_name=CODEC, B=B, n_parts=2, n_shards=n_shards)
    rng = np.random.default_rng(11)
    for _ in range(60):
        terms = sorted(rng.choice(V, size=rng.integers(1, 4),
                                  replace=False).tolist())
        model[mi.add(terms)] = set(terms)
    mi.seal()
    for _ in range(25):
        terms = sorted(rng.choice(V, size=rng.integers(1, 4),
                                  replace=False).tolist())
        model[mi.add(terms)] = set(terms)
    for d in rng.choice(sorted(model), size=90, replace=False).tolist():
        mi.delete(int(d))
        del model[int(d)]
    return mi, model


# -- basic lifecycle --------------------------------------------------------

def test_mutable_only_matches_oracle():
    mi = segments.MutableIndex(codec_name=CODEC, B=B, n_parts=2)
    model = {}
    rng = np.random.default_rng(0)
    for _ in range(50):
        terms = sorted(rng.choice(V, size=rng.integers(1, 4),
                                  replace=False).tolist())
        model[mi.add(terms)] = set(terms)
    _assert_identical(mi, model)
    assert mi.counters()["n_segments"] == 0
    assert mi.counters()["mutable_docs"] == 50


def test_seal_then_mutate_matches_oracle():
    mi, model = _mutated_index()
    c = mi.counters()
    assert c["n_segments"] == 2 and c["mutable_docs"] == 25
    assert c["tombstones"] == 90 and c["n_seals"] == 1
    _assert_identical(mi, model)


def test_add_rejects_empty_and_delete_validates():
    mi = segments.MutableIndex()
    with pytest.raises(ValueError):
        mi.add([])
    gid = mi.add([0, 1])
    with pytest.raises(KeyError):
        mi.delete(gid + 1)                      # never assigned
    assert mi.delete(gid) is True
    assert mi.delete(gid) is False              # idempotent


def test_seal_empty_is_noop():
    mi = segments.MutableIndex()
    assert mi.seal() is None
    assert mi.generation == 0 and mi.counters()["n_seals"] == 0


def test_vocab_growth_new_term_after_seal():
    """A term id first seen after a seal must read as empty in the older
    sealed segment (TermMap), not raise, and still match the oracle."""
    mi = segments.MutableIndex(codec_name=CODEC, B=B)
    model = {}
    for i in range(30):
        model[mi.add([i % 3])] = {i % 3}
    mi.seal()
    for i in range(10):
        terms = {i % 3, 6}                      # term 6: post-seal vocab
        model[mi.add(sorted(terms))] = terms
    got = mi.execute_batch([[6], [0, 6], [5]])
    idx = _oracle(model, mi.next_doc_id)
    for q, g in zip([[6], [0, 6], [5]], got):
        w = engine.query(idx, list(q))
        assert g.count == w.count and np.array_equal(g.docs, w.docs)


def test_tombstones_filter_bitmap_and_list_postings():
    """The seed corpus serves term 0 as a bitmap and sparser terms as
    packed lists; deletes must filter both at collect."""
    mi, model = _mutated_index()
    view = mi._state[0].view
    kinds = {tp.kind for part in view.parts
             for tp in part.terms.values() if tp.kind != "empty"}
    assert "bitmap" in kinds and "list" in kinds
    _assert_identical(mi, model, fuse=False)


def test_delete_changes_no_signatures():
    """Deletes are collect-time only: a warmed steady state stays at zero
    compiles while tombstones accumulate."""
    mi, model = _mutated_index()
    mi.warm([list(q) for q in QUERIES])
    for d in sorted(model)[:20]:
        mi.delete(int(d))
        del model[int(d)]
    stats = {}
    _assert_identical(mi, model, stats=stats)
    assert stats.get("n_compiles", 0) == 0


# -- merge ------------------------------------------------------------------

def test_merge_compacts_and_matches_oracle():
    mi, model = _mutated_index()
    assert mi.merge() is True
    c = mi.counters()
    assert c["n_merges"] == 1 and c["n_segments"] == 1
    _assert_identical(mi, model)
    # tombstoned docs were physically reclaimed: the decoded live corpus
    # is exactly the model, with no dead ids surviving in sealed payloads
    live = mi.live_postings()
    for t in range(V):
        want = np.asarray(sorted(d for d, ts in model.items() if t in ts),
                          dtype=np.int64)
        assert np.array_equal(live[t], want)


def test_merge_noop_when_nothing_to_compact():
    post = _seed_corpus()
    mi = segments.MutableIndex.from_postings(post, 400, codec_name=CODEC,
                                             B=B)
    assert mi.merge() is False                  # 1 segment, 0 tombstones
    assert mi.counters()["n_merges"] == 0


STAGES = ["snapshot", "decode", "build", "stage", "warm", "swap"]


class _Crash(RuntimeError):
    pass


@pytest.mark.parametrize("crash_at", STAGES)
def test_merge_fault_injection_leaves_old_generation(crash_at):
    """A crash at ANY merge phase boundary must leave the old generation
    serving byte-identical, and a retry must converge."""
    mi, model = _mutated_index()
    gen0 = mi.generation
    before = mi.execute_batch([list(q) for q in QUERIES])

    def hook(stage):
        if stage == crash_at:
            raise _Crash(stage)

    with pytest.raises(_Crash):
        mi.merge(hook=hook)
    assert mi.generation == gen0                # nothing published
    assert mi.counters()["n_merges"] == 0
    after = mi.execute_batch([list(q) for q in QUERIES])
    for b, a in zip(before, after):
        assert b.count == a.count and np.array_equal(b.docs, a.docs)
    _assert_identical(mi, model)

    assert mi.merge() is True                   # retry converges
    assert mi.counters()["n_merges"] == 1
    _assert_identical(mi, model)


def test_merge_guard_rejects_concurrent_merge():
    mi, model = _mutated_index()
    entered, release = threading.Event(), threading.Event()

    def hook(stage):
        if stage == "decode":
            entered.set()
            release.wait(timeout=30)

    t = mi.merge_async(hook=hook)
    assert entered.wait(timeout=30)
    assert mi.merge() is False                  # guard: one merge at a time
    release.set()
    t.join(timeout=60)
    assert mi.counters()["n_merges"] == 1
    _assert_identical(mi, model)


def test_merge_absorbs_seal_published_mid_merge():
    """A seal landing between the merge snapshot and the swap must survive
    into the published generation (late-segment rebuild under the lock)."""
    mi, model = _mutated_index()
    late = {}

    def hook(stage):
        if stage == "stage":                    # off-lock: mutate + seal
            for terms in ([1, 2], [0, 7]):
                late[mi.add(terms)] = set(terms)
            mi.seal()

    assert mi.merge(hook=hook) is True
    model.update(late)
    _assert_identical(mi, model)
    assert mi.counters()["n_segments"] == 2     # merged + late-sealed


def test_serving_never_pauses_during_background_merge():
    mi, model = _mutated_index()
    mi.warm([list(q) for q in QUERIES])
    gen0 = mi.generation
    mid_merge = threading.Event()

    def hook(stage):
        if stage == "build":
            mid_merge.set()

    t = mi.merge_async(hook=hook)
    assert mid_merge.wait(timeout=60)
    _assert_identical(mi, model)                # served while compacting
    t.join(timeout=120)
    assert not t.is_alive()
    assert mi.generation > gen0
    _assert_identical(mi, model)


def test_merge_warm_keeps_zero_compiles_across_swap():
    """The acceptance bar: warm, background-merge with pre-warm through
    the shared plan, and the first post-swap batch compiles nothing."""
    mi, model = _mutated_index()
    queries = [list(q) for q in QUERIES]
    mi.warm(queries)
    assert mi.merge(warm_queries=queries) is True
    stats = {}
    _assert_identical(mi, model, stats=stats)
    assert stats.get("n_compiles", 0) == 0


# -- residency / generations ------------------------------------------------

def test_generation_pool_tag_tracks_gid():
    mi, _ = _mutated_index()
    gen = mi._state[0]
    assert gen.pool is not None
    assert gen.pool.tag == gen.gid
    assert mi.stats()["residency"]["tag"] == gen.gid


def test_seal_carries_resident_buffers_forward():
    """Sealing must not re-transfer the previous generation's postings:
    the new pool carries the old generation's device buffers, keyed by
    the preserved part uids."""
    post = _seed_corpus()
    mi = segments.MutableIndex.from_postings(post, 400, codec_name=CODEC,
                                             B=B, n_parts=2)
    old = mi._state[0]
    old_keys = set(old.pool._store)
    assert old_keys, "seed generation staged nothing"
    for terms in ([0, 1], [2, 3], [4, 5]):
        mi.add(terms)
    mi.seal()
    new = mi._state[0]
    assert new.pool is not old.pool
    assert old_keys <= set(new.pool._store)     # carried, same uid keys
    for key in old_keys:                        # same device buffers reused
        assert new.pool._store[key]["dev"] is old.pool._store[key]["dev"]


def test_sharded_lifecycle_matches_oracle():
    mi, model = _mutated_index(n_shards=2)
    assert mi._state[0].sharded is not None
    _assert_identical(mi, model)
    assert mi.merge() is True
    _assert_identical(mi, model, backend="pallas", fuse=False)


# -- merge_async failure surfacing (DESIGN.md §2.15) ------------------------

def test_merge_async_retries_and_clears_error():
    """A crash injected into the first attempt via the stage hook: the
    failure is surfaced in ``counters()`` (never a silent dead thread),
    the capped backoff retries, and the eventual success clears it."""
    mi, model = _mutated_index()
    crashed = []

    def hook(stage):
        if stage == "build" and not crashed:
            crashed.append(1)
            raise _Crash("build")

    t = mi.merge_async(hook=hook, retries=2, retry_backoff_s=0.01)
    t.join(timeout=120)
    assert not t.is_alive()
    c = mi.counters()
    assert c["n_merges"] == 1                   # the retry landed the merge
    assert c["merge_failures"] == 1
    assert c["last_merge_error"] is None        # success clears the error
    _assert_identical(mi, model)


def test_merge_async_exhausted_retries_surface_error():
    """Every attempt fails: the last error string stays visible in
    ``counters()``, nothing publishes, and the old generation serves."""
    mi, model = _mutated_index()

    def hook(stage):
        if stage == "decode":
            raise _Crash("decode stage down")

    t = mi.merge_async(hook=hook, retries=1, retry_backoff_s=0.01)
    t.join(timeout=120)
    c = mi.counters()
    assert c["n_merges"] == 0                   # nothing ever published
    assert c["merge_failures"] == 2             # initial attempt + 1 retry
    assert "_Crash" in c["last_merge_error"]
    assert "decode stage down" in c["last_merge_error"]
    _assert_identical(mi, model)
