"""Differential coverage for the device-resident index and the pipelined
executor (ISSUE 3).

Layers:
  * pool-backed batch execution == sequential engine (byte-identical), with
    resident-hit accounting actually firing,
  * pipelined execution at depth ∈ {1, 2, 4} == ``engine.query`` across
    jax/pallas backends and uniform/skewed corpora, plus the empty-batch and
    single-query edges,
  * ResidentPool staging/eviction accounting, layout-memo counters, and the
    build-time layout precompute.
"""

import numpy as np
import pytest

from repro.index import batch as batch_lib
from repro.index import pipeline as pipe_lib
from repro.index import builder, corpus as corpus_lib, engine, source

pytestmark = pytest.mark.pipeline


# --------------------------------------------------------------------------
# fixtures: uniform (Table-2-shaped) and skewed-ratio corpora
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def uniform():
    corpus = corpus_lib.synthesize(n_docs=1 << 14, n_queries=10, seed=33)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="fastpfor-d1", B=16, n_parts=2)
    seq = [engine.query(idx, q) for q in corpus.queries]
    return idx, corpus.queries, seq


@pytest.fixture(scope="module")
def skewed():
    # tiny first term, very long second term: exercises the packed
    # (skip-aware partial decode) folds through the pipeline
    n_docs = 1 << 16
    table = {2: (100.0, [0.8 * (1 << 18) / n_docs,
                         38000.0 * (1 << 18) / n_docs])}
    corpus = corpus_lib.synthesize(n_docs=n_docs, n_queries=4, seed=7,
                                   table=table)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="bp8-d1", B=0, n_parts=1)
    seq = [engine.query(idx, q) for q in corpus.queries]
    return idx, corpus.queries, seq


def _assert_identical(results, seq):
    assert len(results) == len(seq)
    for got, want in zip(results, seq):
        assert got.count == want.count
        assert got.docs.dtype == want.docs.dtype
        assert np.array_equal(got.docs, want.docs)      # byte-identical


# --------------------------------------------------------------------------
# pool-backed batch execution
# --------------------------------------------------------------------------

def test_pool_batch_matches_sequential(uniform):
    idx, queries, seq = uniform
    pool = source.ResidentPool()
    pool.warm(idx)
    stats: dict = {}
    _assert_identical(
        batch_lib.execute_batch(idx, queries, pool=pool, stats=stats), seq)
    assert stats.get("resident_hits", 0) > 0
    # steady state: a second pass decodes nothing at all
    stats2: dict = {}
    _assert_identical(
        batch_lib.execute_batch(idx, queries, pool=pool, stats=stats2), seq)
    assert stats2.get("decoded_lists", 0) == 0


def test_pool_composes_with_cache(uniform):
    idx, queries, seq = uniform
    pool = source.ResidentPool()
    cache = engine.DecodeCache(capacity_ints=1 << 24)
    for _ in range(2):
        _assert_identical(
            batch_lib.execute_batch(idx, queries, pool=pool, cache=cache),
            seq)


def test_pool_lazy_staging_converges(uniform):
    """Without warm(), the first batch decodes and stages; the second batch
    serves from residency."""
    idx, queries, _ = uniform
    pool = source.ResidentPool()
    batch_lib.execute_batch(idx, queries, pool=pool)
    staged = pool.staged_lists
    assert staged > 0
    stats: dict = {}
    batch_lib.execute_batch(idx, queries, pool=pool, stats=stats)
    assert pool.staged_lists == staged          # nothing new staged
    assert stats.get("decoded_lists", 0) == 0


# --------------------------------------------------------------------------
# pipelined execution: depth × backend × corpus differential matrix
# --------------------------------------------------------------------------

@pytest.mark.parametrize("depth", [1, 2, 4])
@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_pipeline_matches_sequential_uniform(uniform, depth, backend):
    idx, queries, seq = uniform
    pool = source.ResidentPool()
    pool.warm(idx)
    out = pipe_lib.execute_pipelined(idx, queries, batch_size=4, depth=depth,
                                     backend=backend, pool=pool)
    _assert_identical(out, seq)


@pytest.mark.parametrize("depth", [1, 2, 4])
@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_pipeline_matches_sequential_skewed(skewed, depth, backend):
    idx, queries, seq = skewed
    out = pipe_lib.execute_pipelined(idx, queries, batch_size=2, depth=depth,
                                     backend=backend)
    _assert_identical(out, seq)


def test_pipeline_empty_batch(uniform):
    idx, _, _ = uniform
    assert pipe_lib.execute_pipelined(idx, [], batch_size=8, depth=2) == []


def test_pipeline_single_query(uniform):
    idx, queries, seq = uniform
    for depth in (1, 2, 4):
        out = pipe_lib.execute_pipelined(idx, [queries[0]], batch_size=8,
                                         depth=depth)
        _assert_identical(out, seq[:1])


def test_pipeline_depth_one_equals_execute_batch(uniform):
    idx, queries, _ = uniform
    pool = source.ResidentPool()
    pool.warm(idx)
    serial = []
    for lo in range(0, len(queries), 4):
        serial.extend(batch_lib.execute_batch(idx, queries[lo: lo + 4],
                                              pool=pool))
    piped = pipe_lib.execute_pipelined(idx, queries, batch_size=4, depth=1,
                                       pool=pool)
    _assert_identical(piped, serial)


def test_pipeline_timings_populated(uniform):
    idx, queries, seq = uniform
    tm = pipe_lib.StageTimings()
    out = pipe_lib.execute_pipelined(idx, queries, batch_size=4, depth=2,
                                     timings=tm)
    _assert_identical(out, seq)
    assert tm.batches == (len(queries) + 3) // 4
    assert tm.stage >= 0 and tm.dispatch >= 0 and tm.block >= 0
    assert tm.assemble > 0          # launcher-attributed operand assembly
    assert set(tm.as_dict()) == {"stage_s", "assemble_s", "dispatch_s",
                                 "block_s", "batches"}


# --------------------------------------------------------------------------
# pool accounting + layout memoization
# --------------------------------------------------------------------------

def test_pool_eviction_accounting(uniform):
    idx, queries, seq = uniform
    pool = source.ResidentPool(capacity_ints=2048)      # tiny: forces churn
    _assert_identical(batch_lib.execute_batch(idx, queries, pool=pool), seq)
    st = pool.stats()
    assert st["evicted_lists"] > 0
    # budget respected (a single oversized entry is the only exception)
    assert st["resident_lists"] == 1 or st["resident_ints"] <= 2048
    assert st["staged_ints"] - st["evicted_ints"] == st["resident_ints"]


def test_pool_churn_bounds_device_footprint(uniform):
    """ISSUE 6 regression: under eviction churn, the *full* device
    footprint (store entries + pad memos + arena row copies) stops
    growing — previously every arena kept a device copy of every row ever
    staged and pad memos outlived their entries, so real device memory
    grew without bound while ``resident_ints`` claimed the budget held."""
    idx, queries, seq = uniform
    pool = source.ResidentPool(capacity_ints=2048)      # tiny: forces churn
    for _ in range(2):                                   # reach steady churn
        _assert_identical(batch_lib.execute_batch(idx, queries, pool=pool),
                          seq)
    st1 = pool.stats()
    assert st1["evicted_lists"] > 0
    for _ in range(3):                                   # keep churning
        _assert_identical(batch_lib.execute_batch(idx, queries, pool=pool),
                          seq)
    st2 = pool.stats()
    assert st2["evicted_lists"] > st1["evicted_lists"]   # churn continued...
    # ...but the allocated arena footprint stopped growing (slot reuse)
    assert st2["arena_ints"] == st1["arena_ints"]
    assert st2["overhead_ints"] == st1["overhead_ints"]
    assert st2["arena_evictions"] > 0
    # pad accounting has no drift: the aggregate counter equals the sum
    # over live entries (evicted entries dropped their memos)
    assert st2["pad_ints"] == sum(e["pad_ints"]
                                  for e in pool._store.values())
    assert all(not e["pads"] or e["pad_ints"] > 0
               for e in pool._store.values())
    # the store invariant survives the new accounting
    assert st2["staged_ints"] - st2["evicted_ints"] == st2["resident_ints"]
    assert st2["device_ints"] == st2["resident_ints"] + st2["overhead_ints"]


def test_arena_evict_reuses_slots():
    """RowArena.evict frees the slot for the next miss — allocated
    footprint (and therefore the gather buffer shape) stays flat under
    churn."""
    a = source.RowArena([np.zeros(4, np.int32)])
    s1 = a.slot("a", lambda: np.ones(4, np.int32))
    a.slot("b", lambda: np.full(4, 2, np.int32))
    ints0 = a.ints
    assert a.evict("a") == 4
    assert a.evict("missing") == 0
    s3 = a.slot("c", lambda: np.full(4, 3, np.int32))
    assert s3 == s1                         # freed slot reused
    assert a.ints == ints0                  # no growth
    assert a.evictions == 1
    buf = np.asarray(a.buffer())
    assert np.array_equal(buf[s3], np.full(4, 3, np.int32))


def test_pool_warm_skips_long_skip_capable_lists(skewed):
    """warm() keeps skip-served lists compressed — residency must not
    silently decompress the index."""
    idx, queries, seq = skewed
    pool = source.ResidentPool()
    pool.warm(idx)
    stats: dict = {}
    _assert_identical(
        batch_lib.execute_batch(idx, queries, pool=pool, stats=stats), seq)
    assert stats.get("skip_folds", 0) > 0       # packed path still taken


def test_demoted_geometry_mismatch_stays_out_of_pool():
    """A packed fold demoted over a block-geometry mismatch is decoded for
    that group only: staging it resident would evict hot short lists and
    permanently win over want_skip, disabling its skip path."""
    rng = np.random.default_rng(0)
    n_docs = 1 << 18
    postings = [
        np.sort(rng.choice(n_docs, 50, replace=False)),      # seed
        np.sort(rng.choice(n_docs, 6000, replace=False)),    # 8-row blocks
        np.sort(rng.choice(n_docs, 40000, replace=False)),   # 32-row blocks
    ]
    idx = builder.build(postings, n_docs, codec_name="bp-d1", B=0, n_parts=1)
    q = [0, 1, 2]
    seq = engine.query(idx, q)
    pool = source.ResidentPool()
    pool.warm(idx)
    for _ in range(2):
        stats: dict = {}
        out = batch_lib.execute_batch(idx, [q], pool=pool, stats=stats)
        assert out[0].count == seq.count
        assert np.array_equal(out[0].docs, seq.docs)
        # the 40k list is skip-served every pass; the demoted 6k list is
        # decoded per group, never staged
        assert stats.get("skip_folds", 0) == 1
        assert stats.get("decoded_lists", 0) == 1
        assert (idx.parts[0].uid, 1) not in pool


def test_layout_precomputed_at_build(skewed):
    """builder.build warms the self-padded layout memo: the sequential
    packed probe never projects on the query path."""
    idx, queries, _ = skewed
    stats: dict = {}
    engine.query(idx, queries[0], stats=stats)
    assert stats.get("layout_misses", 0) == 0
    assert stats.get("layout_hits", 0) > 0


def test_decoded_source_vals_np_consistent(uniform):
    idx, _, _ = uniform
    from repro.core import codecs as codec_lib
    codec = codec_lib.get_codec(idx.codec_name)
    part = idx.parts[0]
    tid, tp = next((t, tp) for t, tp in part.terms.items()
                   if tp.kind == "list")
    src = source.resolve(part, tid, tp, codec, r_count=None)
    assert src.vals_np is not None
    assert np.array_equal(np.asarray(src.vals), src.vals_np)
