"""Optional-`hypothesis` shim so the tier-1 suite runs on clean machines.

Import ``given``, ``settings``, ``st`` and ``HealthCheck`` from here instead
of `hypothesis`.  When the real package is installed (CI installs it via
``requirements-dev.txt``), these are the real objects and the property
tests get shrinking, the example database, and ``--hypothesis-seed``
pinning.  When it is not, a small deterministic fallback engine stands in:
each ``@given`` test runs ``max_examples`` generated examples drawn from a
seeded PRNG (``REPRO_PROP_SEED`` env, default 0), so the op-sequence
differential harnesses still *execute* — no silent skips — just without
shrinking.  A failing fallback example prints its seed and index so the
exact case replays.

Only the strategy surface the suite uses is implemented: ``integers``,
``booleans``, ``just``, ``sampled_from``, ``lists`` (with ``unique``),
``tuples``, ``one_of``, ``data``.
"""

from __future__ import annotations

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    import functools
    import inspect
    import os
    import random

    HAVE_HYPOTHESIS = False

    _FALLBACK_EXAMPLES = 25

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example_with(self, rng: random.Random):
            return self._draw(rng)

    class _St:
        @staticmethod
        def integers(min_value=0, max_value=1 << 30):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def just(value):
            return _Strategy(lambda rng: value)

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10, unique=False):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                if not unique:
                    return [elements.example_with(rng) for _ in range(n)]
                out, seen = [], set()
                for _ in range(8 * n + 8):          # bounded retry
                    v = elements.example_with(rng)
                    k = repr(v)
                    if k not in seen:
                        seen.add(k)
                        out.append(v)
                    if len(out) == n:
                        break
                return out
            return _Strategy(draw)

        @staticmethod
        def tuples(*strategies):
            return _Strategy(
                lambda rng: tuple(s.example_with(rng) for s in strategies))

        @staticmethod
        def one_of(*strategies):
            return _Strategy(
                lambda rng: strategies[rng.randrange(len(strategies))]
                .example_with(rng))

        @staticmethod
        def data():
            return _Strategy(lambda rng: _Data(rng))

    class _Data:
        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.example_with(self._rng)

    st = _St()

    def given(*gargs, **gkwargs):
        def deco(fn):
            sig = inspect.signature(fn)
            names = list(sig.parameters)
            bound = dict(gkwargs)
            if gargs:          # positional strategies bind rightmost params
                for name, strat in zip(names[len(names) - len(gargs):],
                                       gargs):
                    bound[name] = strat
            rest = [p for p in sig.parameters.values()
                    if p.name not in bound]

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", _FALLBACK_EXAMPLES)
                base = int(os.environ.get("REPRO_PROP_SEED", "0"))
                for i in range(n):
                    rng = random.Random(f"{base}:{fn.__qualname__}:{i}")
                    drawn = {k: s.example_with(rng)
                             for k, s in bound.items()}
                    try:
                        fn(*args, **kwargs, **drawn)
                    except Exception:
                        print(f"[property-fallback] falsifying example "
                              f"{i + 1}/{n} of {fn.__qualname__} "
                              f"(REPRO_PROP_SEED={base}): {drawn!r}")
                        raise

            # pytest must see only the non-strategy params as fixtures
            wrapper.__signature__ = sig.replace(parameters=rest)
            return wrapper
        return deco

    def settings(*args, **kwargs):
        max_examples = kwargs.get("max_examples")

        def deco(fn):
            if max_examples is not None:
                fn._max_examples = max_examples
            return fn
        return deco

    class HealthCheck:
        """Inert stand-in; attribute access returns opaque tokens."""
        def __getattr__(self, name):
            return name
    HealthCheck = HealthCheck()
