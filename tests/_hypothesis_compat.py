"""Optional-`hypothesis` shim so the tier-1 suite collects on clean machines.

Import ``given``, ``settings`` and ``st`` from here instead of `hypothesis`.
When the real package is installed, these are the real objects.  When it is
not, property tests decorated with ``@given(...)`` are replaced by a no-arg
stub carrying a skip marker with a clear reason, and ``settings``/``st``
become inert stand-ins (the strategy objects they build are never executed).
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    import pytest

    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Builds inert placeholders for st.integers(...), st.data(), ..."""

        def __getattr__(self, name):
            def make(*args, **kwargs):
                return None
            return make

    st = _StrategyStub()

    def given(*args, **kwargs):
        def deco(fn):
            # Return a no-arg stub: pytest must not try to resolve the
            # strategy parameters of the wrapped property test as fixtures.
            @pytest.mark.skip(reason="hypothesis not installed "
                                     "(see requirements-dev.txt)")
            def stub():
                pass
            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub
        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco
