"""End-to-end behaviour tests for the paper's system: build a synthetic
corpus, compress it with every codec family, answer conjunctive queries, and
check every result against a brute-force oracle (paper §6.7 pipeline)."""

import numpy as np
import pytest

from repro.index import builder, corpus as corpus_lib, engine


@pytest.fixture(scope="module")
def corpus():
    return corpus_lib.synthesize(n_docs=1 << 16, n_queries=10, seed=3)


@pytest.mark.parametrize("codec", ["bp-d1", "bp-dv", "fastpfor-d1", "varint"])
@pytest.mark.parametrize("B", [0, 16])
def test_queries_match_bruteforce(corpus, codec, B):
    idx = builder.build(corpus.postings, corpus.n_docs, codec_name=codec,
                        B=B, n_parts=2)
    for q in corpus.queries:
        got = engine.query(idx, q)
        expect = engine.brute_force(corpus.postings, q)
        assert got.count == len(expect)
        assert np.array_equal(np.sort(got.docs), expect[: len(got.docs)])


def test_bitmap_threshold_controls_hybrid(corpus):
    """HYB+M2: larger B → more bitmap terms."""
    def n_bitmaps(B):
        idx = builder.build(corpus.postings, corpus.n_docs, B=B, n_parts=1)
        return sum(tp.kind == "bitmap" for p in idx.parts
                   for tp in p.terms.values())
    assert n_bitmaps(0) == 0
    assert n_bitmaps(8) <= n_bitmaps(32)


def test_partitioning_preserves_results(corpus):
    """The paper's corpus partitioning must not change answers."""
    idx1 = builder.build(corpus.postings, corpus.n_docs, B=16, n_parts=1)
    idx4 = builder.build(corpus.postings, corpus.n_docs, B=16, n_parts=4)
    for q in corpus.queries[:6]:
        a, b = engine.query(idx1, q), engine.query(idx4, q)
        assert a.count == b.count
        assert np.array_equal(np.sort(a.docs), np.sort(b.docs))


def test_decode_cache_regime(corpus):
    """Table 4 regime (SvS over cached/decoded lists) must return identical
    results to the per-query-decode regime, across repeated queries."""
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="fastpfor-d1", B=16, n_parts=2)
    cache = engine.DecodeCache(capacity_ints=1 << 22)
    for _ in range(2):                       # second pass hits the cache
        for q in corpus.queries[:6]:
            a = engine.query(idx, q)
            b = engine.query(idx, q, cache=cache)
            assert a.count == b.count
            assert np.array_equal(np.sort(a.docs), np.sort(b.docs))
    assert len(cache._store) > 0


def test_compression_stats_sane(corpus):
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="fastpfor-d1", B=16, n_parts=2)
    st = idx.stats()
    assert 0 < st["bits_per_int"] < 32.0
