"""Multi-(host-)device behaviour: sharded MoE equivalence, elastic checkpoint
reshard, and a tiny dry-run cell.  Each runs in a subprocess because jax pins
the device count at first init."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

# repro.launch.mesh is AxisType-free since PR 4: it only passes axis_types
# when the running jax provides it, so these subprocess checks run on the
# pinned jax 0.4.37 too.  No test in this file needs AxisType itself —
# if one ever does, skip that test alone with a comment naming the API.

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         env=env, capture_output=True, text=True,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_sharded_moe_matches_local():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.models import moe
        from repro.distributed import sharding as shd
        from repro.launch.mesh import make_local_mesh
        mesh = make_local_mesh(data=2, model=4)
        params = moe.init_moe_params(jax.random.PRNGKey(0), 32, 64, 8)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32))
        ref, aux_ref = moe.moe_ffn_local(params, x, top_k=2,
                                         capacity_factor=8.0, act='swiglu')
        shd.set_hint_rules({}, mesh)
        xs = jax.device_put(x, NamedSharding(mesh, P('data', 'model', None)))
        ps = jax.device_put(params, jax.tree.map(
            lambda l: NamedSharding(mesh, P(*((('model',)+(None,)*(l.ndim-1))
                                              if l.ndim == 3
                                              else (None,)*l.ndim))), params))
        out, aux = jax.jit(lambda p, xx: moe.moe_ffn_sharded(
            p, xx, top_k=2, capacity_factor=8.0, act='swiglu',
            mesh=mesh))(ps, xs)
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-4, err
        assert abs(float(aux) - float(aux_ref)) < 1e-4
        print('moe ok', err)
    """))


def test_elastic_checkpoint_reshard(tmp_path):
    """Save on a 2×4 mesh, restore onto 4×2 and 1×8 — elastic scaling."""
    code_save = f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.manager import CheckpointManager
        from repro.launch.mesh import make_local_mesh
        mesh = make_local_mesh(data=2, model=4)
        w = jnp.arange(64*8, dtype=jnp.float32).reshape(64, 8)
        ws = jax.device_put(w, NamedSharding(mesh, P('data', 'model')))
        CheckpointManager({str(tmp_path)!r}).save(5, {{'w': ws}})
        print('saved')
    """
    print(_run(code_save))
    for d, m in ((4, 2), (1, 8)):
        code_load = f"""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.checkpoint.manager import CheckpointManager
            from repro.launch.mesh import make_local_mesh
            mesh = make_local_mesh(data={d}, model={m})
            tpl = {{'w': jnp.zeros((64, 8), jnp.float32)}}
            sh = {{'w': NamedSharding(mesh, P('data', 'model'))}}
            tree, step = CheckpointManager({str(tmp_path)!r}).restore(
                tpl, shardings=sh)
            assert step == 5
            got = np.asarray(tree['w'])
            assert np.array_equal(got,
                np.arange(64*8, dtype=np.float32).reshape(64, 8))
            print('restored onto', {d}, 'x', {m})
        """
        print(_run(code_load))


@pytest.mark.slow
def test_dryrun_cell_compiles():
    """One real production-mesh cell end-to-end (512 host devices)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "internlm2-1.8b", "--shape", "decode_32k", "--mesh", "multipod"],
        env=env, capture_output=True, text=True, timeout=600, cwd=REPO)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "OK" in out.stdout
