"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
same-family config and runs one forward/train step on CPU, asserting output
shapes and no NaNs.  (Full configs are exercised only via the dry-run.)"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import all_arch_ids, get_config
from repro.data import graph_data, recsys_data
from repro.models import gnn as gnn_lib
from repro.models import recsys as recsys_lib
from repro.models import transformer as tfm
from repro.optim import adamw
from repro.train import steps as train_steps

LM_ARCHS = ["gemma-7b", "phi3-medium-14b", "internlm2-1.8b",
            "granite-moe-1b-a400m", "kimi-k2-1t-a32b"]
RECSYS_ARCHS = ["din", "sasrec", "bert4rec", "mind"]


def test_all_archs_registered():
    ids = all_arch_ids()
    for a in LM_ARCHS + RECSYS_ARCHS + ["graphsage-reddit", "paper-index"]:
        assert a in ids
    assert len([a for a in ids if a != "paper-index"]) == 10


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke(arch, rng):
    spec = get_config(arch)
    cfg = spec.smoke_config()
    key = jax.random.PRNGKey(0)
    params = tfm.init_params(key, cfg)
    toks = jax.random.randint(key, (2, 32), 0, cfg.vocab)
    logits, aux = tfm.forward(params, toks, cfg)
    assert logits.shape == (2, 32, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    # one train step
    opt_cfg = adamw.AdamWConfig(lr=1e-3)
    step = train_steps.make_lm_train_step(cfg, opt_cfg)
    opt = adamw.init(params, opt_cfg)
    batch = {"tokens": toks, "labels": toks}
    p2, o2, m = jax.jit(step)(params, opt, batch, key)
    assert np.isfinite(float(m["loss"]))
    # decode one token
    lg, cache = tfm.prefill(params, toks, cfg)
    kv = tfm.init_kv_cache(cfg, 2, 64)
    kv = {"k": kv["k"].at[:, :, :32].set(cache["k"]),
          "v": kv["v"].at[:, :, :32].set(cache["v"])}
    lg2, _ = tfm.decode_step(params, kv, jnp.argmax(lg, -1).astype(jnp.int32),
                             jnp.int32(32), cfg)
    assert lg2.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(lg2)).all()


@pytest.mark.parametrize("shape_name", ["full_graph_sm", "minibatch_lg",
                                        "ogb_products", "molecule"])
def test_gnn_smoke(shape_name, rng):
    spec = get_config("graphsage-reddit")
    cfg = spec.smoke_config()
    sh = spec.shapes[shape_name]
    cfg = dataclasses.replace(cfg, task="graph"
                              if sh["kind"] == "molecule" else "node")
    key = jax.random.PRNGKey(0)
    params = gnn_lib.init_params(key, cfg)
    if sh["kind"] == "molecule":
        mb = graph_data.molecule_batch(rng, 8, sh["n_nodes"], sh["n_edges"],
                                       cfg.d_feat)
        loss, _ = gnn_lib.molecule_loss(
            params, {k: jnp.asarray(v) for k, v in mb.items()}, cfg)
    elif sh["kind"] == "minibatch":
        g = graph_data.synthetic_graph(2000, 8, d_feat=cfg.d_feat,
                                       n_classes=cfg.n_classes)
        batch = {"feats": jnp.asarray(g["x"]),
                 "indptr": jnp.asarray(g["indptr"]),
                 "indices": jnp.asarray(g["indices"]),
                 "seeds": jnp.arange(64),
                 "labels": jnp.asarray(g["labels"][:64])}
        loss, _ = gnn_lib.minibatch_loss(params, batch, key, cfg, (5, 3))
    else:
        g = graph_data.synthetic_graph(1000, 6, d_feat=cfg.d_feat,
                                       n_classes=cfg.n_classes)
        batch = {k: jnp.asarray(g[k]) for k in
                 ("x", "edge_src", "edge_dst", "labels", "train_mask")}
        loss, _ = gnn_lib.node_loss(params, batch, cfg)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke(arch, rng):
    spec = get_config(arch)
    cfg = spec.smoke_config()
    key = jax.random.PRNGKey(0)
    params = recsys_lib.INIT[arch](key, cfg)
    mk = {"din": recsys_data.din_batch, "sasrec": recsys_data.seq_batch,
          "bert4rec": recsys_data.bert4rec_batch,
          "mind": recsys_data.mind_batch}[arch]
    kwargs = {"n_masked": 4} if arch == "bert4rec" else {}
    b = {k: jnp.asarray(v) for k, v in mk(rng, cfg, 16, **kwargs).items()}
    loss, _ = recsys_lib.LOSS[arch](params, b, cfg)
    assert np.isfinite(float(loss))
    scores = recsys_lib.SCORE[arch](params, b, cfg)
    assert scores.shape == (16,)
    rb = {k: jnp.asarray(v) for k, v in
          recsys_data.retrieval_batch(rng, cfg, 256).items()}
    rs = recsys_lib.RETRIEVAL[arch](params, rb, cfg)
    assert rs.shape == (256,) and np.isfinite(np.asarray(rs)).all()
    # one train step
    opt_cfg = adamw.AdamWConfig(lr=1e-3, weight_decay=0.0)
    step = train_steps.make_recsys_train_step(cfg, opt_cfg)
    opt = adamw.init(params, opt_cfg)
    p2, o2, m = jax.jit(step)(params, opt, b, key)
    assert np.isfinite(float(m["loss"]))
