"""Fault-tolerance behaviour: exact resume, async+atomic checkpoints,
corrupt-checkpoint skip, straggler stall fallback, gradient compression."""

import os
import shutil
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.lm_data import TokenStream
from repro.distributed import grad_compress as gc
from repro.models.transformer import LMConfig, init_params
from repro.optim import adamw
from repro.train.steps import make_lm_train_step
from repro.train.trainer import Trainer, TrainerConfig

CFG = LMConfig(name="t", n_layers=2, d_model=32, n_heads=2, n_kv=2,
               d_ff=64, vocab=64, remat="none")
OPT = adamw.AdamWConfig(lr=3e-3)


def _data_iter(seed=0):
    stream = TokenStream(CFG.vocab, seed=seed)
    while True:
        b = stream.batch(4, 16)
        yield {k: jnp.asarray(v) for k, v in b.items()}


def _fresh():
    p = init_params(jax.random.PRNGKey(0), CFG)
    return p, adamw.init(p, OPT)


def test_loss_decreases_and_resume_is_bitwise(tmp_path):
    step = make_lm_train_step(CFG, OPT, total_steps=40, warmup=4)
    # uninterrupted run
    p, o = _fresh()
    tr = Trainer(step, p, o, _data_iter(), TrainerConfig(
        total_steps=40, ckpt_every=20, ckpt_dir=str(tmp_path / "a")))
    res = tr.run()
    assert res["history"][-1] < res["history"][0]
    final_a = jax.tree.map(np.asarray, tr.params)

    # interrupted at 20 then resumed (same data order: fresh iterator is
    # deterministic and step-aligned at the checkpoint boundary)
    p, o = _fresh()
    tr1 = Trainer(step, p, o, _data_iter(), TrainerConfig(
        total_steps=20, ckpt_every=20, ckpt_dir=str(tmp_path / "b")))
    tr1.run()
    tr1.mgr.wait()
    it = _data_iter()
    for _ in range(20):      # advance data to the checkpoint boundary
        next(it)
    p, o = _fresh()
    tr2 = Trainer(step, p, o, it, TrainerConfig(
        total_steps=40, ckpt_every=20, ckpt_dir=str(tmp_path / "b")))
    start = tr2.try_restore()
    assert start == 20
    tr2.run(start_step=start)
    final_b = jax.tree.map(np.asarray, tr2.params)
    for a, b in zip(jax.tree.leaves(final_a), jax.tree.leaves(final_b)):
        assert np.array_equal(a, b), "resume must be bitwise identical"


def test_corrupt_checkpoint_skipped(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=5)
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    mgr.save(10, tree)
    mgr.save(20, jax.tree.map(lambda x: x * 2, tree))
    # corrupt step 20 (truncate an array file)
    d = os.path.join(str(tmp_path), "ckpt_00000020")
    bad = os.path.join(d, "arr_00000.npy")
    with open(bad, "wb") as fh:
        fh.write(b"corrupt")
    restored, step = mgr.restore(tree)
    assert step == 10
    assert np.array_equal(np.asarray(restored["w"]), np.arange(8))


def test_atomic_no_partial_dirs(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    tree = {"w": jnp.zeros((128,))}
    for s in (1, 2, 3, 4):
        mgr.save_async(s, tree)
    mgr.wait()
    names = sorted(os.listdir(str(tmp_path)))
    assert all(n.startswith("ckpt_") for n in names)
    assert len(mgr.all_steps()) == 2          # retention


def test_straggler_stall_fallback():
    """A stalling data pipeline must not block training (reuse last batch)."""
    def slow_iter():
        yield {"tokens": jnp.zeros((4, 16), jnp.int32),
               "labels": jnp.zeros((4, 16), jnp.int32)}
        time.sleep(60)        # producer wedges
        yield None

    step = make_lm_train_step(CFG, OPT)
    p, o = _fresh()
    tr = Trainer(step, p, o, slow_iter(), TrainerConfig(
        total_steps=3, ckpt_every=100, ckpt_dir="/tmp/repro_stall",
        stall_timeout_s=0.5))
    res = tr.run()
    assert res["step"] == 3
    assert res["stalls"] >= 1


def test_grad_compress_wire_lossless(rng):
    g = rng.normal(size=20000).astype(np.float32)
    res = np.zeros_like(g)
    idx, vals, new_res = gc.sparsify(jnp.asarray(g), jnp.asarray(res), 512)
    packed, vals16 = gc.encode_wire(np.asarray(idx), np.asarray(vals))
    idx2, vals2 = gc.decode_wire(packed, vals16)
    assert np.array_equal(idx2, np.asarray(idx))          # indices lossless
    assert np.allclose(vals2, np.asarray(vals), rtol=8e-3, atol=1e-4)
    assert gc.compress_ratio(g.size, 512, packed) > 10    # ≥10× vs dense
    # error feedback holds the residual
    dense = np.asarray(gc.apply_sparse(jnp.asarray(g), idx, vals))
    assert np.allclose(dense + np.asarray(new_res), g, atol=1e-6)


def test_grad_compress_preserves_convergence():
    """Toy quadratic: top-k + error feedback still converges."""
    w_true = np.linspace(-1, 1, 64).astype(np.float32)
    w = jnp.zeros(64)
    res = jnp.zeros(64)
    for _ in range(300):
        g = w - jnp.asarray(w_true)
        # canonical DGC: the residual lives in update (lr-scaled) space
        idx, vals, res = gc.sparsify(0.2 * g, res, 8)
        w = w - gc.apply_sparse(g, idx, vals)
    assert float(jnp.max(jnp.abs(w - w_true))) < 0.05
