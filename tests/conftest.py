import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(12345)


def sorted_unique(rng, n, universe_bits=26):
    u = 1 << universe_bits
    return np.sort(rng.choice(u, size=min(n, u // 2), replace=False)).astype(
        np.int64)
