"""Continuous-batching server coverage (ISSUE 6).

Layers:
  * differential guarantee: served results == offline ``execute_batch`` on
    the same query multiset across {jax, pallas} × {fused, unfused} ×
    shards {1, 2}, in drain and live (open-loop) modes, under any arrival
    order,
  * steady state: a warmed server reports n_compiles == 0 (skipped — not
    vacuously passed — when jax lacks jit ``_cache_size``),
  * the three loop policies: family-aligned admission after warmup,
    deadline flush vs full flush, bounded-queue shedding,
  * unit coverage for the arrival processes, ``batch.plan_covers`` and the
    ``warm_to_fixed_point`` convergence flag.
"""

import asyncio

import numpy as np
import pytest

from repro.index import batch as batch_lib
from repro.index import builder, corpus as corpus_lib, engine, source
from repro.index import shard as shard_lib
from repro.launch import server as server_lib

pytestmark = pytest.mark.server


# --------------------------------------------------------------------------
# fixtures (mirrors tests/test_fusion.py)
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def uniform():
    corpus = corpus_lib.synthesize(n_docs=1 << 14, n_queries=10, seed=33)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="fastpfor-d1", B=16, n_parts=2)
    seq = [engine.query(idx, q) for q in corpus.queries]
    return idx, corpus.queries, seq


@pytest.fixture(scope="module")
def mixed():
    table = {k: corpus_lib.TABLE2_CLUEWEB[k] for k in (2, 3, 4, 5)}
    corpus = corpus_lib.synthesize(n_docs=1 << 14, n_queries=32, seed=11,
                                   table=table)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="fastpfor-d1", B=16, n_parts=2)
    seq = [engine.query(idx, q) for q in corpus.queries]
    return idx, corpus.queries, seq


def _assert_identical(results, seq):
    assert len(results) == len(seq)
    for got, want in zip(results, seq):
        assert got.count == want.count
        assert got.docs.dtype == want.docs.dtype
        assert np.array_equal(got.docs, want.docs)      # byte-identical



# --------------------------------------------------------------------------
# differential: served == offline, {backend} × {fuse} × {drain, live}
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jax", "pallas"])
@pytest.mark.parametrize("fuse", [True, False])
def test_server_matches_offline(uniform, backend, fuse):
    idx, queries, seq = uniform
    results, srv = server_lib.serve_open_loop(
        idx, queries, qps=0.0, backend=backend, fuse=fuse, max_batch=4)
    assert srv.metrics.n_shed == 0
    _assert_identical(results, seq)


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_server_live_load_matches_offline(uniform, backend):
    idx, queries, seq = uniform
    results, srv = server_lib.serve_open_loop(
        idx, queries, qps=2000.0, pattern="poisson", seed=3,
        backend=backend, max_batch=4, max_queue=1024, max_wait_ms=1.0)
    assert srv.metrics.n_shed == 0
    _assert_identical(results, seq)
    s = srv.metrics.summary()
    assert s["n_done"] == len(queries)
    assert s["p99_ms"] >= s["p50_ms"] > 0
    assert sum(s["queue_depth_hist"].values()) == len(queries)


@pytest.mark.parametrize("n_shards", [1, 2])
def test_server_sharded_matches_offline(uniform, n_shards):
    idx, queries, seq = uniform
    sharded = shard_lib.shard_index(idx, n_shards)
    results, srv = server_lib.serve_open_loop(
        idx, queries, qps=0.0, sharded=sharded, max_batch=4)
    _assert_identical(results, seq)


def test_server_arrival_order_independent(mixed):
    """Any packing of the same query multiset returns per-query results
    identical to the sequential engine."""
    idx, queries, seq = mixed
    perm = np.random.default_rng(9).permutation(len(queries))
    shuffled = [queries[i] for i in perm]
    results, _ = server_lib.serve_open_loop(idx, shuffled, qps=0.0,
                                            max_batch=8)
    for out_i, src_i in enumerate(perm):
        _assert_identical([results[out_i]], [seq[src_i]])


def test_server_pool_composes(uniform):
    idx, queries, seq = uniform
    pool = source.ResidentPool()
    pool.warm(idx)
    results, srv = server_lib.serve_open_loop(idx, queries, qps=0.0,
                                              pool=pool, max_batch=4)
    _assert_identical(results, seq)
    assert srv.stats.get("resident_hits", 0) > 0


# --------------------------------------------------------------------------
# steady state: warmed server compiles nothing
# --------------------------------------------------------------------------

def test_server_steady_state_zero_compiles(mixed):
    if getattr(batch_lib._svs_program, "_cache_size", None) is None:
        pytest.skip("this jax does not expose jit _cache_size — compile "
                    "accounting unavailable (would pass vacuously)")
    idx, queries, seq = mixed
    pool = source.ResidentPool()
    pool.warm(idx)
    results, srv = server_lib.serve_open_loop(
        idx, queries, qps=0.0, warmup=True, pool=pool, max_batch=8)
    wu = srv.warm_report
    assert wu["converged"] and wu["n_signatures"] > 0
    # drain mode after warmup: deterministic full batches, zero compiles
    assert srv.stats.get("n_compiles", 0) == 0
    _assert_identical(results, seq)
    # every flush was family-aligned (the sticky plan covered its groups
    # before fusion — the property that makes steady state compile-free)
    m = srv.metrics
    assert m.unaligned_flushes == 0
    assert m.aligned_flushes == m.n_flushes > 0


# --------------------------------------------------------------------------
# loop policies: flush reasons + backpressure
# --------------------------------------------------------------------------

def test_server_drain_mode_flushes_full_batches(mixed):
    idx, queries, seq = mixed                   # 32 queries
    results, srv = server_lib.serve_open_loop(idx, queries, qps=0.0,
                                              max_batch=8)
    m = srv.metrics
    assert m.flush_deadline == 0                # drain mode never deadlines
    assert m.flush_full + m.flush_drain == m.n_flushes == 4
    _assert_identical(results, seq)


def test_server_deadline_flush_fires(uniform):
    """Arrivals far slower than max_wait: every batch launches on the
    deadline (or the end-of-stream drain), never on max_batch."""
    idx, queries, seq = uniform
    results, srv = server_lib.serve_open_loop(
        idx, queries, qps=200.0, pattern="uniform", max_batch=32,
        max_wait_ms=0.5, max_queue=64)
    m = srv.metrics
    assert m.flush_full == 0
    assert m.flush_deadline >= 1
    assert srv.metrics.n_shed == 0
    _assert_identical(results, seq)


def test_server_bounded_queue_sheds(uniform):
    """Open-loop arrivals that find the queue full are shed and counted —
    submitting with no await between arrivals means the batcher never
    runs, so exactly max_queue requests are admitted."""
    idx, queries, seq = uniform
    many = queries * 4
    srv = server_lib.ContinuousBatchingServer(idx, max_batch=4, max_queue=4)
    results = asyncio.run(srv.run(many, [0.0] * len(many)))
    assert srv.metrics.n_shed == len(many) - 4
    served = [r for r in results if r is not None]
    assert len(served) == 4
    _assert_identical(served, seq[:4])          # first 4 arrivals admitted
    s = srv.metrics.summary()
    assert s["n_shed"] == len(many) - 4


# --------------------------------------------------------------------------
# unit: arrival processes, plan_covers, convergence flag
# --------------------------------------------------------------------------

def test_arrival_gaps_shapes():
    assert server_lib.arrival_gaps(5, 0.0) == [0.0] * 5
    assert server_lib.arrival_gaps(0, 100.0) == []
    u = server_lib.arrival_gaps(4, 100.0, "uniform")
    assert u == [0.01] * 4
    g = server_lib.arrival_gaps(2000, 100.0, "poisson", seed=1)
    assert all(x >= 0 for x in g)
    assert 0.005 < float(np.mean(g)) < 0.02     # mean ≈ 1/qps
    b = server_lib.arrival_gaps(16, 100.0, "bursty", seed=1, burst=8)
    assert all(x == 0.0 for x in b[1:8])        # within-burst: no gap
    assert all(x == 0.0 for x in b[9:16])
    with pytest.raises(ValueError):
        server_lib.arrival_gaps(4, 100.0, "sawtooth")


def test_plan_covers_predicate(mixed):
    """The admission predicate: an empty plan covers nothing; after one
    fused batch the sticky ceilings cover any narrower batch — checked
    *before* fuse_groups raises ceilings."""
    idx, queries, _ = mixed
    plan = batch_lib.FusionPlan()
    groups = batch_lib.schedule(idx, queries)
    assert not batch_lib.plan_covers(groups, plan)
    assert not batch_lib.plan_covers(groups, None)
    batch_lib.fuse_groups(dict(groups), plan=plan)
    sub = batch_lib.schedule(idx, queries[:3])
    assert batch_lib.plan_covers(sub, plan)
    assert batch_lib.plan_covers({}, plan)      # empty flush: nothing new


def test_warm_to_fixed_point_reports_convergence():
    calls = []

    def never_settles(stats):
        calls.append(1)
        stats.setdefault("signatures", set()).add(len(calls))

    n, passes, converged = batch_lib.warm_to_fixed_point(never_settles,
                                                         max_passes=3)
    assert passes == 3 and not converged and n == 3

    def settles(stats):
        stats.setdefault("signatures", set()).add(1)

    n, passes, converged = batch_lib.warm_to_fixed_point(settles)
    assert converged and n == 1 and passes == 2
