"""Continuous-batching server coverage (ISSUE 6).

Layers:
  * differential guarantee: served results == offline ``execute_batch`` on
    the same query multiset across {jax, pallas} × {fused, unfused} ×
    shards {1, 2}, in drain and live (open-loop) modes, under any arrival
    order,
  * steady state: a warmed server reports n_compiles == 0 (skipped — not
    vacuously passed — when jax lacks jit ``_cache_size``),
  * the three loop policies: family-aligned admission after warmup,
    deadline flush vs full flush, bounded-queue shedding,
  * unit coverage for the arrival processes, ``batch.plan_covers`` and the
    ``warm_to_fixed_point`` convergence flag.
"""

import asyncio

import numpy as np
import pytest

from repro.index import batch as batch_lib
from repro.index import builder, corpus as corpus_lib, engine, segments, \
    source
from repro.index import shard as shard_lib
from repro.launch import server as server_lib

pytestmark = pytest.mark.server


# --------------------------------------------------------------------------
# fixtures (mirrors tests/test_fusion.py)
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def uniform():
    corpus = corpus_lib.synthesize(n_docs=1 << 14, n_queries=10, seed=33)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="fastpfor-d1", B=16, n_parts=2)
    seq = [engine.query(idx, q) for q in corpus.queries]
    return idx, corpus.queries, seq


@pytest.fixture(scope="module")
def mixed():
    table = {k: corpus_lib.TABLE2_CLUEWEB[k] for k in (2, 3, 4, 5)}
    corpus = corpus_lib.synthesize(n_docs=1 << 14, n_queries=32, seed=11,
                                   table=table)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="fastpfor-d1", B=16, n_parts=2)
    seq = [engine.query(idx, q) for q in corpus.queries]
    return idx, corpus.queries, seq


def _assert_identical(results, seq):
    assert len(results) == len(seq)
    for got, want in zip(results, seq):
        assert got.count == want.count
        assert got.docs.dtype == want.docs.dtype
        assert np.array_equal(got.docs, want.docs)      # byte-identical



# --------------------------------------------------------------------------
# differential: served == offline, {backend} × {fuse} × {drain, live}
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jax", "pallas"])
@pytest.mark.parametrize("fuse", [True, False])
def test_server_matches_offline(uniform, backend, fuse):
    idx, queries, seq = uniform
    results, srv = server_lib.serve_open_loop(
        idx, queries, qps=0.0, backend=backend, fuse=fuse, max_batch=4)
    assert srv.metrics.n_shed == 0
    _assert_identical(results, seq)


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_server_live_load_matches_offline(uniform, backend):
    idx, queries, seq = uniform
    results, srv = server_lib.serve_open_loop(
        idx, queries, qps=2000.0, pattern="poisson", seed=3,
        backend=backend, max_batch=4, max_queue=1024, max_wait_ms=1.0)
    assert srv.metrics.n_shed == 0
    _assert_identical(results, seq)
    s = srv.metrics.summary()
    assert s["n_done"] == len(queries)
    assert s["p99_ms"] >= s["p50_ms"] > 0
    assert sum(s["queue_depth_hist"].values()) == len(queries)


@pytest.mark.parametrize("n_shards", [1, 2])
def test_server_sharded_matches_offline(uniform, n_shards):
    idx, queries, seq = uniform
    sharded = shard_lib.shard_index(idx, n_shards)
    results, srv = server_lib.serve_open_loop(
        idx, queries, qps=0.0, sharded=sharded, max_batch=4)
    _assert_identical(results, seq)


def test_server_arrival_order_independent(mixed):
    """Any packing of the same query multiset returns per-query results
    identical to the sequential engine."""
    idx, queries, seq = mixed
    perm = np.random.default_rng(9).permutation(len(queries))
    shuffled = [queries[i] for i in perm]
    results, _ = server_lib.serve_open_loop(idx, shuffled, qps=0.0,
                                            max_batch=8)
    for out_i, src_i in enumerate(perm):
        _assert_identical([results[out_i]], [seq[src_i]])


def test_server_pool_composes(uniform):
    idx, queries, seq = uniform
    pool = source.ResidentPool()
    pool.warm(idx)
    results, srv = server_lib.serve_open_loop(idx, queries, qps=0.0,
                                              pool=pool, max_batch=4)
    _assert_identical(results, seq)
    assert srv.stats.get("resident_hits", 0) > 0


# --------------------------------------------------------------------------
# steady state: warmed server compiles nothing
# --------------------------------------------------------------------------

def test_server_steady_state_zero_compiles(mixed):
    if getattr(batch_lib._svs_program, "_cache_size", None) is None:
        pytest.skip("this jax does not expose jit _cache_size — compile "
                    "accounting unavailable (would pass vacuously)")
    idx, queries, seq = mixed
    pool = source.ResidentPool()
    pool.warm(idx)
    results, srv = server_lib.serve_open_loop(
        idx, queries, qps=0.0, warmup=True, pool=pool, max_batch=8)
    wu = srv.warm_report
    assert wu["converged"] and wu["n_signatures"] > 0
    # drain mode after warmup: deterministic full batches, zero compiles
    assert srv.stats.get("n_compiles", 0) == 0
    _assert_identical(results, seq)
    # every flush was family-aligned (the sticky plan covered its groups
    # before fusion — the property that makes steady state compile-free)
    m = srv.metrics
    assert m.unaligned_flushes == 0
    assert m.aligned_flushes == m.n_flushes > 0


# --------------------------------------------------------------------------
# loop policies: flush reasons + backpressure
# --------------------------------------------------------------------------

def test_server_drain_mode_flushes_full_batches(mixed):
    idx, queries, seq = mixed                   # 32 queries
    results, srv = server_lib.serve_open_loop(idx, queries, qps=0.0,
                                              max_batch=8)
    m = srv.metrics
    assert m.flush_deadline == 0                # drain mode never deadlines
    assert m.flush_full + m.flush_drain == m.n_flushes == 4
    _assert_identical(results, seq)


def test_server_deadline_flush_fires(uniform):
    """Arrivals far slower than max_wait: every batch launches on the
    deadline (or the end-of-stream drain), never on max_batch."""
    idx, queries, seq = uniform
    results, srv = server_lib.serve_open_loop(
        idx, queries, qps=200.0, pattern="uniform", max_batch=32,
        max_wait_ms=0.5, max_queue=64)
    m = srv.metrics
    assert m.flush_full == 0
    assert m.flush_deadline >= 1
    assert srv.metrics.n_shed == 0
    _assert_identical(results, seq)


def test_server_bounded_queue_sheds(uniform):
    """Open-loop arrivals that find the queue full are shed and counted —
    submitting with no await between arrivals means the batcher never
    runs, so exactly max_queue requests are admitted."""
    idx, queries, seq = uniform
    many = queries * 4
    srv = server_lib.ContinuousBatchingServer(idx, max_batch=4, max_queue=4)
    results = asyncio.run(srv.run(many, [0.0] * len(many)))
    assert srv.metrics.n_shed == len(many) - 4
    served = [r for r in results if r is not None]
    assert len(served) == 4
    _assert_identical(served, seq[:4])          # first 4 arrivals admitted
    s = srv.metrics.summary()
    assert s["n_shed"] == len(many) - 4


# --------------------------------------------------------------------------
# unit: arrival processes, plan_covers, convergence flag
# --------------------------------------------------------------------------

def test_arrival_gaps_shapes():
    assert server_lib.arrival_gaps(5, 0.0) == [0.0] * 5
    assert server_lib.arrival_gaps(0, 100.0) == []
    u = server_lib.arrival_gaps(4, 100.0, "uniform")
    assert u == [0.01] * 4
    g = server_lib.arrival_gaps(2000, 100.0, "poisson", seed=1)
    assert all(x >= 0 for x in g)
    assert 0.005 < float(np.mean(g)) < 0.02     # mean ≈ 1/qps
    b = server_lib.arrival_gaps(16, 100.0, "bursty", seed=1, burst=8)
    assert all(x == 0.0 for x in b[1:8])        # within-burst: no gap
    assert all(x == 0.0 for x in b[9:16])
    with pytest.raises(ValueError):
        server_lib.arrival_gaps(4, 100.0, "sawtooth")


def test_plan_covers_predicate(mixed):
    """The admission predicate: an empty plan covers nothing; after one
    fused batch the sticky ceilings cover any narrower batch — checked
    *before* fuse_groups raises ceilings."""
    idx, queries, _ = mixed
    plan = batch_lib.FusionPlan()
    groups = batch_lib.schedule(idx, queries)
    assert not batch_lib.plan_covers(groups, plan)
    assert not batch_lib.plan_covers(groups, None)
    batch_lib.fuse_groups(dict(groups), plan=plan)
    sub = batch_lib.schedule(idx, queries[:3])
    assert batch_lib.plan_covers(sub, plan)
    assert batch_lib.plan_covers({}, plan)      # empty flush: nothing new


def test_warm_to_fixed_point_reports_convergence():
    calls = []

    def never_settles(stats):
        calls.append(1)
        stats.setdefault("signatures", set()).add(len(calls))

    n, passes, converged = batch_lib.warm_to_fixed_point(never_settles,
                                                         max_passes=3)
    assert passes == 3 and not converged and n == 3

    def settles(stats):
        stats.setdefault("signatures", set()).add(1)

    n, passes, converged = batch_lib.warm_to_fixed_point(settles)
    assert converged and n == 1 and passes == 2


# --------------------------------------------------------------------------
# live mutation (ISSUE 9): a MutableIndex behind the server
# --------------------------------------------------------------------------

def _mutable_setup(n_queries=16, seed=7):
    corpus = corpus_lib.synthesize(n_docs=1 << 13, n_queries=n_queries,
                                   seed=seed)
    mi = segments.MutableIndex.from_postings(
        corpus.postings, corpus.n_docs, codec_name="fastpfor-d1", B=16,
        n_parts=2)
    terms = sorted({t for q in corpus.queries for t in q})
    return mi, corpus, terms


def _compile_accounting_available():
    return getattr(batch_lib._svs_program, "_cache_size", None) is not None


def test_server_live_mutation_windows_match_offline():
    """Rounds of adds/deletes between Poisson serving windows: every
    window's served results equal offline ``MutableIndex.execute_batch``
    on the then-current state, at zero compiles once warmed — including
    across a seal + background-style merge (generation swap)."""
    mi, corpus, terms = _mutable_setup()
    stats: dict = {}
    srv = server_lib.ContinuousBatchingServer(
        mutable=mi, max_batch=4, max_wait_ms=1.0, max_queue=1024,
        stats=stats)
    wu = server_lib.warm_server(srv, corpus.queries)
    assert wu["converged"]
    check_compiles = _compile_accounting_available()
    rng = np.random.default_rng(2)

    def mutate(n_adds=20, n_dels=5):
        for _ in range(n_adds):
            k = int(rng.integers(1, min(4, len(terms)) + 1))
            doc = sorted(rng.choice(terms, size=k, replace=False).tolist())
            mi.add(doc)
        for _ in range(n_dels):
            mi.delete(int(rng.integers(0, mi.next_doc_id)))

    def window(seed, steady=True):
        stats.pop("n_compiles", None)
        gaps = server_lib.arrival_gaps(len(corpus.queries), 2000.0,
                                       "poisson", seed=seed)
        results = asyncio.run(srv.run(corpus.queries, gaps))
        assert srv.metrics.n_shed == 0
        offline = mi.execute_batch(corpus.queries)
        _assert_identical(results, offline)
        if steady and check_compiles:
            assert stats.get("n_compiles", 0) == 0

    # window 0 converges the plan: the AOT ladder warms contiguous chunk
    # packings, live Poisson packings are arbitrary subsets — the first
    # window raises the family ceilings over them, after which the sticky
    # plan covers ANY packing (the steady-state claim under test)
    mutate()
    window(seed=0, steady=False)
    for r in range(1, 3):
        mutate()
        window(seed=r)

    # generation swap: seal + merge pre-warmed through the *shared* sticky
    # plan; the first post-swap window must still compile nothing
    mutate()
    assert mi.seal() is not None
    assert mi.merge(warm_queries=corpus.queries) is True
    window(seed=99)
    assert mi.counters()["n_merges"] == 1


def test_server_mutations_between_flushes_under_poisson():
    """Mutations injected *between flushes* (at the server's snapshot
    seam) while Poisson traffic is in flight: each flush's served results
    must equal a python set-model oracle evaluated at that flush's
    snapshot — the per-flush byte-identity the windowed test can't see."""
    mi, corpus, terms = _mutable_setup()
    model = {t: set(corpus.postings[t].tolist()) for t in terms}
    dead: set[int] = set()
    rng = np.random.default_rng(4)

    # depth=1 serializes flush -> collect -> next flush, so the model is
    # stable from each snapshot through its finalize
    srv = server_lib.ContinuousBatchingServer(
        mutable=mi, max_batch=4, max_wait_ms=1.0, max_queue=1024, depth=1)
    server_lib.warm_server(srv, corpus.queries)

    muts = iter(range(64))

    def mutate_once():
        if next(muts, None) is None:
            return
        for _ in range(3):
            k = int(rng.integers(1, min(4, len(terms)) + 1))
            doc = sorted(rng.choice(terms, size=k, replace=False).tolist())
            gid = mi.add(doc)
            for t in doc:
                model[t].add(gid)
        d = int(rng.integers(0, mi.next_doc_id))
        mi.delete(d)
        dead.add(d)

    orig_snapshot = srv._snapshot

    def snapshot_with_mutation():
        mutate_once()
        return orig_snapshot()

    srv._snapshot = snapshot_with_mutation

    def oracle(q):
        alive = set.intersection(*[model[t] for t in q]) - dead
        return np.asarray(sorted(alive), dtype=np.int64)

    checked = []
    orig_finalize = mi.finalize

    def checking_finalize(snap, queries, results, max_results=1 << 16):
        out = orig_finalize(snap, queries, results, max_results)
        for q, r in zip(queries, out):
            want = oracle(q)
            assert r.count == want.size, (q, r.count, want.size)
            assert np.array_equal(r.docs, want)
            checked.append(1)
        return out

    mi.finalize = checking_finalize
    try:
        stream = corpus.queries * 3
        gaps = server_lib.arrival_gaps(len(stream), 1500.0, "poisson",
                                       seed=5)
        results = asyncio.run(srv.run(stream, gaps))
    finally:
        mi.finalize = orig_finalize
        srv._snapshot = orig_snapshot
    assert srv.metrics.n_shed == 0
    assert all(r is not None for r in results)
    assert len(checked) == len(stream)          # every request was checked
    assert mi.counters()["mutable_docs"] > 0    # mutations really landed
    assert mi.counters()["tombstones"] > 0


@pytest.mark.parametrize("n_shards", [1, 2])
def test_server_mutable_sharded_matches_offline(n_shards):
    mi, corpus, terms = _mutable_setup(n_queries=10, seed=21)
    if n_shards > 1:
        mi = segments.MutableIndex.from_postings(
            corpus.postings, corpus.n_docs, codec_name="fastpfor-d1",
            B=16, n_parts=2, n_shards=n_shards)
    rng = np.random.default_rng(8)
    for _ in range(15):
        k = int(rng.integers(1, min(4, len(terms)) + 1))
        mi.add(sorted(rng.choice(terms, size=k, replace=False).tolist()))
    for _ in range(4):
        mi.delete(int(rng.integers(0, mi.next_doc_id)))
    results, srv = server_lib.serve_open_loop(
        None, corpus.queries, qps=0.0, mutable=mi, max_batch=4)
    offline = mi.execute_batch(corpus.queries)
    _assert_identical(results, offline)


# --------------------------------------------------------------------------
# resolution audit (DESIGN.md §2.15): no request ever goes unresolved
# --------------------------------------------------------------------------

def test_server_every_request_resolves_with_explicit_outcome(uniform):
    """Shed, timed-out and served requests all finish with their ``done``
    event set and an explicit entry in ``outcomes()`` — no awaiter can
    hang, drain mode included.  Here the queue bound sheds most of the
    stream and a microscopic deadline expires the admitted rest."""
    idx, queries, _ = uniform
    many = queries * 4
    srv = server_lib.ContinuousBatchingServer(
        idx, max_batch=4, max_queue=4, timeout_ms=1e-4)
    results = asyncio.run(srv.run(many, [0.0] * len(many)))
    outs = srv.outcomes()
    assert len(outs) == len(many)
    assert "pending" not in outs
    assert outs.count("shed") == len(many) - 4
    assert outs.count("timeout") == 4           # every admitted one expired
    assert all(r is None for r in results)
    assert all(r is None or r.done.is_set() for r in srv.requests)
    s = srv.metrics.summary()
    assert s["n_timeout"] == 4 and s["n_shed"] == len(many) - 4


def test_server_generous_timeout_serves_everything(uniform):
    """A deadline far above service time must change nothing: all done,
    byte-identical, zero timeout outcomes."""
    idx, queries, seq = uniform
    results, srv = server_lib.serve_open_loop(
        idx, queries, qps=0.0, max_batch=4, timeout_ms=60_000.0)
    assert srv.outcomes() == ["done"] * len(queries)
    assert srv.metrics.n_timeout == 0
    _assert_identical(results, seq)
