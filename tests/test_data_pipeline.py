"""Data substrate: ClusterData properties, compressed CSR, sampler validity,
compressed shuffle-index and history stores (the paper's codec applied to the
framework substrate)."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bitpack
from repro.data import graph_data, lm_data, recsys_data
from repro.data.clusterdata import clusterdata, delta_entropy, paired_lists
from repro.models.gnn import sample_neighbors


def test_clusterdata_properties(rng):
    for bits in (19, 26, 30):
        x = clusterdata(rng, 65536, bits)
        assert np.all(np.diff(x) > 0)
        assert x[0] >= 0 and x[-1] < (1 << bits)
    # dense lists have lower delta entropy than sparse (paper Table 3)
    h_dense = delta_entropy(clusterdata(rng, 65536, 19))
    h_sparse = delta_entropy(clusterdata(rng, 65536, 30))
    assert h_dense < h_sparse


def test_paired_lists_overlap(rng):
    r, f = paired_lists(rng, 3000, 100000)
    inter = np.intersect1d(r, f)
    assert len(inter) >= 3000 // 3 - 1


def test_compressed_csr_roundtrip(rng):
    g = graph_data.synthetic_graph(5000, 12, seed=1)
    cc = graph_data.CompressedCSR.compress(g["indptr"], g["indices"], 5000)
    assert np.array_equal(cc.decompress(), g["indices"])
    assert cc.bits_per_edge() < 32


def test_sampler_validity(rng):
    g = graph_data.synthetic_graph(3000, 10, seed=2)
    indptr = jnp.asarray(g["indptr"])
    indices = jnp.asarray(g["indices"])
    seeds = jnp.asarray(rng.integers(0, 3000, size=256).astype(np.int32))
    nbrs = np.asarray(sample_neighbors(jax.random.PRNGKey(0), indptr,
                                       indices, seeds, 7))
    assert nbrs.shape == (256, 7)
    ip, ix = g["indptr"], g["indices"]
    for i, s in enumerate(np.asarray(seeds)):
        deg = ip[s + 1] - ip[s]
        valid = set(ix[ip[s]: ip[s + 1]]) if deg else {s}
        assert set(nbrs[i]) <= valid


def test_shuffle_index_compressed(rng):
    order, packed = lm_data.make_shuffle_index(10000, epoch=3)
    assert sorted(order.tolist()) == list(range(10000))
    assert np.array_equal(bitpack.decode_np(packed), np.arange(10000))
    assert bitpack.bits_per_int(packed) < 2.0     # deltas are ~1


def test_history_store_compression(rng):
    hists = [np.sort(rng.choice(1 << 20, size=rng.integers(10, 400),
                                replace=False)) for _ in range(50)]
    packed, bits = recsys_data.compress_histories(list(hists))
    from repro.core import varint
    for (kind, p), h in zip(packed, hists):
        got = varint.decode(p) if kind == "varint" else bitpack.decode_np(p)
        assert np.array_equal(got, np.unique(h))
    assert bits < 32


def test_token_stream_learnable():
    ts = lm_data.TokenStream(vocab=64, seed=0)
    b = ts.batch(8, 32)
    assert b["tokens"].shape == (8, 32)
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()
