"""Flash-attention Pallas kernel vs the jnp oracles, swept over GQA ratios,
block shapes, causal/full and ragged kv lengths (interpret mode)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models import layers as L


def _ref(q, k, v, causal, kv_len):
    H, Hkv = q.shape[2], k.shape[2]
    kk = jnp.repeat(k, H // Hkv, 2)
    vv = jnp.repeat(v, H // Hkv, 2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / np.sqrt(q.shape[-1])
    if causal:
        qp = jnp.arange(q.shape[1])[:, None]
        kp = jnp.arange(k.shape[1])[None, :]
        s = jnp.where(qp >= kp, s, -1e30)
    if kv_len is not None:
        s = jnp.where(jnp.arange(k.shape[1])[None, None, None, :] < kv_len,
                      s, -1e30)
    return jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vv)


CASES = [
    # B, Sq, Sk, H, Hkv, D, causal, kv_len, bq, bk
    (2, 256, 256, 4, 2, 64, True, None, 128, 128),
    (1, 512, 512, 8, 8, 128, True, None, 256, 256),
    (2, 256, 512, 4, 1, 64, False, 450, 128, 128),
    (1, 128, 1024, 2, 2, 256, False, None, 128, 512),
    (1, 256, 256, 4, 4, 64, True, 200, 64, 64),
]


@pytest.mark.parametrize("case", CASES)
def test_flash_matches_reference(case):
    B, Sq, Sk, H, Hkv, D, causal, kv_len, bq, bk = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, D), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, kv_len=kv_len, bq=bq, bk=bk)
    ref = _ref(q, k, v, causal, kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=2e-5)


def test_flash_matches_chunked_library_path():
    """Kernel ≡ the jnp online-softmax path used by prefill."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 512, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (2, 512, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (2, 512, 2, 64), jnp.float32)
    a = flash_attention(q, k, v, causal=True, bq=128, bk=128)
    b = L.attention_chunked(q, k, v, chunk=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)


def test_flash_bf16_io():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 256, 2, 64)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 256, 2, 64)).astype(jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, bq=128, bk=128)
    assert out.dtype == jnp.bfloat16
    ref = _ref(q.astype(jnp.float32), k.astype(jnp.float32),
               v.astype(jnp.float32), True, None)
    np.testing.assert_allclose(np.asarray(out, dtype=np.float32),
                               np.asarray(ref), rtol=0.05, atol=0.05)
