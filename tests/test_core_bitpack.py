"""Codec round-trip + compression-ratio invariants (paper §3–4)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import bitpack, codecs, varint, fastpfor
from repro.core.deltas import MODES, prefix_sum_ops_per_int

ALL_MODES = [m for m in MODES if m != "none"]


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("n", [0, 1, 127, 4096, 4097, 12800])
def test_roundtrip_sizes(mode, n, rng):
    gaps = rng.integers(1, 100, size=n)
    x = np.cumsum(gaps)
    pl = bitpack.encode(x, mode=mode)
    assert np.array_equal(bitpack.decode_np(pl), x)


@pytest.mark.parametrize("mode", ALL_MODES)
def test_roundtrip_wide_values(mode, rng):
    x = np.sort(rng.choice(2**31 - 2, size=8192, replace=False))
    pl = bitpack.encode(x, mode=mode)
    assert np.array_equal(bitpack.decode_np(pl), x)


@pytest.mark.parametrize("mode", ALL_MODES)
def test_roundtrip_block_rows_8(mode, rng):
    x = np.cumsum(rng.integers(1, 9, size=3000))
    pl = bitpack.encode(x, mode=mode, block_rows=8)
    assert np.array_equal(bitpack.decode_np(pl), x)


def test_ni_equals_integrated(rng):
    x = np.cumsum(rng.integers(1, 1000, size=9000))
    pl = bitpack.encode(x, mode="d2")
    a = np.asarray(bitpack.decode(pl))
    b = np.asarray(bitpack.decode_ni(pl))
    assert np.array_equal(a, b)


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_property_roundtrip_all_codecs(data):
    """Any strictly increasing uint31 list round-trips through any codec."""
    r = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    n = data.draw(st.integers(1, 6000))
    mode = data.draw(st.sampled_from(ALL_MODES))
    fam = data.draw(st.sampled_from(["bp", "fastpfor", "varint"]))
    heavy_tail = data.draw(st.booleans())
    if heavy_tail:
        gaps = np.where(r.random(n) < 0.05,
                        r.integers(1, 1 << 20, n), r.integers(1, 8, n))
    else:
        gaps = r.integers(1, 64, n)
    x = np.cumsum(gaps)
    name = "varint" if fam == "varint" else f"{fam}-{mode}"
    c = codecs.get_codec(name)
    enc = c.encode(x)
    assert np.array_equal(c.decode_np(enc), x)


def test_compression_ordering_dense(rng):
    """Paper Table 3 structure: d1 ≤ d2 ≤ d4 ≤ dm ≈ dv on small-gap data."""
    x = np.cumsum(rng.integers(1, 8, size=65536))
    bits = {m: bitpack.bits_per_int(bitpack.encode(x, mode=m))
            for m in ["d1", "d2", "d4", "dm", "dv"]}
    assert bits["d1"] <= bits["d2"] <= bits["d4"] <= bits["dm"] + 1e-9
    assert bits["dm"] <= bits["dv"] + 1e-9


def test_fastpfor_beats_bp_on_outliers(rng):
    """Patching wins exactly where the paper says it does."""
    gaps = np.where(rng.random(65536) < 0.01,
                    rng.integers(1, 100000, 65536), rng.integers(1, 4, 65536))
    x = np.cumsum(gaps)
    bp_bits = bitpack.bits_per_int(bitpack.encode(x, mode="d1"))
    pf = fastpfor.encode(x, mode="d1")
    assert np.array_equal(fastpfor.decode_np(pf), x)
    assert fastpfor.bits_per_int(pf) < bp_bits * 0.6


def test_varint_small_gaps_one_byte(rng):
    x = np.cumsum(rng.integers(1, 100, size=10000))       # gaps < 2**7
    vl = varint.encode(x)
    assert abs(varint.bits_per_int(vl) - 8.0) < 0.2
    assert np.array_equal(varint.decode(vl), x)


def test_prefix_sum_cost_model_monotone():
    """Table 1 analogue: wider stride → fewer ops/int."""
    costs = [prefix_sum_ops_per_int(m) for m in ["d1", "d2", "d4", "dm", "dv"]]
    assert costs == sorted(costs, reverse=True)
    assert costs[-1] < 0.01           # dv ≈ free at lane width 128
