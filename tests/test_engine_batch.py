"""Differential coverage for the query engine and the batched scheduler.

Three layers (ISSUE 1):
  * DecodeCache size accounting regression (re-putting a key must not drift),
  * randomized corpora (codec × part-count × list/bitmap mixes) asserting
    ``engine.query`` == ``brute_force``,
  * batched-vs-sequential equivalence: ``batch.execute_batch`` must return
    byte-identical counts and doc ids for every query, on both backends.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.index import batch as batch_lib
from repro.index import builder, corpus as corpus_lib, engine


# --------------------------------------------------------------------------
# DecodeCache regression
# --------------------------------------------------------------------------

def test_decode_cache_reput_size_stable():
    cache = engine.DecodeCache(capacity_ints=1 << 20)
    vals = jnp.zeros((256,), jnp.int32)
    for _ in range(5):
        cache.put("k", vals, 200)
    assert cache._size == 256            # was 5×256 before the fix
    bigger = jnp.zeros((512,), jnp.int32)
    cache.put("k", bigger, 400)
    assert cache._size == 512
    assert cache.get("k")[1] == 400


def test_decode_cache_reput_does_not_evict_prematurely():
    cache = engine.DecodeCache(capacity_ints=1024)
    a = jnp.zeros((400,), jnp.int32)
    b = jnp.zeros((400,), jnp.int32)
    cache.put("a", a, 400)
    cache.put("b", b, 400)
    for _ in range(10):                  # drifting _size used to evict here
        cache.put("a", a, 400)
    assert cache.get("b") is not None
    assert cache._size == 800


def test_decode_cache_distinct_across_rebuilds():
    """Cache keys are part-uid based: rebuilding an index must not share
    (or collide with) entries from a previous build."""
    corpus = corpus_lib.synthesize(n_docs=1 << 12, n_queries=3, seed=9)
    cache = engine.DecodeCache(capacity_ints=1 << 24)
    q = corpus.queries[0]
    idx1 = builder.build(corpus.postings, corpus.n_docs,
                         codec_name="bp-d1", B=0, n_parts=1)
    a = engine.query(idx1, q, cache=cache)
    n_entries = len(cache._store)
    assert n_entries > 0
    idx2 = builder.build(corpus.postings, corpus.n_docs,
                         codec_name="bp-d1", B=0, n_parts=1)
    b = engine.query(idx2, q, cache=cache)
    assert len(cache._store) == 2 * n_entries      # no key collisions
    assert a.count == b.count
    assert np.array_equal(a.docs, b.docs)


# --------------------------------------------------------------------------
# randomized differential matrix
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_corpus():
    return corpus_lib.synthesize(n_docs=1 << 14, n_queries=12, seed=21)


@pytest.mark.parametrize("codec,B,n_parts", [
    ("bp-d1", 0, 1),            # pure compressed lists, single part
    ("bp-dv", 8, 2),            # wide-stride deltas + some bitmaps
    ("fastpfor-d1", 16, 2),     # patched codec + bitmap mix
    ("fastpfor-d1", 64, 3),     # bitmap-heavy (all-bitmap queries appear)
    ("varint", 32, 3),          # tail codec everywhere
])
def test_engine_matches_bruteforce(small_corpus, codec, B, n_parts):
    idx = builder.build(small_corpus.postings, small_corpus.n_docs,
                        codec_name=codec, B=B, n_parts=n_parts)
    for q in small_corpus.queries:
        got = engine.query(idx, q)
        expect = engine.brute_force(small_corpus.postings, q)
        assert got.count == len(expect)
        assert np.array_equal(np.sort(got.docs), expect[: len(got.docs)])


@pytest.mark.parametrize("seed", [1, 2, 3])
def test_engine_matches_bruteforce_random_seeds(seed):
    corpus = corpus_lib.synthesize(n_docs=1 << 13, n_queries=6, seed=seed)
    rng = np.random.default_rng(seed)
    codec = rng.choice(["bp-d1", "bp-d2", "fastpfor-d1", "varint"])
    B = int(rng.choice([0, 8, 32]))
    n_parts = int(rng.choice([1, 2, 4]))
    idx = builder.build(corpus.postings, corpus.n_docs, codec_name=codec,
                        B=B, n_parts=n_parts)
    for q in corpus.queries:
        got = engine.query(idx, q)
        expect = engine.brute_force(corpus.postings, q)
        assert got.count == len(expect), (codec, B, n_parts)
        assert np.array_equal(np.sort(got.docs), expect[: len(got.docs)])


# --------------------------------------------------------------------------
# batched vs sequential equivalence
# --------------------------------------------------------------------------

@pytest.mark.parametrize("codec,B,n_parts", [
    ("bp-d1", 0, 1),
    ("fastpfor-d1", 16, 2),
    ("fastpfor-d1", 64, 3),     # includes all-bitmap groups
    ("varint", 32, 3),
])
def test_batched_matches_sequential(small_corpus, codec, B, n_parts):
    idx = builder.build(small_corpus.postings, small_corpus.n_docs,
                        codec_name=codec, B=B, n_parts=n_parts)
    stats = {}
    batched = batch_lib.execute_batch(idx, small_corpus.queries, stats=stats)
    assert len(batched) == len(small_corpus.queries)
    assert stats["n_items"] > 0
    for q, br in zip(small_corpus.queries, batched):
        sr = engine.query(idx, q)
        assert sr.count == br.count
        assert br.docs.dtype == sr.docs.dtype
        assert np.array_equal(sr.docs, br.docs)      # byte-identical


def test_batched_pallas_backend_matches(small_corpus):
    idx = builder.build(small_corpus.postings, small_corpus.n_docs,
                        codec_name="fastpfor-d1", B=16, n_parts=2)
    queries = small_corpus.queries[:6]
    batched = batch_lib.execute_batch(idx, queries, backend="pallas")
    for q, br in zip(queries, batched):
        sr = engine.query(idx, q)
        assert sr.count == br.count
        assert np.array_equal(sr.docs, br.docs)


def test_batched_with_cache_matches(small_corpus):
    idx = builder.build(small_corpus.postings, small_corpus.n_docs,
                        codec_name="fastpfor-d1", B=16, n_parts=2)
    cache = engine.DecodeCache(capacity_ints=1 << 24)
    for _ in range(2):                   # second pass served from cache
        batched = batch_lib.execute_batch(idx, small_corpus.queries,
                                          cache=cache)
        for q, br in zip(small_corpus.queries, batched):
            sr = engine.query(idx, q)
            assert sr.count == br.count
            assert np.array_equal(sr.docs, br.docs)
    assert len(cache._store) > 0


def test_batched_grouping_amortizes_programs(small_corpus):
    """The scheduler must fuse work: device programs < work items."""
    idx = builder.build(small_corpus.postings, small_corpus.n_docs,
                        codec_name="fastpfor-d1", B=16, n_parts=2)
    stats = {}
    batch_lib.execute_batch(idx, small_corpus.queries, stats=stats)
    assert stats["n_programs"] <= stats["n_items"]
    assert stats["n_programs"] == stats["n_groups"]  # no chunk overflow here


def test_batched_respects_max_group_size(small_corpus):
    idx = builder.build(small_corpus.postings, small_corpus.n_docs,
                        codec_name="fastpfor-d1", B=16, n_parts=2)
    stats = {}
    batched = batch_lib.execute_batch(idx, small_corpus.queries,
                                      max_group_size=1, stats=stats)
    assert stats["n_programs"] == stats["n_items"]
    for q, br in zip(small_corpus.queries, batched):
        sr = engine.query(idx, q)
        assert sr.count == br.count
        assert np.array_equal(sr.docs, br.docs)


def test_engine_kernel_backend_matches(small_corpus):
    """USE_KERNELS routes big-ratio folds through the Pallas gallop kernel."""
    idx = builder.build(small_corpus.postings, small_corpus.n_docs,
                        codec_name="fastpfor-d1", B=16, n_parts=2)
    queries = small_corpus.queries[:4]
    baseline = [engine.query(idx, q) for q in queries]
    engine.USE_KERNELS = True
    try:
        kerneled = [engine.query(idx, q) for q in queries]
    finally:
        engine.USE_KERNELS = False
    for a, b in zip(baseline, kerneled):
        assert a.count == b.count
        assert np.array_equal(a.docs, b.docs)
