"""Intersection algorithms vs the scalar oracle (paper §5)."""

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import bitmap, bitpack
from repro.core import intersect as its


def _pair(rng, m, n, overlap=0.34):
    inter = np.sort(rng.choice(2**26, size=max(int(m * overlap), 1),
                               replace=False))
    r = np.union1d(inter, rng.choice(2**26, size=m, replace=False))
    f = np.union1d(inter, rng.choice(2**26, size=n, replace=False))
    return r.astype(np.int64), f.astype(np.int64)


def _run(fn, r, f):
    M = its.pow2_bucket(len(r))
    N = its.pow2_bucket(len(f), floor=1024)
    rp, fp = jnp.asarray(its.pad_to(r, M)), jnp.asarray(its.pad_to(f, N))
    mask = fn(rp, fp)
    vals, cnt = its.compact(rp, mask)
    return np.asarray(vals)[: int(cnt)]


@pytest.mark.parametrize("m,n", [(10, 10), (128, 128), (100, 3000),
                                 (1000, 64000), (7, 200000)])
def test_gallop_and_tiled_match_oracle(m, n, rng):
    r, f = _pair(rng, m, n)
    expect = its.intersect_ref(r, f)
    assert np.array_equal(_run(its.intersect_gallop, r, f), expect)
    assert np.array_equal(_run(its.intersect_tiled, r, f), expect)


def test_auto_dispatch(rng):
    r, f = _pair(rng, 100, 100000)
    expect = its.intersect_ref(r, f)
    M, N = its.pow2_bucket(len(r)), its.pow2_bucket(len(f), floor=1024)
    rp, fp = jnp.asarray(its.pad_to(r, M)), jnp.asarray(its.pad_to(f, N))
    mask = its.intersect_auto(rp, fp, len(r), len(f))
    vals, cnt = its.compact(rp, mask)
    assert np.array_equal(np.asarray(vals)[: int(cnt)], expect)


def test_packed_gallop_block_skip(rng):
    """Galloping over a *compressed* list via the block-max skip index."""
    r, f = _pair(rng, 300, 500000)
    expect = its.intersect_ref(r, f)
    for mode in ["d1", "dv"]:
        pf = bitpack.encode(f, mode=mode)
        rp = jnp.asarray(its.pad_to(r, its.pow2_bucket(len(r))))
        mask = its.intersect_packed(rp, pf)
        vals, cnt = its.compact(rp, mask)
        assert np.array_equal(np.asarray(vals)[: int(cnt)], expect)


def test_disjoint_and_identical(rng):
    a = np.arange(0, 20000, 2, dtype=np.int64)
    b = np.arange(1, 20001, 2, dtype=np.int64)
    assert len(_run(its.intersect_gallop, a, b)) == 0
    assert np.array_equal(_run(its.intersect_gallop, a, a), a)
    assert np.array_equal(_run(its.intersect_tiled, a, a), a)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31), st.integers(1, 3000), st.integers(1, 30000))
def test_property_intersection(seed, m, n):
    rng = np.random.default_rng(seed)
    r, f = _pair(rng, m, n)
    expect = np.intersect1d(r, f)
    assert np.array_equal(_run(its.intersect_gallop, r, f), expect)
    assert np.array_equal(_run(its.intersect_tiled, r, f), expect)


def test_svs_fold_batch_with_and_without_active_mask(rng):
    """Batch-axis fused fold (index/batch.py substrate): both the plain and
    the arity-merged (fold_active) scan bodies must match the oracle."""
    M, N = 256, 1024
    rows, folds0, folds1, expect_full, expect_one = [], [], [], [], []
    for _ in range(3):
        r, f0 = _pair(rng, 150, 700)
        _, f1 = _pair(rng, 150, 700)
        rows.append(its.pad_to(r, M))
        folds0.append(its.pad_to(f0, N))
        folds1.append(its.pad_to(f1, N))
        expect_one.append(its.intersect_ref(r, f0))
        expect_full.append(its.intersect_ref(expect_one[-1], f1))
    R = jnp.asarray(np.stack(rows))
    F = jnp.asarray(np.stack([np.stack(folds0), np.stack(folds1)]))

    out, cnt = its.svs_fold_batch(R, F, algo="gallop")
    for b in range(3):
        assert np.array_equal(np.asarray(out)[b, : int(cnt[b])],
                              expect_full[b])
    out, cnt = its.svs_fold_batch(R, F, algo="tiled")
    for b in range(3):
        assert np.array_equal(np.asarray(out)[b, : int(cnt[b])],
                              expect_full[b])

    # arity merge: row 2 deactivates the second fold and must pass through
    active = jnp.asarray(np.array([[True, True, True],
                                   [True, True, False]]))
    out, cnt = its.svs_fold_batch(R, F, algo="gallop", fold_active=active)
    for b, expect in enumerate([expect_full[0], expect_full[1],
                                expect_one[2]]):
        assert np.array_equal(np.asarray(out)[b, : int(cnt[b])], expect)


def test_bitmap_ops(rng):
    r, f = _pair(rng, 400, 30000)
    bm = bitmap.build_np(f, 2**26)
    assert int(bitmap.popcount(jnp.asarray(bm))) == len(f)
    assert np.array_equal(bitmap.extract_np(bm), f.astype(np.int32))
    rp = jnp.asarray(its.pad_to(r, its.pow2_bucket(len(r))))
    mask = bitmap.to_mask_over(rp, jnp.asarray(bm))
    vals, cnt = its.compact(rp, mask)
    assert np.array_equal(np.asarray(vals)[: int(cnt)],
                          its.intersect_ref(r, f))
    # bitmap ∧ bitmap count
    bm_r = bitmap.build_np(r, 2**26)
    assert int(bitmap.intersect_count(jnp.asarray(bm), jnp.asarray(bm_r))) \
        == len(its.intersect_ref(r, f))
