"""Flag-coercion coverage for the serving launcher (ISSUE 6 bugfix).

``serve.py`` historically rewrote flag combinations silently (``--shards``
turned ``--batch 1`` into 32 and dropped ``--cache`` with only a partial
note); ``coerce_index_flags`` now makes every implied rewrite an explicit
warning.  These tests pin the coercion table."""

import argparse

from repro.launch.serve import coerce_index_flags


def _ns(**kw):
    base = dict(batch=0, pipeline=0, shards=0, resident=False, fuse=True,
                warmup=False, cache=False, queries=20, backend="jax",
                shared_vocab=False, tokens=16, mutate=0, delete_frac=None,
                wal=None, chaos=None, timeout_ms=None, qps=0.0, seed=0)
    base.update(kw)
    return argparse.Namespace(**base)


def test_plain_flags_pass_through_unwarned():
    a = _ns(batch=64, pipeline=2, resident=True)
    assert coerce_index_flags(a) == []
    assert a.batch == 64 and a.pipeline == 2 and a.resident


def test_sequential_mode_untouched():
    a = _ns()
    assert coerce_index_flags(a) == []
    assert a.batch == 0 and not a.resident


def test_shards_coerces_batch_pipeline_resident():
    a = _ns(shards=2)
    w = coerce_index_flags(a)
    assert a.batch == 32 and a.pipeline == 2 and a.resident
    assert len(w) == 3
    assert any("--batch" in m for m in w)
    assert any("--pipeline" in m for m in w)
    assert any("--resident" in m for m in w)


def test_shards_ignores_cache_with_warning():
    a = _ns(shards=2, batch=64, pipeline=4, resident=True, cache=True)
    w = coerce_index_flags(a)
    assert not a.cache
    assert len(w) == 1 and "--cache" in w[0]
    assert a.batch == 64 and a.pipeline == 4      # explicit values kept


def test_pipeline_implies_batched_and_resident():
    a = _ns(pipeline=2)
    w = coerce_index_flags(a)
    assert a.batch == 32 and a.resident
    assert len(w) == 2


def test_pipeline_with_explicit_batch_keeps_it():
    a = _ns(pipeline=3, batch=16, resident=True)
    assert coerce_index_flags(a) == []
    assert a.batch == 16 and a.pipeline == 3


def test_warmup_without_fuse_warns():
    a = _ns(batch=8, warmup=True, fuse=False)
    w = coerce_index_flags(a)
    assert len(w) == 1 and "--no-fuse" in w[0]


def test_warmup_with_fuse_silent():
    a = _ns(batch=8, warmup=True)
    assert coerce_index_flags(a) == []


def test_mutate_implies_batched_and_resident():
    a = _ns(mutate=100)
    w = coerce_index_flags(a)
    assert a.batch == 32 and a.resident
    assert len(w) == 2
    assert any("--batch" in m for m in w)
    assert any("--resident" in m for m in w)


def test_mutate_drops_pipeline_and_cache_with_warnings():
    a = _ns(mutate=100, batch=16, resident=True, pipeline=2, cache=True)
    w = coerce_index_flags(a)
    assert a.pipeline == 0 and not a.cache
    assert len(w) == 2
    assert any("--pipeline" in m for m in w)
    assert any("--cache" in m for m in w)
    assert a.batch == 16                          # explicit value kept


def test_mutate_with_explicit_flags_silent():
    a = _ns(mutate=100, batch=16, resident=True, delete_frac=0.2)
    assert coerce_index_flags(a) == []
    assert a.delete_frac == 0.2


def test_delete_frac_without_mutate_warns_and_clears():
    a = _ns(batch=8, delete_frac=0.5)
    w = coerce_index_flags(a)
    assert len(w) == 1 and "--delete-frac" in w[0]
    assert a.delete_frac is None


def test_mutate_composes_with_shards_unwarned():
    """--mutate handles sharding itself (per-generation ShardedIndex), so
    --shards adds none of its frozen-path coercions on top."""
    a = _ns(mutate=100, batch=16, resident=True, shards=2)
    assert coerce_index_flags(a) == []
    assert a.pipeline == 0 and a.shards == 2


# -- durability / chaos / live-traffic coercions (DESIGN.md §2.15) ----------

def test_wal_implies_mutate():
    a = _ns(wal="/tmp/w", batch=16, resident=True)
    w = coerce_index_flags(a)
    assert a.mutate == 256
    assert any("--wal implies" in m for m in w)


def test_wal_with_explicit_mutate_silent():
    a = _ns(wal="/tmp/w", mutate=64, batch=16, resident=True)
    assert coerce_index_flags(a) == []
    assert a.mutate == 64


def test_chaos_without_wal_warns_but_keeps_spec():
    a = _ns(chaos="transient@launch:0.1", batch=8)
    w = coerce_index_flags(a)
    assert a.chaos == "transient@launch:0.1"    # seam faults still valid
    assert len(w) == 1 and "--chaos without --wal" in w[0]


def test_chaos_with_wal_unwarned():
    a = _ns(chaos="crash@wal.append.add:5", wal="/tmp/w", mutate=64,
            batch=16, resident=True)
    assert coerce_index_flags(a) == []


def test_timeout_without_qps_warns_and_clears():
    a = _ns(timeout_ms=50.0, batch=8)
    w = coerce_index_flags(a)
    assert a.timeout_ms is None
    assert len(w) == 1 and "--timeout-ms" in w[0]


def test_timeout_with_qps_kept():
    a = _ns(timeout_ms=50.0, qps=500.0, batch=16)
    assert coerce_index_flags(a) == []
    assert a.timeout_ms == 50.0


def test_qps_coerces_batch_and_drops_pipeline_and_shards():
    a = _ns(qps=500.0, pipeline=2, shards=2)
    w = coerce_index_flags(a)
    assert a.batch == 32 and a.pipeline == 0 and a.shards == 0
    assert len(w) == 3
    assert any("--pipeline" in m for m in w)
    assert any("--shards" in m for m in w)
    assert any("--batch" in m for m in w)


def test_qps_mutate_with_explicit_flags_silent():
    a = _ns(qps=500.0, mutate=64, batch=16, resident=True,
            timeout_ms=100.0)
    assert coerce_index_flags(a) == []
