"""Property-based op-sequence differential harness for the mutable
segmented index (DESIGN.md §2.14).

Generated interleavings of add/delete/query/seal/merge run against a
``segments.MutableIndex`` and are checked **byte-identical** against a
rebuild-from-scratch oracle: the live corpus (tracked by a plain python
model) rebuilt with ``builder.build`` and queried through the sequential
``engine.query`` reference.  Identity must hold at every generated query
point *and* over a fixed probe set at the end of every sequence, across
{jax, pallas} × {fused, unfused} × shards {1, 2}.

With real hypothesis installed (CI) the sequences shrink and the seed is
pinned via ``--hypothesis-seed``; on clean machines the deterministic
fallback engine in ``_hypothesis_compat`` runs the same properties from
``REPRO_PROP_SEED``.
"""

import tempfile

import numpy as np
import pytest

from _hypothesis_compat import (HAVE_HYPOTHESIS, HealthCheck, given,
                                settings, st)
from repro.index import builder, durability, engine, segments
from repro.launch import faults

pytestmark = pytest.mark.segments

V = 6                       # term universe
CODEC = "bp-d1"             # sealed segments: bitpacked (+ varint tail)
B = 16                      # bitmap threshold: dense lists go bitmap


def _term_set():
    return st.lists(st.integers(0, V - 1), min_size=1, max_size=3,
                    unique=True)


# weighted toward adds so sequences grow a corpus worth querying; delete
# carries a raw index resolved modulo the live-doc count at apply time
OPS = st.lists(
    st.one_of(
        st.tuples(st.just("add"), _term_set()),
        st.tuples(st.just("add"), _term_set()),
        st.tuples(st.just("add"), _term_set()),
        st.tuples(st.just("delete"), st.integers(0, 1 << 20)),
        st.tuples(st.just("query"), _term_set()),
        st.tuples(st.just("seal"), st.just(0)),
        st.tuples(st.just("merge"), st.just(0)),
    ),
    min_size=5, max_size=30)

PROBES = ([[t] for t in range(V)]
          + [[0, 1], [2, 3], [1, 4, 5], [0, 1, 2], [3, 5]])

# durable-harness alphabet additions: a crash op arms one registered
# fault (any crash point, or a torn WAL tail), drives an op stream at it,
# then recovers from the WAL directory — the model keeps only
# acknowledged ops, so recovery must land exactly on it
FAULTS = ([("crash", p) for p in faults.CRASH_POINTS]
          + [("torn", p) for p in faults.TEAR_POINTS])

OPS_CRASH = st.lists(
    st.one_of(
        st.tuples(st.just("add"), _term_set()),
        st.tuples(st.just("add"), _term_set()),
        st.tuples(st.just("add"), _term_set()),
        st.tuples(st.just("delete"), st.integers(0, 1 << 20)),
        st.tuples(st.just("query"), _term_set()),
        st.tuples(st.just("seal"), st.just(0)),
        st.tuples(st.just("merge"), st.just(0)),
        st.tuples(st.just("crash"), st.integers(0, 1 << 20)),
        st.tuples(st.just("crash"), st.integers(0, 1 << 20)),
    ),
    min_size=6, max_size=24)


def _oracle(model: dict, n_docs: int):
    """Rebuild the live corpus from scratch — the differential reference."""
    post = [np.asarray(sorted(d for d, ts in model.items() if t in ts),
                       dtype=np.int64) for t in range(V)]
    return builder.build(post, max(n_docs, 1), codec_name=CODEC, B=B,
                         n_parts=2)


def _check(mi: segments.MutableIndex, model: dict, queries, *,
           backend: str, fuse: bool):
    got = mi.execute_batch([list(q) for q in queries], backend=backend,
                           fuse=fuse)
    idx = _oracle(model, mi.next_doc_id)
    for q, g in zip(queries, got):
        w = engine.query(idx, list(q))
        assert g.count == w.count, (q, g.count, w.count)
        assert np.array_equal(g.docs, w.docs), (q, g.docs, w.docs)
        assert g.docs.dtype == w.docs.dtype == np.int64


def _run_sequence(ops, *, backend: str, fuse: bool, n_shards: int):
    mi = segments.MutableIndex(codec_name=CODEC, B=B, n_parts=2,
                               n_shards=0 if n_shards == 1 else n_shards)
    model: dict[int, set] = {}
    n_adds = 0
    for op, arg in ops:
        if op == "add":
            gid = mi.add(sorted(arg))
            model[gid] = set(arg)
            n_adds += 1
        elif op == "delete":
            live = sorted(model)
            if live:
                d = live[arg % len(live)]
                assert mi.delete(d)
                del model[d]
        elif op == "query":
            _check(mi, model, [sorted(arg)], backend=backend, fuse=fuse)
        elif op == "seal":
            mi.seal()
        elif op == "merge":
            mi.merge()
    # end of sequence: the fixed probe set over whatever state remains
    _check(mi, model, PROBES, backend=backend, fuse=fuse)
    # sanity on the lifecycle counters the banner reports
    c = mi.counters()
    assert c["next_doc_id"] == n_adds
    assert c["tombstones"] >= 0 and c["n_segments"] >= 0


def _run_sequence_durable(ops, *, backend: str, fuse: bool):
    """The durable variant: every op journals through a WAL; a ``crash``
    op arms one registered fault (crash point or torn WAL tail), drives a
    short aimed burst at it, and — if the fault fired — recovers from the
    directory and continues the sequence on the recovered index.  The
    model tracks only acknowledged ops, so the post-recovery differential
    *is* the durability contract."""
    with tempfile.TemporaryDirectory() as wal_dir:
        injector = faults.FaultInjector(seed=0)
        log = durability.DurableLog(wal_dir, injector=injector)
        mi = segments.MutableIndex(codec_name=CODEC, B=B, n_parts=2,
                                   wal=log)
        model: dict[int, set] = {}
        n_adds = 0
        for op, arg in ops:
            if op == "add":
                gid = mi.add(sorted(arg))
                model[gid] = set(arg)
                n_adds += 1
            elif op == "delete":
                live = sorted(model)
                if live:
                    d = live[arg % len(live)]
                    assert mi.delete(d)
                    del model[d]
            elif op == "query":
                _check(mi, model, [sorted(arg)], backend=backend,
                       fuse=fuse)
            elif op == "seal":
                mi.seal()
            elif op == "merge":
                mi.merge()
            elif op == "crash":
                kind, point = FAULTS[arg % len(FAULTS)]
                injector.arm(kind, point, 1)
                try:
                    # the aimed burst: adds, a delete, a checkpointing
                    # seal, and a hooked merge reach every armed point
                    for t in range(V):
                        gid = mi.add([t])
                        model[gid] = {t}
                        n_adds += 1
                    live = sorted(model)
                    victim = live[arg % len(live)]
                    if mi.delete(victim):
                        del model[victim]
                    mi.seal()
                    mi.merge(hook=injector.merge_hook())
                except faults.InjectedCrash:
                    injector.disarm_all()
                    mi = segments.MutableIndex.recover(wal_dir,
                                                       injector=injector)
                else:
                    # point unreachable from this state (e.g. a merge
                    # stage with nothing to compact): drop the rule so it
                    # cannot fire later at an untracked moment
                    injector.disarm_all()
        _check(mi, model, PROBES, backend=backend, fuse=fuse)
        assert mi.counters()["next_doc_id"] == n_adds


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=OPS)
def test_op_sequences_differential_primary(ops):
    """The primary configuration (jax, fused, unsharded) gets the deepest
    sequence exploration."""
    _run_sequence(ops, backend="jax", fuse=True, n_shards=1)


@pytest.mark.parametrize("backend,fuse,n_shards", [
    ("jax", False, 1),
    ("jax", True, 2),
    ("jax", False, 2),
    ("pallas", True, 1),
    ("pallas", False, 1),
    ("pallas", True, 2),
    ("pallas", False, 2),
], ids=lambda v: str(v))
@settings(max_examples=5, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=OPS)
def test_op_sequences_differential_matrix(backend, fuse, n_shards, ops):
    """The remaining {backend} × {fusion} × {shards} cells: same property,
    fewer examples per cell (the full cross runs every CI push)."""
    _run_sequence(ops, backend=backend, fuse=fuse, n_shards=n_shards)


@pytest.mark.faults
@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=OPS_CRASH)
def test_op_sequences_crash_recover_primary(ops):
    """The durable alphabet on the primary configuration: after any
    injected crash/torn-tail, ``recover()`` must land byte-identical to
    the rebuild oracle of the acknowledged ops."""
    _run_sequence_durable(ops, backend="jax", fuse=True)


@pytest.mark.faults
@pytest.mark.parametrize("backend,fuse", [
    ("jax", False),
    ("pallas", True),
    ("pallas", False),
], ids=lambda v: str(v))
@settings(max_examples=4, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=OPS_CRASH)
def test_op_sequences_crash_recover_matrix(backend, fuse, ops):
    """The remaining {backend} × {fusion} cells of the durable property."""
    _run_sequence_durable(ops, backend=backend, fuse=fuse)


def test_harness_engine_present():
    """The harness must actually execute: either real hypothesis is
    installed, or the deterministic fallback engine is active — the
    skip-stub shim would silently void the whole differential contract."""
    ran = []

    @given(x=st.integers(0, 3))
    def probe(x):
        ran.append(x)

    probe()
    assert ran, "property engine did not generate examples"
