"""Differential coverage for megagroup fusion + AOT warmup (ISSUE 5).

Layers:
  * fused == unfused == sequential byte-identity over {jax, pallas} ×
    {uniform, skewed} corpora, single-device and sharded at {1, 2, 4},
  * fusion edge cases: single-group batch, all-bitmap family, empty batch,
  * the dispatch collapse itself (scheduled signatures ≫ fused dispatches
    on a mixed batch) and FusionPlan stickiness,
  * ``warmup`` compile accounting: steady-state serving after warmup
    compiles nothing.
"""

import numpy as np
import pytest

from repro.index import batch as batch_lib
from repro.index import builder, corpus as corpus_lib, engine, source
from repro.index import pipeline as pipe_lib
from repro.index import shard as shard_lib

pytestmark = pytest.mark.fusion


# --------------------------------------------------------------------------
# fixtures (mirrors tests/test_pipeline.py)
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def uniform():
    corpus = corpus_lib.synthesize(n_docs=1 << 14, n_queries=10, seed=33)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="fastpfor-d1", B=16, n_parts=2)
    seq = [engine.query(idx, q) for q in corpus.queries]
    return idx, corpus.queries, seq


@pytest.fixture(scope="module")
def skewed():
    # tiny first term, very long second term: exercises the packed
    # (skip-aware partial decode) folds through fused programs
    n_docs = 1 << 16
    table = {2: (100.0, [0.8 * (1 << 18) / n_docs,
                         38000.0 * (1 << 18) / n_docs])}
    corpus = corpus_lib.synthesize(n_docs=n_docs, n_queries=4, seed=7,
                                   table=table)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="bp8-d1", B=0, n_parts=1)
    seq = [engine.query(idx, q) for q in corpus.queries]
    return idx, corpus.queries, seq


@pytest.fixture(scope="module")
def mixed():
    """Big enough mixed batch that the scheduler produces many signatures —
    the regime fusion exists for."""
    table = {k: corpus_lib.TABLE2_CLUEWEB[k] for k in (2, 3, 4, 5)}
    corpus = corpus_lib.synthesize(n_docs=1 << 14, n_queries=32, seed=11,
                                   table=table)
    idx = builder.build(corpus.postings, corpus.n_docs,
                        codec_name="fastpfor-d1", B=16, n_parts=2)
    seq = [engine.query(idx, q) for q in corpus.queries]
    return idx, corpus.queries, seq


def _assert_identical(results, seq):
    assert len(results) == len(seq)
    for got, want in zip(results, seq):
        assert got.count == want.count
        assert got.docs.dtype == want.docs.dtype
        assert np.array_equal(got.docs, want.docs)      # byte-identical


# --------------------------------------------------------------------------
# fused == unfused == sequential, single-device
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jax", "pallas"])
@pytest.mark.parametrize("corpus_kind", ["uniform", "skewed"])
def test_fused_matches_unfused_and_sequential(request, corpus_kind, backend):
    idx, queries, seq = request.getfixturevalue(corpus_kind)
    unfused = batch_lib.execute_batch(idx, queries, backend=backend,
                                      fuse=False)
    fused = batch_lib.execute_batch(idx, queries, backend=backend,
                                    fuse=True)
    _assert_identical(unfused, seq)
    _assert_identical(fused, seq)


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_fused_pool_and_pipeline_match(uniform, backend):
    idx, queries, seq = uniform
    pool = source.ResidentPool()
    pool.warm(idx)
    plan = batch_lib.FusionPlan()
    _assert_identical(
        batch_lib.execute_batch(idx, queries, backend=backend, pool=pool,
                                plan=plan), seq)
    for depth in (1, 2):
        _assert_identical(
            pipe_lib.execute_pipelined(idx, queries, batch_size=4,
                                       depth=depth, backend=backend,
                                       pool=pool, plan=plan), seq)


# --------------------------------------------------------------------------
# fused sharded fan-out
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [1, 2, 4])
@pytest.mark.parametrize("backend", ["jax", "pallas"])
@pytest.mark.parametrize("corpus_kind", ["uniform", "skewed"])
def test_fused_sharded_matches_sequential(request, corpus_kind, backend,
                                          n_shards):
    idx, queries, seq = request.getfixturevalue(corpus_kind)
    sharded = shard_lib.shard_index(idx, n_shards)
    out = shard_lib.execute_sharded(sharded, queries, batch_size=4, depth=2,
                                    backend=backend, fuse=True)
    _assert_identical(out, seq)


def test_sharded_fused_collapses_dispatches(mixed):
    idx, queries, seq = mixed
    sharded = shard_lib.shard_index(idx, 2)
    fused_stats: dict = {}
    out = shard_lib.execute_sharded(sharded, queries, batch_size=32,
                                    depth=2, stats=fused_stats)
    _assert_identical(out, seq)
    unfused_stats: dict = {}
    shard_lib.execute_sharded(sharded, queries, batch_size=32, depth=2,
                              fuse=False, stats=unfused_stats)
    assert fused_stats["n_dispatches"] * 4 <= unfused_stats["n_dispatches"]


# --------------------------------------------------------------------------
# edge cases
# --------------------------------------------------------------------------

def test_fused_empty_batch(uniform):
    idx, _, _ = uniform
    assert batch_lib.execute_batch(idx, [], fuse=True) == []
    assert pipe_lib.execute_pipelined(idx, [], batch_size=8, fuse=True) == []


def test_fused_single_group_batch(uniform):
    """A batch whose schedule yields one group still round-trips through
    fusion (the fused key coarsens algo/arities but stays one program)."""
    idx, queries, seq = uniform
    stats: dict = {}
    out = batch_lib.execute_batch(idx, [queries[0]], fuse=True, stats=stats)
    _assert_identical(out, seq[:1])
    assert stats["n_fused_groups"] == stats["n_dispatches"]


def test_fused_all_bitmap_family():
    """Dense-only index: every query is an all-bitmap item; fusion merges
    the bitmap groups into one family program per batch."""
    n_docs = 1 << 12
    rng = np.random.default_rng(5)
    postings = [np.sort(rng.choice(n_docs, n_docs // 4, replace=False))
                for _ in range(3)]
    idx = builder.build(postings, n_docs, codec_name="bp-d1", B=16,
                        n_parts=2)
    assert all(tp.kind == "bitmap" for p in idx.parts
               for tp in p.terms.values())
    queries = [[0, 1], [1, 2], [0, 1, 2], [2]]
    seq = [engine.query(idx, q) for q in queries]
    for fuse in (False, True):
        _assert_identical(
            batch_lib.execute_batch(idx, queries, fuse=fuse), seq)
    stats: dict = {}
    batch_lib.execute_batch(idx, queries, fuse=True, stats=stats)
    assert stats["n_dispatches"] == 1           # one bitmap family program


def test_fused_mixed_words_and_missing_bitmaps(uniform):
    """Queries of different bitmap arity (including none) fuse into one svs
    family: missing probe slots gather the all-ones identity."""
    idx, queries, seq = uniform
    pool = source.ResidentPool()
    pool.warm(idx)
    _assert_identical(
        batch_lib.execute_batch(idx, queries, pool=pool, fuse=True), seq)


def test_fused_composite_zero_length_tail():
    """Composite lists at exact block multiples carry a zero-length varint
    tail; the decoded serving path and the fused family ceilings must both
    stay inert to the empty-tail container (ISSUE 8 bugfix guard)."""
    from repro.core import composite
    per = composite.DEFAULT_ROWS * 128
    n_docs = 1 << 14
    rng = np.random.default_rng(13)
    postings = [np.sort(rng.choice(n_docs, per, replace=False)),       # tail 0
                np.sort(rng.choice(n_docs, per + 3, replace=False)),   # tail 3
                np.sort(rng.choice(n_docs, 200, replace=False))]       # no head
    idx = builder.build(postings, n_docs, codec_name="composite-d1", B=0,
                        n_parts=1, varint_tail_below=0)
    payloads = [tp.payload for tp in idx.parts[0].terms.values()]
    assert payloads[0].tail.n == 0 and payloads[1].tail.n == 3
    assert payloads[2].head is None
    queries = [[0, 1], [0, 2], [1, 2], [0, 1, 2]]
    seq = [engine.query(idx, q) for q in queries]
    for fuse in (False, True):
        _assert_identical(
            batch_lib.execute_batch(idx, queries, fuse=fuse), seq)


def test_fused_mixed_codec_families_one_batch():
    """An autotuned index mixes varint/composite/bitpack payloads in one
    batch; sentinel padding from the decoded sources must stay inert
    through the fused family ceilings on both backends — 2^32-range values
    sit right under the int32 sentinel, the regime where a padding bug
    would surface as phantom hits."""
    n_docs = 1 << 14
    rng = np.random.default_rng(17)
    postings = [np.sort(rng.choice(n_docs, n, replace=False))
                for n in (60, 300, 1100, 5000, 9000)]
    idx = builder.build(postings, n_docs, codec_name="auto", B=0, n_parts=1)
    fams = {type(tp.payload).__name__ for p in idx.parts
            for tp in p.terms.values() if tp.kind == "list"}
    assert len(fams) >= 2                       # genuinely mixed families
    queries = [[0, 4], [1, 3], [2, 4], [0, 1, 2], [3, 4], [0, 1, 2, 3, 4]]
    seq = [engine.query(idx, q) for q in queries]
    for backend in ("jax", "pallas"):
        for fuse in (False, True):
            _assert_identical(
                batch_lib.execute_batch(idx, queries, backend=backend,
                                        fuse=fuse), seq)


# --------------------------------------------------------------------------
# the dispatch collapse + plan stickiness
# --------------------------------------------------------------------------

def test_fusion_collapses_dispatch_count(mixed):
    idx, queries, seq = mixed
    unfused_stats: dict = {}
    _assert_identical(batch_lib.execute_batch(idx, queries, fuse=False,
                                              stats=unfused_stats), seq)
    fused_stats: dict = {}
    _assert_identical(batch_lib.execute_batch(idx, queries, fuse=True,
                                              stats=fused_stats), seq)
    assert fused_stats["n_sched_groups"] == unfused_stats["n_groups"]
    # the ISSUE 5 gate: ≥ 4× fewer device dispatches on a mixed batch
    assert fused_stats["n_dispatches"] * 4 <= unfused_stats["n_dispatches"]


def test_fusion_plan_ceilings_are_sticky(mixed):
    idx, queries, _ = mixed
    plan = batch_lib.FusionPlan()
    full = batch_lib.fuse_groups(batch_lib.schedule(idx, queries),
                                 plan=plan)
    # a later, narrower batch reuses the full batch's (sticky) ceilings,
    # so its fused keys — and therefore compiled programs — are a subset
    sub = batch_lib.fuse_groups(batch_lib.schedule(idx, queries[:3]),
                                plan=plan)
    assert set(sub).issubset(set(full))


def test_fused_key_shape_contains_members(mixed):
    idx, queries, _ = mixed
    groups = batch_lib.schedule(idx, queries)
    fused = batch_lib.fuse_groups(dict(groups))
    assert len(fused) < len(groups)
    for fkey in fused:
        assert fkey.fused is not None
        members = [k for k in groups
                   if k.kind == fkey.kind
                   and ((k.packed is None) == (fkey.packed is None))]
        for k in members:
            assert fkey.m_bucket >= k.m_bucket
            assert fkey.n_bucket >= k.n_bucket
            assert fkey.words >= k.words
    # every scheduled item lands in exactly one fused group
    assert (sum(len(v) for v in fused.values())
            == sum(len(v) for v in groups.values()))


# --------------------------------------------------------------------------
# AOT warmup
# --------------------------------------------------------------------------

def test_warmup_then_steady_state_never_compiles(mixed):
    idx, queries, seq = mixed
    pool = source.ResidentPool()
    pool.warm(idx)
    plan = batch_lib.FusionPlan()
    wu = batch_lib.warmup(idx, queries, plan=plan, batch_size=8, pool=pool)
    assert wu["n_signatures"] > 0
    assert wu["passes"] >= 2                    # ran to the fixed point
    stats: dict = {}
    out = []
    for lo in range(0, len(queries), 8):
        out.extend(batch_lib.execute_batch(idx, queries[lo: lo + 8],
                                           pool=pool, plan=plan,
                                           stats=stats))
    _assert_identical(out, seq)
    assert stats.get("n_compiles", 0) == 0


def test_warmup_synthesizes_queries_when_none_given(uniform):
    idx, _, _ = uniform
    qs = batch_lib.synth_warmup_queries(idx, 8, seed=3)
    assert len(qs) == 8
    for q in qs:
        assert len(q) >= 1
        out = engine.query(idx, q)              # every query is answerable
        assert out.count >= 0
    plan = batch_lib.FusionPlan()
    wu = batch_lib.warmup(idx, None, plan=plan, batch_size=8)
    assert wu["n_signatures"] > 0
